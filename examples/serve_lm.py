"""Batched serving demo: reduced gemma3 (5:1 local:global attention) behind
the KV-cache engine — prefill once, then one-token decode steps.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config, reduce_config
from repro.models.transformer import init_lm
from repro.serve.engine import Engine

cfg = reduce_config(get_config("gemma3-1b"))
print(f"serving {cfg.name}: {cfg.num_layers} layers "
      f"({sum(1 for b in cfg.blocks if b.window)} local / "
      f"{sum(1 for b in cfg.blocks if not b.window)} global), d={cfg.d_model}")
params = init_lm(cfg, jax.random.PRNGKey(0))
eng = Engine(cfg, params, max_len=64)

prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 12), 3, cfg.vocab_size))
t0 = time.time()
res = eng.generate(prompts, max_new_tokens=16)
dt = time.time() - t0
print(f"generated {res.tokens.shape[0]}x{res.steps} tokens in {dt:.2f}s "
      f"({res.tokens.shape[0]*res.steps/dt:.1f} tok/s on CPU)")
for i, row in enumerate(res.tokens):
    print(f"  req{i}: prompt={row[:res.prompt_len].tolist()} -> gen={row[res.prompt_len:].tolist()}")
