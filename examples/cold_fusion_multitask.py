"""Multitask ColD Fusion with baselines + a malicious contributor.

Mirrors the paper's main experiment (§5.1) plus the §9 robustness story:
one contributor uploads NaN weights, another uploads a destructive update;
the Repository's screening rejects both and the run is unaffected.

  PYTHONPATH=src python examples/cold_fusion_multitask.py
"""
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.roberta_base import TINY
from repro.core import Contributor, EvalTask, Repository, evaluate_base_model, run_cold_fusion
from repro.data.synthetic import SyntheticSuite
from repro.train.pretrain import pretrain_mlm

SEQ = 24
cfg = dataclasses.replace(TINY, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
                          d_ff=128, vocab_size=256, max_seq_len=SEQ + 8)
suite = SyntheticSuite(vocab_size=256, num_tasks=16, seed=0, noise=0.15)
body, _ = pretrain_mlm(cfg, suite, steps=150, seq_len=SEQ)

contribs = []
for tid in range(8):
    d = suite.dataset(tid, 1024, 64, SEQ)
    contribs.append(Contributor(cfg, tid, suite.tasks[tid].num_classes,
                                d["x_train"], d["y_train"], steps=30, lr=2e-3, seed=tid))

ev_seen = [EvalTask(t, suite.tasks[t].num_classes, *(suite.dataset(t, 256, 256, SEQ, split_seed=1)[k]
           for k in ("x_train", "y_train", "x_test", "y_test"))) for t in (0, 1)]
ev_unseen = [EvalTask(t, suite.tasks[t].num_classes, *(suite.dataset(t, 256, 256, SEQ, split_seed=1)[k]
             for k in ("x_train", "y_train", "x_test", "y_test"))) for t in (12, 13)]

print("== honest cohort ==")
repo = Repository(body)
log = run_cold_fusion(cfg, repo, contribs, iterations=3, contributors_per_iter=4,
                      eval_seen=ev_seen, eval_unseen=ev_unseen, eval_every=3,
                      eval_steps=60, eval_lr=2e-3, progress=True)
print(f"seen  finetuned: {log.mean('seen_finetuned')[-1]:.3f}  frozen: {log.mean('seen_frozen')[-1]:.3f}")
print(f"unseen finetuned: {log.mean('unseen_finetuned')[-1]:.3f}  frozen: {log.mean('unseen_frozen')[-1]:.3f}")

print("\n== adversarial iteration: NaN + runaway contributions get screened ==")
base = repo.download()
for c in contribs[:3]:
    repo.upload(c.contribute(base))
repo.upload(jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), base))          # malicious NaN
repo.upload(jax.tree.map(lambda x: x + 100.0 * jax.random.normal(jax.random.PRNGKey(0), x.shape, x.dtype), base))  # runaway
rec = repo.fuse_pending()
print(f"fused {rec.n_accepted}/{rec.n_contributions} contributions "
      f"(rejected {rec.n_contributions - rec.n_accepted} anomalous uploads)")
acc = np.mean(list(evaluate_base_model(cfg, repo.download(), ev_seen, frozen=True,
                                       steps=60, lr=2e-3).values()))
print(f"post-adversarial frozen accuracy still healthy: {acc:.3f}")
