"""Multitask ColD Fusion with baselines + a malicious contributor.

Demonstrates the paper's main loop end-to-end on the synthetic multitask
suite: (1) the §5.1 collaborative schedule — several contributors finetune
the shared base on their own tasks, the Repository screens and fuses every
cohort, and both seen- and unseen-task accuracy improve across iterations;
then (2) the §9 robustness story — one contributor uploads NaN weights and
another a runaway update, the Repository's MAD screen rejects both, and the
fused model is unaffected.

  PYTHONPATH=src python examples/cold_fusion_multitask.py [--dry-run]

``--dry-run`` shrinks every knob (steps, cohort size, eval budget) so the
whole script finishes in seconds — scripts/ci.sh runs it on every CI pass
so this example cannot silently rot.
"""
import argparse
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.roberta_base import TINY
from repro.core import Contributor, EvalTask, Repository, evaluate_base_model, run_cold_fusion
from repro.data.synthetic import SyntheticSuite
from repro.train.pretrain import pretrain_mlm

SEQ = 24


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="minimal steps/cohort for a seconds-long smoke run")
    args = ap.parse_args(argv)

    if args.dry_run:
        knobs = dict(pretrain=8, n_contrib=3, ft_steps=4, iters=1,
                     per_iter=3, eval_steps=8, n_train=96, n_eval=48)
    else:
        knobs = dict(pretrain=150, n_contrib=8, ft_steps=30, iters=3,
                     per_iter=4, eval_steps=60, n_train=1024, n_eval=256)

    cfg = dataclasses.replace(TINY, d_model=64, num_heads=2, num_kv_heads=2,
                              head_dim=32, d_ff=128, vocab_size=256,
                              max_seq_len=SEQ + 8)
    suite = SyntheticSuite(vocab_size=256, num_tasks=16, seed=0, noise=0.15)
    body, _ = pretrain_mlm(cfg, suite, steps=knobs["pretrain"], seq_len=SEQ)

    contribs = []
    for tid in range(knobs["n_contrib"]):
        d = suite.dataset(tid, knobs["n_train"], 64, SEQ)
        contribs.append(Contributor(cfg, tid, suite.tasks[tid].num_classes,
                                    d["x_train"], d["y_train"],
                                    steps=knobs["ft_steps"], lr=2e-3, seed=tid))

    def ev_tasks(tids):
        return [EvalTask(t, suite.tasks[t].num_classes,
                         *(suite.dataset(t, knobs["n_eval"], knobs["n_eval"], SEQ,
                                         split_seed=1)[k]
                           for k in ("x_train", "y_train", "x_test", "y_test")))
                for t in tids]

    ev_seen, ev_unseen = ev_tasks((0, 1)), ev_tasks((12, 13))

    print("== honest cohort ==")
    repo = Repository(body)
    log = run_cold_fusion(cfg, repo, contribs, iterations=knobs["iters"],
                          contributors_per_iter=knobs["per_iter"],
                          eval_seen=ev_seen, eval_unseen=ev_unseen,
                          eval_every=knobs["iters"], eval_steps=knobs["eval_steps"],
                          eval_lr=2e-3, progress=True)
    print(f"seen  finetuned: {log.mean('seen_finetuned')[-1]:.3f}  "
          f"frozen: {log.mean('seen_frozen')[-1]:.3f}")
    print(f"unseen finetuned: {log.mean('unseen_finetuned')[-1]:.3f}  "
          f"frozen: {log.mean('unseen_frozen')[-1]:.3f}")

    print("\n== adversarial iteration: NaN + runaway contributions get screened ==")
    base = repo.download()
    for c in contribs[:3]:
        repo.upload(c.contribute(base))
    repo.upload(jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), base))          # malicious NaN
    repo.upload(jax.tree.map(
        lambda x: x + 100.0 * jax.random.normal(jax.random.PRNGKey(0), x.shape, x.dtype),
        base))                                                                    # runaway
    rec = repo.fuse_pending()
    print(f"fused {rec.n_accepted}/{rec.n_contributions} contributions "
          f"(rejected {rec.n_contributions - rec.n_accepted} anomalous uploads)")
    acc = np.mean(list(evaluate_base_model(cfg, repo.download(), ev_seen, frozen=True,
                                           steps=knobs["eval_steps"], lr=2e-3).values()))
    print(f"post-adversarial frozen accuracy still healthy: {acc:.3f}")
    assert rec.n_accepted == rec.n_contributions - 2, "screen must reject both attacks"


if __name__ == "__main__":
    main()
