"""ColD Fusion as an always-on service: a fusion daemon + N contributor
processes recycling "finetuned" models through the durable contribution
queue (docs/service_loop.md).

The driver initializes an on-disk repository, launches the daemon
(``python -m repro.launch.serve_repository``) and ``--contributors``
independent contributor subprocesses.  Each contributor loops for
``--rounds``: wait for the base of its round to publish, download it,
apply a deterministic "finetune" delta, and submit — so the run is fully
checkable: the driver verifies the final base against the closed-form
expectation and reports queue throughput.

  PYTHONPATH=src python examples/cold_service_demo.py
  PYTHONPATH=src python examples/cold_service_demo.py --mesh 8   # sharded daemon
  PYTHONPATH=src python examples/cold_service_demo.py --duplicates 1  # novelty screen
  PYTHONPATH=src python examples/cold_service_demo.py --compress  # delta codec

With ``--compress`` every contributor enqueues its round as a
delta-compressed submission (top-k int8 payload against the base it just
downloaded, docs/service_loop.md) instead of a dense row; the daemon
decodes inside the fused kernel and the driver checks the same closed
form — compression must be invisible to the result.

With ``--mesh N`` the daemon opens the repository on an N-device mesh
(the driver forces the fake host-device count for that child); the
contributors are unchanged — the queue format is engine-agnostic.

``--duplicates D`` additionally launches D *shadow* contributors, each
replaying contributor 0's exact submission every round under its own
name, and arms the daemon's content-based novelty screen
(``--novelty-threshold``).  The driver then verifies the planted
near-duplicates were all rejected at the queue boundary — the published
base and fused-contribution count match the duplicate-free closed form —
while every distinct contribution was admitted.

``--regress R`` launches R *saboteur* contributors and arms the daemon's
forgetting regression gate (``--gate``, docs/observability.md).  Each
saboteur waits for the last benign round to publish, then submits a full
cohort of large-noise rows — uniform enough to pass the §9 MAD screen,
harmful enough that the post-publish task probes trip.  The driver then
verifies the gate rolled every harmful publish back on disk (the final
base still matches the closed form), moved every planted row into
``<root>/quarantine/``, and logged the verdicts to ``metrics.jsonl``.

``--tasks T`` runs T *dissimilar* contributor streams against a routed
multi-base daemon (``--max-bases``, docs/service_loop.md): each task's
finetune delta carries a distinct per-lane-tile sign pattern, every
contributor declares ``family="main"`` in round 0 and then follows
wherever the sketch router actually sent it (``route_of``).  The driver
verifies the streams *separate*: exactly T family members at the end,
each bit-close to the closed-form fuse of only its own task's stream,
then runs one in-process ``cross_fuse`` and checks every member lands on
the closed-form inter-family average.
"""
import argparse
import os
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

W, B = 2048, 17  # tiny deterministic base: every element moves identically
LANE = 1024      # repro.utils.flat.LANE — the sketch's bucket granularity


def _expected_w(contributors: int, rounds: int) -> float:
    """w starts at 0; round r adds mean_c((c+1) * 0.1 * (r+1))."""
    mean_c = sum(c + 1 for c in range(contributors)) / contributors
    return sum(0.1 * (r + 1) * mean_c for r in range(rounds))


def _task_pattern(t: int):
    """Task t's finetune direction: alternating per-LANE-tile signs on w
    (offset by t, so adjacent tasks are near-orthogonal in every sketch
    bucket), all-positive b.  Signs must be constant per tile — random
    per-element signs would cancel inside the sketch's bucket sums and
    make every task look alike to the router."""
    w = np.ones((W,), np.float32)
    for j in range((W + LANE - 1) // LANE):
        if (j + t) % 2:
            w[j * LANE:(j + 1) * LANE] = -1.0
    return {"w": w, "b": np.ones((B,), np.float32)}


def contributor_main(args) -> int:
    import jax

    from repro.serve.cold_service import ContributorClient

    if args.regressor:
        # the saboteur: wait for every benign round to land, then submit a
        # full cohort of large-noise rows.  All the rows' diff norms agree,
        # so the §9 MAD screen admits them; the noise wrecks the probe
        # readouts, so the regression gate must roll the publish back and
        # quarantine every row (docs/observability.md).
        name = f"bad{args.index}"
        client = ContributorClient(args.root, name=name)
        client.wait_for_iteration(args.rounds, timeout=args.timeout)
        base = client.download_base()
        for j in range(args.contributors):
            rng = np.random.default_rng((4242, args.index, j))
            harmful = jax.tree.map(
                lambda x: x + rng.normal(0.0, 10.0, x.shape).astype(x.dtype),
                base)
            sub = client.submit(harmful, weight=1.0,
                                base_iteration=args.rounds)
            print(f"[{name}] submitted harmful row {sub}", flush=True)
        return 0

    if args.tasks > 1:
        # a routed-stream contributor: round 0 declares main (the base is
        # all-zeros, so the finetune IS the task-patterned delta) and then
        # follows wherever the router actually sent it — the member name
        # is discovered from the status routes ring, never assumed.
        t, c = args.task, args.index
        name = f"t{t}c{c}"
        client = ContributorClient(args.root, name=name)
        pat = _task_pattern(t)
        home = "main"
        for r in range(args.rounds):
            delta = (c + 1) * 0.1 * (r + 1)
            if r == 0:
                client.wait_for_iteration(0, timeout=args.timeout)
                finetuned = {k: delta * v for k, v in pat.items()}
                sub = client.submit(finetuned, weight=1.0, base_iteration=0,
                                    family="main")
                deadline = time.time() + args.timeout
                route = None
                while route is None and time.time() < deadline:
                    route = client.route_of(sub)
                    if route is None:
                        time.sleep(0.05)
                if route is None:
                    print(f"[{name}] round-0 route never landed", flush=True)
                    return 1
                home = route["family"]
            else:
                client.wait_for_family(home, r, timeout=args.timeout)
                base = client.download_base(family=home)
                finetuned = {k: np.asarray(base[k]) + delta * pat[k]
                             for k in pat}
                sub = client.submit(finetuned, weight=1.0, base_iteration=r,
                                    family=home)
            print(f"[{name}] round {r}: submitted {sub} -> {home} "
                  f"(delta=+{delta:.2f})", flush=True)
        return 0

    # a shadow contributor replays contributor --shadow-of's round-r
    # finetune under its own name: content the novelty screen must reject,
    # submission ids it must not.  The replay is rebuilt from the run's
    # closed form rather than download_base() — the real base may already
    # have advanced past round r by the time a slow shadow downloads, and a
    # replay against the wrong base would be genuinely novel content.
    shadow = args.shadow_of is not None
    index = args.shadow_of if shadow else args.index
    name = f"dup{args.index}" if shadow else f"c{args.index}"
    client = ContributorClient(args.root, name=name)
    for r in range(args.rounds):
        # a shadow replays round r only once round r has FUSED (iteration
        # r+1 published): the original's row is then guaranteed to be in
        # the novelty screen's window, so the replay is deterministically
        # the duplicate.  Replaying as soon as round r opens can win the
        # race instead — the replay is admitted as novel and the original
        # rejected, and the original's NEXT round then re-finetunes a
        # newer base, leaving a genuinely-novel row staged forever.
        st = client.wait_for_iteration(r + 1 if shadow else r,
                                       timeout=args.timeout)
        delta = (index + 1) * 0.1 * (r + 1)
        if shadow:
            val = _expected_w(args.contributors, r) + delta
            finetuned = {"w": np.full((W,), val, np.float32),
                         "b": np.full((B,), val, np.float32)}
        else:
            base = client.download_base()
            finetuned = jax.tree.map(lambda x: x + delta, base)
        if args.compress and not shadow:
            # a uniform finetune delta has every entry live, so keep the
            # whole block (k_per_block=LANE) — the only loss is int8
            # quantization, invisible at the driver's closed-form atol
            from repro.utils.flat import LANE
            sub = client.submit(finetuned, weight=1.0, base_iteration=r,
                                compress=True, base=base, k_per_block=LANE)
        else:
            sub = client.submit(finetuned, weight=1.0, base_iteration=r)
        print(f"[{name}] round {r}: submitted {sub} "
              f"(delta=+{delta:.2f}{' REPLAY' if shadow else ''}"
              f"{' COMPRESSED' if args.compress and not shadow else ''})",
              flush=True)
    return 0


def _routed_checks(args, root, st, elapsed) -> int:
    """Verify the routed run separated: exactly --tasks members, each
    bit-close to the closed-form fuse of only its own task's stream
    (membership decided by CONTENT, not by name — which stream ends up on
    'main' depends on arrival order), then one in-process cross-fuse
    round landing every member on the inter-family average."""
    from repro.checkpoint import io as ckpt
    from repro.core.repository import RepositoryFamily, family_member_root

    fams = st.get("families") or {}
    want_w = _expected_w(args.contributors, args.rounds)
    per_member = args.contributors * args.rounds
    ok = len(fams) == args.tasks
    if not ok:
        print(f"[demo] expected {args.tasks} members, have {sorted(fams)}",
              flush=True)
    got = {}
    for n, f in sorted(fams.items()):
        ok = ok and (f["iteration"] == args.rounds
                     and f["fused_contributions"] == per_member)
        got[n] = ckpt.load(os.path.join(
            family_member_root(root, n),
            f"base_iter{f['iteration']:04d}.npz"), as_jax=False)
    matched = {}
    for t in range(args.tasks):
        want = {k: want_w * v for k, v in _task_pattern(t).items()}
        hits = [n for n, bb in got.items()
                if all(np.allclose(np.asarray(bb[k]), want[k], atol=1e-5)
                       for k in want)]
        if len(hits) == 1:
            matched[t] = hits[0]
        else:
            print(f"[demo] task {t}: want exactly one member at closed "
                  f"form, matched {hits}", flush=True)
            ok = False
    ok = ok and len(set(matched.values())) == args.tasks
    cross_ok = False
    if ok:
        # one inter-cluster merge round: every member must land exactly on
        # the mean of the pre-cross bases (closed form of cross_fuse at
        # alpha=1), one iteration further on
        pre = {n: {k: np.asarray(v) for k, v in bb.items()}
               for n, bb in got.items()}
        RepositoryFamily.open(root).cross_fuse()
        mean = {k: np.mean([bb[k] for bb in pre.values()], axis=0)
                for k in ("w", "b")}
        cross_ok = True
        for n in fams:
            bb = ckpt.load(os.path.join(
                family_member_root(root, n),
                f"base_iter{args.rounds + 1:04d}.npz"), as_jax=False)
            cross_ok = cross_ok and all(
                np.allclose(np.asarray(bb[k]), mean[k], atol=1e-5)
                for k in mean)
        ok = ok and cross_ok
    print(f"[demo] {args.tasks} tasks x {args.contributors} contributors x "
          f"{args.rounds} rounds -> members {sorted(fams)} "
          f"({st.get('families_spawned_total', 0)} spawned), "
          f"task->member {matched}, "
          f"{st['fused_contributions']} contributions fused in "
          f"{elapsed:.1f}s", flush=True)
    print(f"[demo] separation + cross-fuse -> "
          f"{'OK' if ok else 'MISMATCH'}", flush=True)
    return 0 if ok else 1


def driver_main(args) -> int:
    from repro.checkpoint import io as ckpt
    from repro.serve.cold_service import ContributorClient

    root = args.root or tempfile.mkdtemp(prefix="cold_service_demo_")
    os.makedirs(root, exist_ok=True)
    base_npz = os.path.join(root, "seed_base.npz")
    ckpt.save(base_npz, {"w": np.zeros((W,), np.float32),
                         "b": np.zeros((B,), np.float32)})

    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    daemon_env = dict(env)
    if args.mesh:
        flags = daemon_env.get("XLA_FLAGS", "")
        daemon_env["XLA_FLAGS"] = (flags + " " if flags else "") + \
            f"--xla_force_host_platform_device_count={args.mesh}"
    daemon_cmd = [
        sys.executable, "-m", "repro.launch.serve_repository",
        "--root", root, "--init-npz", base_npz,
        "--min-cohort", str(args.contributors), "--poll", "0.02",
    ]
    routed = args.tasks > 1
    # drain-driver mode: the daemon gets NO --max-iterations, because a
    # counter the driver asserts on can land *after* the stop condition —
    # the --duplicates flake was exactly that race (the replayer's last
    # planted near-duplicate raced the final round's publish, so the
    # daemon quiesced with novelty_rejected_total one short).  Instead
    # the driver polls status until every asserted counter reaches its
    # closed form AND the queue is fully drained, then asks for a clean
    # shutdown; the idle timeout is only a backstop.
    drain = not args.regress and (routed or args.duplicates > 0)
    if args.regress:
        # no --max-iterations: the daemon would quiesce at the benign fixed
        # point (iteration == rounds, empty queue) before the saboteurs'
        # rows arrive — and after a rollback it sits there again.  The
        # driver watches status for the gate verdict and asks for a clean
        # shutdown; the idle timeout is only a backstop.
        daemon_cmd += ["--gate", "--idle-timeout", str(args.timeout)]
    elif drain:
        daemon_cmd += ["--idle-timeout", str(args.timeout)]
    else:
        daemon_cmd += ["--max-iterations", str(args.rounds),
                       "--idle-timeout", "30"]
    if routed:
        max_bases = (args.max_bases if args.max_bases is not None
                     else args.tasks + 1)
        daemon_cmd += ["--max-bases", str(max_bases)]
    if args.mesh:
        daemon_cmd += ["--mesh", str(args.mesh)]
    if args.duplicates:
        # planted replays ride the queue alongside the real contributors;
        # the novelty screen must keep them out of every cohort
        daemon_cmd += ["--novelty-threshold", "0.1",
                       "--sketch-window",
                       str(4 * (args.contributors + args.duplicates))]

    def _spawn(i, shadow_of=None, regressor=False, task=None):
        cmd = [sys.executable, os.path.abspath(__file__),
               "--role", "contributor", "--root", root, "--index", str(i),
               "--contributors", str(args.contributors),
               "--rounds", str(args.rounds), "--timeout", str(args.timeout)]
        if shadow_of is not None:
            cmd += ["--shadow-of", str(shadow_of)]
        if regressor:
            cmd += ["--regressor"]
        if args.compress:
            cmd += ["--compress"]
        if task is not None:
            cmd += ["--tasks", str(args.tasks), "--task", str(task)]
        return subprocess.Popen(cmd, env=env)

    def _wait(name, proc):
        try:
            rc = proc.wait(timeout=args.timeout)
        except subprocess.TimeoutExpired:
            proc.kill()
            rc = "timeout"
        if rc != 0:
            print(f"[demo] {name} FAILED (rc={rc})", flush=True)
        return rc != 0

    t0 = time.time()
    daemon = subprocess.Popen(daemon_cmd, env=daemon_env)
    if routed:
        workers = [(f"t{t}c{i}", _spawn(i, task=t))
                   for t in range(args.tasks)
                   for i in range(args.contributors)]
    else:
        workers = [(f"c{i}", _spawn(i)) for i in range(args.contributors)]
        workers += [(f"dup{i}", _spawn(i, shadow_of=i % args.contributors))
                    for i in range(args.duplicates)]
        workers += [(f"bad{i}", _spawn(i, regressor=True))
                    for i in range(args.regress)]
    failed = any([_wait(name, proc) for name, proc in workers])
    if drain:
        # every submission is on the queue; wait for the daemon to have
        # fully processed them — every member at its final iteration,
        # every planted replay rejected, nothing queued/staged/in flight —
        # before asking it to quiesce (the closed-form checks below only
        # hold once the drain condition does)
        client = ContributorClient(root)
        n_dup = args.duplicates * args.rounds
        deadline = time.time() + args.timeout
        while not failed and time.time() < deadline:
            st = client.status()
            if st is not None:
                fams = st.get("families") or {}
                settled = (len(fams) == args.tasks
                           and all(f["iteration"] >= args.rounds
                                   for f in fams.values())
                           if routed else st["iteration"] >= args.rounds)
                if (settled and st["queue_depth"] == 0 and st["staged"] == 0
                        and not st["inflight"]
                        and st["novelty_rejected_total"] == n_dup):
                    break
            time.sleep(0.1)
        else:
            if not failed:
                print("[demo] daemon never drained", flush=True)
                failed = True
        daemon.terminate()
    if args.regress:
        # every saboteur row is in the queue; wait for the gate to finish
        # quarantining them all, then ask the daemon to quiesce
        client = ContributorClient(root)
        want_q = args.regress * args.contributors
        deadline = time.time() + args.timeout
        while not failed and time.time() < deadline:
            st = client.status()
            if (st is not None and st["quarantined_total"] == want_q
                    and st["iteration"] == args.rounds
                    and st["queue_depth"] == 0):
                break
            time.sleep(0.1)
        else:
            if not failed:
                print("[demo] gate verdict never landed", flush=True)
                failed = True
        daemon.terminate()
    failed |= _wait("daemon", daemon)
    elapsed = time.time() - t0
    if failed:
        return 1

    st = ContributorClient(root).status()
    if routed:
        return _routed_checks(args, root, st, elapsed)
    want_w = _expected_w(args.contributors, args.rounds)
    got = ckpt.load(os.path.join(
        root, f"base_iter{st['iteration']:04d}.npz"), as_jax=False)
    n_contrib = args.contributors * args.rounds
    n_dup = args.duplicates * args.rounds
    ok = (st["iteration"] == args.rounds
          and st["fused_contributions"] == n_contrib
          and np.allclose(np.asarray(got["w"]), want_w, atol=1e-5)
          and np.allclose(np.asarray(got["b"]), want_w, atol=1e-5))
    if args.duplicates:
        # every planted replay was screened out at the queue boundary
        # (exactly one of each identical-content pair fused, so the base
        # check above already proves none slipped through)
        ok = ok and st["novelty_rejected_total"] == n_dup
    if args.regress:
        # the base check above already proves every harmful publish was
        # rolled back on disk; here: every planted row sits in quarantine
        # (never deleted, never re-fused) and the verdicts were logged
        from repro.checkpoint.io import read_jsonl
        n_bad = args.regress * args.contributors
        qdir = os.path.join(root, "quarantine")
        qfiles = os.listdir(qdir) if os.path.isdir(qdir) else []
        events = [r.get("event") for r in
                  read_jsonl(os.path.join(root, "metrics.jsonl"))]
        ok = (ok and st["quarantined_total"] == n_bad
              and len(qfiles) == n_bad
              and st["rollbacks_total"] >= 1
              and (args.regress > 1 or st["rollbacks_total"] == 1)
              and "quarantine" in events and "rollback" in events)
        print(f"[demo] gate: {st['rollbacks_total']} rollbacks, "
              f"{st['quarantined_total']}/{n_bad} harmful rows quarantined, "
              f"{len(events)} metrics records", flush=True)
    print(f"[demo] {args.contributors} contributors x {args.rounds} rounds "
          f"(+{args.duplicates} replayers) -> iteration {st['iteration']}, "
          f"{st['fused_contributions']} contributions fused, "
          f"{st['novelty_rejected_total']} near-duplicates rejected in "
          f"{elapsed:.1f}s ({n_contrib / elapsed:.1f} contrib/s end-to-end)",
          flush=True)
    print(f"[demo] final base w={float(np.asarray(got['w'])[0]):.4f} "
          f"(expected {want_w:.4f}) -> {'OK' if ok else 'MISMATCH'}", flush=True)
    return 0 if ok else 1


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--role", choices=("driver", "contributor"), default="driver")
    p.add_argument("--root", default=None)
    p.add_argument("--contributors", type=int, default=2)
    p.add_argument("--rounds", type=int, default=3)
    p.add_argument("--mesh", type=int, default=0,
                   help="run the daemon on an N-device (fake) mesh")
    p.add_argument("--duplicates", type=int, default=0,
                   help="launch this many replaying shadow contributors and "
                        "arm the daemon's novelty screen against them")
    p.add_argument("--regress", type=int, default=0,
                   help="launch this many harmful saboteur contributors and "
                        "arm the daemon's forgetting regression gate")
    p.add_argument("--compress", action="store_true",
                   help="contributors enqueue delta-compressed submissions "
                        "(top-k int8 vs their downloaded base) instead of "
                        "dense rows")
    p.add_argument("--tasks", type=int, default=1,
                   help="run this many dissimilar contributor streams "
                        "against a routed multi-base daemon and verify "
                        "they separate (1 = the single-base demo)")
    p.add_argument("--max-bases", type=int, default=None,
                   help="family member cap for the routed daemon "
                        "(default: --tasks + 1)")
    p.add_argument("--timeout", type=float, default=180.0)
    p.add_argument("--index", type=int, default=0, help="(contributor role)")
    p.add_argument("--task", type=int, default=0,
                   help="(contributor role) task stream index")
    p.add_argument("--shadow-of", type=int, default=None,
                   help="(contributor role) replay this index's submissions")
    p.add_argument("--regressor", action="store_true",
                   help="(contributor role) submit a harmful cohort after "
                        "the benign rounds finish")
    args = p.parse_args()
    if args.tasks > 1 and (args.duplicates or args.regress or args.compress):
        p.error("--tasks > 1 does not combine with "
                "--duplicates/--regress/--compress")
    if args.role == "contributor":
        return contributor_main(args)
    return driver_main(args)


if __name__ == "__main__":
    sys.exit(main())
