"""End-to-end training driver example (deliverable b).

Default: a ~10M-parameter gemma3-family model for 200 real optimizer steps
on CPU (~4 min).  The paper-scale invocation — a ~100M model for a few
hundred steps — is the same driver:

  PYTHONPATH=src python -m repro.launch.train --arch roberta-base --steps 300

  PYTHONPATH=src python examples/train_lm_e2e.py
"""
import subprocess
import sys

cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "gemma3-1b", "--reduced",
       "--steps", "200", "--batch", "8", "--seq", "64", "--log-every", "20"]
print("+", " ".join(cmd))
env = {"PYTHONPATH": "src"}
import os
e = dict(os.environ); e.update(env)
raise SystemExit(subprocess.call(cmd, env=e))
