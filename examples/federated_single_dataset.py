"""Federated-learning flavour of ColD Fusion (paper §6, Fig. 6a): several
contributors hold disjoint shards of ONE dataset and fresh data streams in
every iteration; the fused model keeps improving without sharing raw data.

  PYTHONPATH=src python examples/federated_single_dataset.py
"""
import dataclasses
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs.roberta_base import TINY
from repro.core import EvalTask, Repository, evaluate_base_model
from repro.data.synthetic import SyntheticSuite
from repro.models import encoder as E
from repro.train import finetune as FT
from repro.train.pretrain import pretrain_mlm
import jax

SEQ = 24
TASK = 0
cfg = dataclasses.replace(TINY, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
                          d_ff=128, vocab_size=256, max_seq_len=SEQ + 8)
suite = SyntheticSuite(vocab_size=256, num_tasks=4, seed=0, noise=0.15)
body, _ = pretrain_mlm(cfg, suite, steps=150, seq_len=SEQ)

d_eval = suite.dataset(TASK, 512, 512, SEQ, split_seed=9)
ev = EvalTask(TASK, suite.tasks[TASK].num_classes, d_eval["x_train"], d_eval["y_train"],
              d_eval["x_test"], d_eval["y_test"])

N_CONTRIB, PER_ITER, ITERS = 4, 800, 4
repo = Repository(body)
heads = {c: E.init_cls_head(cfg, jax.random.PRNGKey(c), suite.tasks[TASK].num_classes)
         for c in range(N_CONTRIB)}
print(f"{N_CONTRIB} hospitals / banks / silos, {PER_ITER} fresh private examples each per round\n")
for it in range(ITERS):
    base = repo.download()
    for c in range(N_CONTRIB):
        d = suite.dataset(TASK, PER_ITER, 8, SEQ, split_seed=1000 + it * 10 + c)
        b, h, _ = FT.finetune(cfg, base, heads[c], d["x_train"], d["y_train"],
                              steps=25, lr=2e-3, seed=it * 10 + c)
        heads[c] = h
        repo.upload(b)
    repo.fuse_pending()
    acc = np.mean(list(evaluate_base_model(cfg, repo.download(), [ev], frozen=True,
                                           steps=50, lr=2e-3).values()))
    print(f"round {it+1}: fused-model linear-probe accuracy = {acc:.3f}")
print("\nNo raw example ever left a silo; only weights moved (paper §2.3).")
