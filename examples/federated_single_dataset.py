"""Federated-learning flavour of ColD Fusion (paper §6, Fig. 6a).

Demonstrates the single-dataset collaborative setting: several contributors
("hospitals / banks / silos") hold disjoint shards of ONE dataset, fresh
private examples stream in every round, each silo finetunes the shared base
locally, and only weights travel to the Repository — the fused model's
linear-probe accuracy keeps improving while no raw example ever leaves a
silo (the paper's §2.3 constraint).

  PYTHONPATH=src python examples/federated_single_dataset.py [--dry-run]

``--dry-run`` shrinks rounds/steps/data so the script finishes in seconds —
scripts/ci.sh runs it on every CI pass so this example cannot silently rot.
"""
import argparse
import dataclasses
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs.roberta_base import TINY
from repro.core import EvalTask, Repository, evaluate_base_model
from repro.data.synthetic import SyntheticSuite
from repro.models import encoder as E
from repro.train import finetune as FT
from repro.train.pretrain import pretrain_mlm
import jax

SEQ = 24
TASK = 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="minimal rounds/steps for a seconds-long smoke run")
    args = ap.parse_args(argv)

    if args.dry_run:
        knobs = dict(pretrain=8, n_contrib=2, per_iter=64, iters=1,
                     ft_steps=3, eval_steps=5, n_eval=96)
    else:
        knobs = dict(pretrain=150, n_contrib=4, per_iter=800, iters=4,
                     ft_steps=25, eval_steps=50, n_eval=512)

    cfg = dataclasses.replace(TINY, d_model=64, num_heads=2, num_kv_heads=2,
                              head_dim=32, d_ff=128, vocab_size=256,
                              max_seq_len=SEQ + 8)
    suite = SyntheticSuite(vocab_size=256, num_tasks=4, seed=0, noise=0.15)
    body, _ = pretrain_mlm(cfg, suite, steps=knobs["pretrain"], seq_len=SEQ)

    d_eval = suite.dataset(TASK, knobs["n_eval"], knobs["n_eval"], SEQ, split_seed=9)
    ev = EvalTask(TASK, suite.tasks[TASK].num_classes,
                  d_eval["x_train"], d_eval["y_train"],
                  d_eval["x_test"], d_eval["y_test"])

    repo = Repository(body)
    heads = {c: E.init_cls_head(cfg, jax.random.PRNGKey(c), suite.tasks[TASK].num_classes)
             for c in range(knobs["n_contrib"])}
    print(f"{knobs['n_contrib']} hospitals / banks / silos, "
          f"{knobs['per_iter']} fresh private examples each per round\n")
    for it in range(knobs["iters"]):
        base = repo.download()
        for c in range(knobs["n_contrib"]):
            d = suite.dataset(TASK, knobs["per_iter"], 8, SEQ,
                              split_seed=1000 + it * 10 + c)
            b, h, _ = FT.finetune(cfg, base, heads[c], d["x_train"], d["y_train"],
                                  steps=knobs["ft_steps"], lr=2e-3, seed=it * 10 + c)
            heads[c] = h
            repo.upload(b)
        repo.fuse_pending()
        acc = np.mean(list(evaluate_base_model(cfg, repo.download(), [ev], frozen=True,
                                               steps=knobs["eval_steps"], lr=2e-3).values()))
        print(f"round {it+1}: fused-model linear-probe accuracy = {acc:.3f}")
    print("\nNo raw example ever left a silo; only weights moved (paper §2.3).")


if __name__ == "__main__":
    main()
