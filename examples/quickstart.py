"""Quickstart: the whole ColD Fusion loop in ~2 minutes on CPU.

Builds the synthetic multitask suite, MLM-pretrains a tiny RoBERTa-style
encoder, runs 3 ColD Fusion iterations with 4 contributors, and shows the
base model improving under linear probing — the paper's Fig. 2 in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs.roberta_base import TINY
from repro.core import Contributor, EvalTask, Repository, evaluate_base_model, run_cold_fusion
from repro.data.synthetic import SyntheticSuite
from repro.train.pretrain import pretrain_mlm

SEQ = 24
cfg = dataclasses.replace(TINY, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
                          d_ff=128, vocab_size=256, max_seq_len=SEQ + 8)
suite = SyntheticSuite(vocab_size=256, num_tasks=12, seed=0, noise=0.15)

print("1) MLM-pretraining the tiny encoder (the 'RoBERTa' of this demo)...")
body, metrics = pretrain_mlm(cfg, suite, steps=150, seq_len=SEQ)
print(f"   mlm loss {metrics['loss'][0]:.2f} -> {metrics['loss'][-1]:.2f}")

print("2) Building 4 contributors with private datasets...")
contribs = []
for tid in range(4):
    d = suite.dataset(tid, 1024, 64, SEQ)
    contribs.append(Contributor(cfg, tid, suite.tasks[tid].num_classes,
                                d["x_train"], d["y_train"], steps=30, lr=2e-3, seed=tid))

d0 = suite.dataset(0, 512, 256, SEQ)
ev = [EvalTask(0, suite.tasks[0].num_classes, d0["x_train"], d0["y_train"],
               d0["x_test"], d0["y_test"])]
before = np.mean(list(evaluate_base_model(cfg, body, ev, frozen=True, steps=40, lr=2e-3).values()))
print(f"   pretrained linear-probe accuracy on task 0: {before:.3f}")

print("3) Running 3 ColD Fusion iterations (download -> finetune -> upload -> fuse)...")
repo = Repository(body)
log = run_cold_fusion(cfg, repo, contribs, iterations=3, eval_seen=ev,
                      eval_every=1, eval_steps=40, eval_lr=2e-3, progress=True)
for i, acc in enumerate(log.mean("seen_frozen")):
    print(f"   after iter {i+1}: linear-probe acc = {acc:.3f}")
print(f"\nColD Fusion improved the base model: {before:.3f} -> {log.mean('seen_frozen')[-1]:.3f}")
