#!/usr/bin/env bash
# Tier-1 CI: the full test suite plus the kernel micro-bench in smoke mode.
#
#   scripts/ci.sh
#
# pytest exits non-zero on COLLECTION errors as well as failures (exit code
# 2), and `set -e` propagates both — a module that fails to import cannot
# slip through as "0 tests ran".
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -q

# kernel + end-to-end fuse micro-benches (smoke scale); refreshes
# BENCH_kernels.json so the perf trajectory stays current
REPRO_BENCH_SCALE=quick python -m benchmarks.run --only kernels,fuse_e2e
