#!/usr/bin/env bash
# Tier-1 CI: the full test suite, docs consistency, a multi-device smoke of
# the sharded fusion engine, the kernel micro-bench in smoke mode, and the
# examples in --dry-run mode.
#
#   scripts/ci.sh
#
# pytest exits non-zero on COLLECTION errors as well as failures (exit code
# 2), and `set -e` propagates both — a module that fails to import cannot
# slip through as "0 tests ran".
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# opt-in host-throughput tuning (ROADMAP "Host-throughput tuning"):
# REPRO_HOST_TUNING=1 preloads tcmalloc for every stage below when the
# library is installed (existence-gated — containers without it run
# identically), and benchmarks/serve_load.py additionally sweeps
# --xla_force_host_platform_device_count, recording the winning setting
# in its bench row notes.
if [[ "${REPRO_HOST_TUNING:-}" == "1" ]]; then
    eval "$(python -m repro.launch.host_tuning)"
    echo "[ci] REPRO_HOST_TUNING=1: LD_PRELOAD=${LD_PRELOAD:-<tcmalloc absent>}"
fi

python -m pytest -q

# docs suite: every docs/*.md reachable from README, no dead relative
# links, fenced python blocks import-check against src/
python scripts/check_docs.py

# multi-device smoke: the sharded-fuse + novelty-sketch + delta-codec
# tests on a real (fake-)8-device mesh — under plain pytest above they ran
# on the single CPU device.  The sketch tests pin the sharded one-psum
# sketch (the novelty screen's distributed path) against the single-device
# oracle; the codec tests pin the sharded decode+accumulate fuse the same
# way (one psum, no all-gather).  The slow subprocess test forces its own
# 8 devices and already ran above: skip it.
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest tests/test_sharded_fuse.py tests/test_sketch.py \
    tests/test_delta_codec.py -q -m "not slow"

# crash-recovery under the forced 8-fake-device config: kill-and-reopen
# spill recovery (per-shard placement, manifest validation) with the mesh
# tests running on a REAL 8-device mesh rather than the single CPU device.
# Includes the slow sharded kill-and-reopen subprocess test — it IS this
# stage's point (its children force their own 8 devices).
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest tests/test_repository.py tests/test_sharded_fuse.py \
    -q -k "crash or recover"

# service-loop stage: the contributor service loop end-to-end — the demo
# driver (fusion daemon + 2 contributor subprocesses x 3 fusion rounds +
# 1 replaying shadow contributor, daemon on a forced 8-fake-device mesh
# with the novelty screen armed: planted near-duplicates must be rejected
# at the queue boundary while every distinct contribution fuses) plus the
# kill-at-checkpoint fault-injection suite (slow marker: exactly-once
# fusion across every parametrized crash window incl. the sketch-persist
# window, docs/service_loop.md)
python examples/cold_service_demo.py --contributors 2 --rounds 3 --mesh 8 \
    --duplicates 1
# ... and the delta-compressed round: contributors enqueue top-k int8
# payloads against their downloaded base; the sharded daemon decodes
# inside the fused kernel and the same closed form must come out
python examples/cold_service_demo.py --contributors 2 --rounds 3 --mesh 8 \
    --compress
# ... and the similarity-routed round (docs/service_loop.md): two
# dissimilar contributor streams against one daemon with --max-bases 3 —
# the family must separate into exactly two members (each matching its
# own stream's closed form, never the blend) and cross-fuse to the mean
python examples/cold_service_demo.py --contributors 2 --rounds 3 --mesh 8 \
    --tasks 2 --max-bases 3
python -m pytest tests/test_cold_service.py -q -m slow
# routing crash matrix + gate-isolation matrix + the 20-consecutive-run
# duplicates-demo soak (the novelty-count race regression test — runs
# WITHOUT retries by design: one flaky exit fails the stage)
python -m pytest tests/test_routing.py -q -m slow

# regression-gate stage: the forgetting gate end-to-end on the same forced
# 8-fake-device mesh — a planted saboteur's harmful cohort must publish,
# trip the post-publish task probes, roll the base back on disk, and land
# in <root>/quarantine/ while the benign closed form survives
# (docs/observability.md).  The gate fault matrix (kill -9 inside
# probe -> quarantine -> rollback) runs with the slow suite above.
python examples/cold_service_demo.py --contributors 2 --rounds 3 --mesh 8 \
    --regress 1

# fuse-to-serve stage (docs/serving.md): the hot-swap load harness at
# demo scale on a forced 8-fake-device mesh — concurrent inference +
# contribution traffic against one repository; zero failed or
# version-torn requests across >=3 live swaps is the bar — plus the
# swap-seam kill -9 crash matrix (slow marker: a worker restarted from
# any of the 3 kill windows must serve a published, uncorrupted base)
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m benchmarks.serve_load --rounds 4 --clients 2 --mesh 8
python -m pytest tests/test_hot_swap.py -q -m slow

# serving scale-out stage (docs/serving.md): 2 worker PROCESSES behind
# the least-loaded router with the batched scheduler coalescing client
# requests, daemon on the forced 8-fake-device mesh — swaps land
# mid-load and every routed response is closed-form verified against
# its pinned iteration's on-disk base at the executed batch shape
# (zero failed, zero torn), then the run's metrics.jsonl is charted
# (latency / swap / load series) so the plotting path cannot rot.
# The pool kill -9 matrix (worker death mid-swap, router converging to
# zero failed requests) runs with the slow suite.
SCALE_ROOT=$(mktemp -d)
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m benchmarks.serve_load --workers 2 --batch --clients 4 \
    --rounds 3 --measure 2 --root "$SCALE_ROOT"
python scripts/plot_metrics.py "$SCALE_ROOT" --out "$SCALE_ROOT/metrics.png"
test -s "$SCALE_ROOT/metrics.png"
rm -rf "$SCALE_ROOT"
python -m pytest tests/test_worker_pool.py -q -m slow

# kernel + end-to-end fuse micro-benches (smoke scale); refreshes
# BENCH_kernels.json (including the fuse_e2e/mesh8_sharded,
# fuse_e2e/async_overlap, service_loop/throughput,
# service_loop/delta_compression, service_loop/routed_fusion, and
# serve_load/hot_swap rows — the delta row asserts >=5x queue-bytes
# reduction and codec parity, the routed row asserts single-base fuse
# parity AND two-stream separation, the hot-swap row asserts zero
# failed/torn requests across >=3 live swaps, before posting) so the
# perf trajectory stays current
REPRO_BENCH_SCALE=quick python -m benchmarks.run --only kernels,fuse_e2e,service_loop,serve_load

# examples cannot silently rot: both must run end-to-end at dry-run scale
python examples/cold_fusion_multitask.py --dry-run
python examples/federated_single_dataset.py --dry-run
