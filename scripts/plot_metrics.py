#!/usr/bin/env python
"""Chart the service's ``metrics.jsonl`` time series (run by scripts/ci.sh).

Reads the append-only metrics series a repository root accumulates
(``repro.serve.cold_service._emit_metrics`` plus the serving workers'
swap records; the rotation slot ``metrics.jsonl.1`` is merged in) and
renders one PNG with three aligned panels over wall-clock time:

1. **latency** — per-swap ``swap_latency_s`` (one marker per hot-swap,
   colored per worker) and the daemon's ``fuse_latency_s`` cycle series,
   on a log axis (fuses are orders of magnitude slower than flips);
2. **iterations** — the published iteration (cycle events) as a step
   line, each worker's adopted iteration (swap events) as steps on top,
   rollbacks flagged with a marker: divergence between the lines is
   exactly the adoption lag the router drains around;
3. **load** — queue depth and admitted-per-cycle from the cycle series.

Usage::

    python scripts/plot_metrics.py <root-or-metrics.jsonl> [--out m.png]

Exit code 0 = chart written; 1 = no metrics found (an empty series in CI
means the stage that should have produced it silently did nothing).
"""
from __future__ import annotations

import argparse
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

import matplotlib  # noqa: E402

matplotlib.use("Agg")   # headless: CI has no display
import matplotlib.pyplot as plt  # noqa: E402

from repro.checkpoint import io as ckpt  # noqa: E402


def load_series(path: str) -> list:
    """The retained series in time order (rotated slot merged, torn tail
    skipped silently — a mid-append reader must not fail the plot)."""
    if os.path.isdir(path):
        path = os.path.join(path, "metrics.jsonl")
    return ckpt.read_jsonl(path, warn=False, include_rotated=True)


def plot(records: list, out: str) -> dict:
    """Render the three panels; returns the per-event counts plotted."""
    t0 = min(r["t"] for r in records if "t" in r)
    by_event: dict = {}
    for r in records:
        if "t" in r:
            by_event.setdefault(r.get("event", "?"), []).append(r)
    cycles = by_event.get("cycle", [])
    swaps = by_event.get("swap", [])
    rollbacks = by_event.get("rollback", [])

    fig, (ax_lat, ax_it, ax_load) = plt.subplots(
        3, 1, figsize=(9, 8), sharex=True, constrained_layout=True)
    fig.suptitle("ColD Fusion service metrics", fontsize=12)

    # -- panel 1: latencies (log scale: fuse >> swap) -------------------
    workers = sorted({s.get("worker", "worker") for s in swaps})
    for w in workers:
        pts = [(s["t"] - t0, s["swap_latency_s"] * 1e3) for s in swaps
               if s.get("worker", "worker") == w and "swap_latency_s" in s]
        if pts:
            ax_lat.plot(*zip(*pts), marker="o", ms=4, lw=1.0,
                        label=f"swap {w}")
    fuse = [(c["t"] - t0, c["fuse_latency_s"] * 1e3) for c in cycles
            if c.get("fuse_latency_s")]
    if fuse:
        ax_lat.plot(*zip(*fuse), color="0.3", lw=1.2, label="fuse")
    ax_lat.set_yscale("log")
    ax_lat.set_ylabel("latency (ms)")
    if swaps or fuse:
        ax_lat.legend(loc="upper right", fontsize=8, ncols=2)

    # -- panel 2: published vs adopted iteration ------------------------
    pub = [(c["t"] - t0, c["iteration"]) for c in cycles
           if c.get("iteration") is not None]
    if pub:
        ax_it.step(*zip(*pub), where="post", color="0.3", lw=1.8,
                   label="published")
    for w in workers:
        pts = [(s["t"] - t0, s["to_iteration"]) for s in swaps
               if s.get("worker", "worker") == w and "to_iteration" in s]
        if pts:
            ax_it.step(*zip(*pts), where="post", lw=1.0,
                       label=f"adopted {w}")
    for r in rollbacks:
        ax_it.plot(r["t"] - t0, r["to_iteration"], marker="v", ms=8,
                   color="tab:red", ls="none",
                   label="rollback" if r is rollbacks[0] else None)
    ax_it.set_ylabel("iteration")
    if pub or swaps:
        ax_it.legend(loc="upper left", fontsize=8, ncols=2)

    # -- panel 3: daemon load -------------------------------------------
    depth = [(c["t"] - t0, c.get("queue_depth", 0)) for c in cycles]
    if depth:
        ax_load.step(*zip(*depth), where="post", lw=1.2,
                     label="queue depth")
        adm = [(c["t"] - t0, c.get("admitted_this_cycle", 0))
               for c in cycles]
        ax_load.step(*zip(*adm), where="post", lw=1.0, color="tab:green",
                     label="admitted/cycle")
        ax_load.legend(loc="upper right", fontsize=8)
    ax_load.set_ylabel("count")
    ax_load.set_xlabel("seconds since first record")

    fig.savefig(out, dpi=110)
    plt.close(fig)
    return {k: len(v) for k, v in sorted(by_event.items())}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="chart a repository root's metrics.jsonl")
    p.add_argument("path", help="repository root or metrics.jsonl path")
    p.add_argument("--out", default="metrics.png",
                   help="output PNG (default: metrics.png)")
    args = p.parse_args(argv)
    records = load_series(args.path)
    if not records:
        print(f"plot_metrics: no records under {args.path}",
              file=sys.stderr)
        return 1
    counts = plot(records, args.out)
    print(f"plot_metrics: wrote {args.out} "
          f"({sum(counts.values())} records: "
          + ", ".join(f"{k}={v}" for k, v in counts.items()) + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
