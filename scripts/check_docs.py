#!/usr/bin/env python
"""Docs consistency check (run by scripts/ci.sh).

Three rules keep the docs suite from rotting:

1. **Reachability** — every ``docs/*.md`` file is linked from README.md
   (the repo's entry point), so no page can silently fall off the map.
2. **No dead relative links** — every relative markdown link in README.md
   and ``docs/*.md`` resolves to an existing file (anchors are stripped;
   http(s) links are not checked).
3. **Code blocks import-check** — every fenced ```` ```python ```` block in
   README.md and ``docs/*.md`` must parse, and every ``import repro.x`` /
   ``from repro.x import y`` statement in it must resolve against ``src/``
   (module importable, attribute present).  Blocks are NOT executed —
   pseudo-code belongs in untagged fences.
4. **Documented signatures are live** — every inline code span of the form
   ``` `repro.some.module.fn(arg, kw=...)` ``` (a fully-qualified dotted
   path under ``repro``, optionally through a class, followed by an
   argument list) is resolved and each named argument is verified against
   ``inspect.signature`` of the real callable.  A doc that still shows
   ``fuse_pending()`` after the code grew ``fuse_pending(buffer=, wait=)``
   — or that documents a parameter the code no longer has — fails the
   check instead of silently drifting.  ``...`` in the argument list
   elides the rest; ``*``/``**`` markers are ignored.

Exit code 0 = clean; 1 = problems (all listed on stderr).
"""
from __future__ import annotations

import ast
import importlib
import inspect
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")

problems: list[str] = []


def _read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


def check_reachability(readme: str) -> None:
    docs_dir = os.path.join(ROOT, "docs")
    for name in sorted(os.listdir(docs_dir)):
        if name.endswith(".md") and f"docs/{name}" not in readme:
            problems.append(f"README.md does not reference docs/{name}")


def check_links(path: str, text: str) -> None:
    base = os.path.dirname(path)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            problems.append(f"{os.path.relpath(path, ROOT)}: dead link -> {target}")


def python_blocks(text: str):
    lines = text.splitlines()
    block: list[str] | None = None
    lang = None
    start = 0
    for i, line in enumerate(lines, 1):
        m = FENCE_RE.match(line)
        if m and block is None:
            lang, block, start = m.group(1), [], i
        elif line.strip() == "```" and block is not None:
            if lang == "python":
                yield start, "\n".join(block)
            block = None
        elif block is not None:
            block.append(line)


def check_code_blocks(path: str, text: str) -> None:
    rel = os.path.relpath(path, ROOT)
    for lineno, code in python_blocks(text):
        try:
            tree = ast.parse(code)
        except SyntaxError as e:
            problems.append(f"{rel}:{lineno}: python block does not parse: {e}")
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    _check_module(rel, lineno, alias.name)
            elif isinstance(node, ast.ImportFrom) and node.module:
                mod = _check_module(rel, lineno, node.module)
                if mod is None:
                    continue
                for alias in node.names:
                    if alias.name != "*" and not hasattr(mod, alias.name):
                        try:
                            importlib.import_module(f"{node.module}.{alias.name}")
                        except ImportError:
                            problems.append(
                                f"{rel}:{lineno}: `from {node.module} import "
                                f"{alias.name}` does not resolve")


def _check_module(rel: str, lineno: int, name: str):
    if not name.split(".")[0] == "repro":
        return None  # only our own modules are checked (jax etc. assumed)
    try:
        return importlib.import_module(name)
    except ImportError as e:
        problems.append(f"{rel}:{lineno}: cannot import {name}: {e}")
        return None


# -- documented call signatures (rule 4) ------------------------------------

SIG_RE = re.compile(r"`(repro(?:\.\w+)+)\(([^`]*)\)`")


def _resolve_dotted(dotted: str):
    """Import the longest importable module prefix, then getattr the rest
    (classes, methods, nested attributes).  Returns None when unresolvable."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return None
        return obj
    return None


def _documented_params(arglist: str):
    """Parameter names mentioned in a documented argument list.  Splits on
    top-level commas; ``name=...`` yields ``name``; bare ``...``/``*``/``**``
    markers are elided (they claim nothing checkable)."""
    names, depth, tok = [], 0, []
    for ch in arglist + ",":
        if ch == "," and depth == 0:
            t = "".join(tok).strip()
            tok = []
            if not t or t == "...":
                continue
            t = t.split("=", 1)[0].strip().lstrip("*").strip()
            if t and t != "...":
                names.append(t)
            continue
        depth += ch in "([{"
        depth -= ch in ")]}"
        tok.append(ch)
    return names


def check_signatures(path: str, text: str) -> None:
    # scanned over the whole text, not per line: markdown wraps long spans
    # across lines and a wrapped span must not silently escape the check
    rel = os.path.relpath(path, ROOT)
    for m in SIG_RE.finditer(text):
        dotted, arglist = m.group(1), " ".join(m.group(2).split())
        lineno = text.count("\n", 0, m.start()) + 1
        obj = _resolve_dotted(dotted)
        if obj is None:
            problems.append(
                f"{rel}:{lineno}: documented signature `{dotted}(...)` "
                "does not resolve")
            continue
        if isinstance(obj, type):
            obj = obj.__init__
        try:
            sig = inspect.signature(obj)
        except (TypeError, ValueError):
            continue  # builtins without introspectable signatures
        params = set(sig.parameters) - {"self", "cls"}
        has_var_kw = any(
            p.kind is inspect.Parameter.VAR_KEYWORD
            for p in sig.parameters.values())
        for name in _documented_params(arglist):
            if name not in params and not has_var_kw:
                problems.append(
                    f"{rel}:{lineno}: `{dotted}` has no parameter "
                    f"{name!r} (stale documented signature; actual: {sig})")


def main() -> int:
    readme_path = os.path.join(ROOT, "README.md")
    readme = _read(readme_path)
    check_reachability(readme)
    pages = [readme_path] + [
        os.path.join(ROOT, "docs", n)
        for n in sorted(os.listdir(os.path.join(ROOT, "docs")))
        if n.endswith(".md")
    ]
    for path in pages:
        text = _read(path)
        check_links(path, text)
        check_code_blocks(path, text)
        check_signatures(path, text)
    if problems:
        for p in problems:
            print(f"DOCS: {p}", file=sys.stderr)
        print(f"docs check FAILED ({len(problems)} problem(s))", file=sys.stderr)
        return 1
    print(f"docs check OK ({len(pages)} pages)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
