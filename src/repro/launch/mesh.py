"""Device meshes.

``make_production_mesh`` is the assignment-mandated mesh: one v5e pod is a
16x16 ("data", "model") grid; the multi-pod variant prepends a "pod" axis
(2 pods = 512 chips).  Defined as functions so importing this module never
touches jax device state (the dry-run sets the fake device count first).

``make_cold_mesh`` is the ColD Fusion training mesh: the data parallelism is
factored into ("contrib", "replica") — each contributor owns a
(replica x model) slab, local steps all-reduce only over "replica"(+"model"),
and the fusion collective is the only traffic crossing "contrib"/"pod".
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cold_mesh(*, contributors: int = 8, replicas: int = 2, model: int = 16,
                   multi_pod: bool = False):
    """ColD mesh: (pod?) x contrib x replica x model.

    contributors*replicas must equal the pod's data extent (16 on the
    production pod) so chip counts match the production mesh.
    """
    if contributors * replicas * model not in (256, jax.device_count(), 512 // (2 if multi_pod else 1)):
        # permissive: tests use small fake meshes
        pass
    shape = (contributors, replicas, model)
    axes = ("contrib", "replica", "model")
    if multi_pod:
        shape = (2,) + shape
        axes = ("pod",) + axes
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """All batch-parallel axes present in a mesh (pod + data/contrib+replica)."""
    names = mesh.axis_names
    out = tuple(a for a in ("pod", "data", "contrib", "replica") if a in names)
    return out
