"""Opt-in host-throughput tuning for CPU serving/fusion processes.

Two host-level knobs move serving throughput without touching model
code (ROADMAP "Host-throughput tuning"; the recipe follows the
published JAX-on-CPU serving setups):

* **tcmalloc** — glibc malloc serializes the large short-lived
  allocations a serving host makes (activation buffers, codec
  scratch); preloading tcmalloc when it is installed removes that
  contention.  ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` is raised so
  steady-state large allocations don't spam stderr.
* **--xla_force_host_platform_device_count=N** — splits the host CPU
  into N XLA devices.  More devices can help a multi-worker serving
  host (each worker's streams stop contending for one device's
  executor) or hurt (oversubscription on few cores) — which is why
  ``benchmarks/serve_load.py`` *sweeps* it rather than hardcoding, and
  records the best setting in the bench row notes.

Everything here is opt-in behind ``REPRO_HOST_TUNING=1`` and degrades
to a no-op when the library is absent — CI containers without tcmalloc
run identically to before.

``LD_PRELOAD`` and ``XLA_FLAGS`` only act at process start (the loader
and jax import read them once), so the helpers produce *environments
for child processes* (``host_tuning_env``); ``maybe_reexec`` applies
them to the CURRENT process by re-execing once when tuning is enabled
and something would actually change.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Optional

ENV_FLAG = "REPRO_HOST_TUNING"
_APPLIED_MARKER = "REPRO_HOST_TUNING_APPLIED"

# well-known install paths, most specific first (SNIPPETS.md recipe)
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)
LARGE_ALLOC_THRESHOLD = "60000000000"


def enabled(environ: Optional[Dict[str, str]] = None) -> bool:
    env = os.environ if environ is None else environ
    return env.get(ENV_FLAG, "") == "1"


def tcmalloc_path() -> Optional[str]:
    """The installed tcmalloc shared object, or None (gate, don't fail:
    the container may not ship it)."""
    for path in TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def host_tuning_env(*, device_count: Optional[int] = None
                    ) -> Dict[str, str]:
    """Environment overrides for a child process: tcmalloc preload when
    present, plus an optional forced host device count.  Returns {} when
    there is nothing to apply — callers can pass it straight to a
    subprocess env unconditionally."""
    env: Dict[str, str] = {}
    lib = tcmalloc_path()
    if lib is not None:
        prior = os.environ.get("LD_PRELOAD", "")
        if lib not in prior.split(":"):
            env["LD_PRELOAD"] = f"{prior}:{lib}".strip(":")
        env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = LARGE_ALLOC_THRESHOLD
    if device_count is not None:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={int(device_count)}")
    return env


def maybe_reexec() -> None:
    """Apply the tuning to the CURRENT process (``REPRO_HOST_TUNING=1``
    only) by re-execing argv once with the updated environment.  Must be
    called before jax import; the applied-marker guarantees exactly one
    re-exec.  No-op when tuning is off or nothing would change."""
    if not enabled() or os.environ.get(_APPLIED_MARKER) == "1":
        return
    env = host_tuning_env()
    if not env:
        return
    os.environ.update(env)
    os.environ[_APPLIED_MARKER] = "1"
    sys.stdout.flush()
    sys.stderr.flush()
    os.execve(sys.executable, [sys.executable] + sys.argv, os.environ)


def _main(argv=None) -> int:
    """Print ``export KEY=VALUE`` lines for the tuning environment —
    ``scripts/ci.sh`` evals this so its serving stages honor
    ``REPRO_HOST_TUNING=1`` without duplicating the tcmalloc candidate
    list in shell.  Prints nothing (exit 0) when tuning is off or there
    is nothing to apply."""
    import argparse
    import shlex

    p = argparse.ArgumentParser(
        description="emit shell exports for the opt-in host tuning")
    p.add_argument("--device-count", type=int, default=None,
                   help="also force this XLA host device count")
    p.add_argument("--force", action="store_true",
                   help="emit even when REPRO_HOST_TUNING is unset")
    args = p.parse_args(argv)
    if not (enabled() or args.force):
        return 0
    for key, val in host_tuning_env(device_count=args.device_count).items():
        print(f"export {key}={shlex.quote(val)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
