"""End-to-end LM training driver.

Trains any registered architecture (full or ``--reduced``) on the synthetic
token stream with the real train_step (remat, microbatching, optimizer from
the config).  On a multi-device runtime it builds the production mesh and
shards via `repro.launch.sharding`; on this CPU container it runs
single-device (the multi-device path is exercised by dryrun.py and the
subprocess tests).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
      --steps 200 --batch 8 --seq 64
  # the ~100M-parameter end-to-end run (paper-scale model, CPU-hours):
  PYTHONPATH=src python -m repro.launch.train --arch roberta-base --steps 300
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs import ARCH_IDS, get_config, reduce_config
from repro.data.synthetic import SyntheticSuite
from repro.models import whisper as W
from repro.models.transformer import init_lm
from repro.optim.optimizers import make_optimizer, warmup_cosine_lr
from repro.train.step import make_train_state, make_train_step


def build_params(cfg, key):
    if cfg.is_encoder_decoder:
        return W.init_whisper(cfg, key, max_target_len=cfg.max_seq_len)
    return init_lm(cfg, key)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list(ARCH_IDS), default="gemma3-1b")
    p.add_argument("--reduced", action="store_true",
                   help="train the smoke-scale variant (CPU-friendly)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--save", default=None, help="checkpoint path (.npz)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.arch == "roberta-base":
        # decoder-style training of the encoder config: reuse the LM stack
        cfg = dataclasses.replace(cfg, rope=dataclasses.replace(cfg.rope, kind="default"))
    cfg = dataclasses.replace(cfg, param_dtype="float32", compute_dtype="float32",
                              remat=False, max_seq_len=max(cfg.max_seq_len, args.seq))

    key = jax.random.PRNGKey(args.seed)
    print(f"[train] {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps x batch {args.batch} x seq {args.seq}")
    params = build_params(cfg, key)
    opt = make_optimizer(cfg.optimizer, warmup_cosine_lr(args.lr, warmup=20, total=args.steps))
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches))

    suite = SyntheticSuite(vocab_size=min(cfg.vocab_size, 512), num_tasks=8, seed=args.seed)
    stream = suite.lm_stream(args.steps * args.batch, args.seq, seed=args.seed)
    stream = np.clip(stream, 0, cfg.vocab_size - 1)

    t0 = time.time()
    for i in range(args.steps):
        toks = jnp.asarray(stream[i * args.batch : (i + 1) * args.batch])
        batch = {"tokens": toks}
        if cfg.rope.kind == "mrope":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None], (3, args.batch, args.seq))
        if cfg.family == "vlm" and cfg.num_frontend_tokens:
            batch["extra_embeds"] = jnp.zeros(
                (args.batch, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        state, m = step(state, batch)
        if (i + 1) % args.log_every == 0 or i == 0:
            dt = (time.time() - t0) / (i + 1)
            print(f"  step {i+1:4d}: loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} ({dt*1e3:.0f} ms/step)")
    print(f"[train] done in {time.time()-t0:.0f}s; final loss {float(m['loss']):.4f}")
    if args.save:
        ckpt.save(args.save, state["params"])
        print(f"[train] saved params to {args.save}")


if __name__ == "__main__":
    main()
