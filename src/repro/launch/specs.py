"""Abstract input/state specs for dry-run lowering (ShapeDtypeStruct only —
never allocates).

``input_specs(cfg, shape)`` follows the assignment contract: for training
steps {tokens, ...}; for serving the request batch (+ KV/state cache).  The
modality stubs surface here: whisper gets precomputed frame embeddings,
qwen2-vl gets patch embeddings + 3-stream M-RoPE position ids.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import whisper as W
from repro.models.transformer import init_cache, init_lm
from repro.optim.optimizers import Optimizer


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """Model-input stand-ins for one step of the given input shape.

    train/prefill: the full [B, S] token batch (+ modality extras).
    decode: one new token per sequence: tokens [B, 1] (+ cache_index).
    """
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.is_decode:
        batch: Dict[str, Any] = {"tokens": _sds((B, 1), jnp.int32)}
        return batch
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.rope.kind == "mrope":
        batch["positions"] = _sds((3, B, S), jnp.int32)
    if cfg.family == "vlm" and cfg.num_frontend_tokens:
        batch["extra_embeds"] = _sds((B, cfg.num_frontend_tokens, cfg.d_model), cdt)
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), cdt)
    return batch


def abstract_params(cfg: ArchConfig):
    if cfg.is_encoder_decoder:
        return jax.eval_shape(lambda: W.init_whisper(cfg, jax.random.PRNGKey(0)))
    return jax.eval_shape(lambda: init_lm(cfg, jax.random.PRNGKey(0)))


def abstract_state(cfg: ArchConfig, optimizer: Optimizer):
    params = abstract_params(cfg)
    opt = jax.eval_shape(optimizer.init, params)
    return {"params": params, "opt": opt}


def abstract_cache(cfg: ArchConfig, shape: InputShape):
    """Decode-state stand-in: KV/state cache of length seq_len."""
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        return jax.eval_shape(lambda: W.init_whisper_cache(cfg, B, S, cdt))
    return jax.eval_shape(lambda: init_cache(cfg, B, S, cdt))


def auto_microbatches(cfg: ArchConfig, shape: InputShape, dp_size: int) -> int:
    """Gradient-accumulation factor: drive per-device microbatch to ~1
    sequence for the big-activation training shape."""
    if shape.kind != "train":
        return 1
    if cfg.microbatches:
        return cfg.microbatches
    per_dp = shape.global_batch // max(dp_size, 1)
    return max(1, min(16, per_dp))
