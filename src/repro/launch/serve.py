"""Batched serving driver: loads (or random-inits) a model, prefills a batch
of synthetic prompts, and greedy-decodes with the KV-cache engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 12 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs import ARCH_IDS, get_config, reduce_config
from repro.models.transformer import init_lm
from repro.serve.engine import Engine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list(ARCH_IDS), default="gemma3-1b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--load", default=None, help="params checkpoint (.npz)")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.is_encoder_decoder:
        raise SystemExit("use whisper_decode directly for enc-dec archs")
    key = jax.random.PRNGKey(args.seed)
    params = ckpt.load(args.load) if args.load else init_lm(cfg, key)
    max_len = args.prompt_len + args.new_tokens + 1
    eng = Engine(cfg, params, max_len=max_len)
    prompts = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt_len), 3, cfg.vocab_size)
    )
    t0 = time.time()
    res = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: {args.batch} requests x {args.new_tokens} tokens "
          f"in {dt:.2f}s ({args.batch*args.new_tokens/dt:.1f} tok/s)")
    for i, row in enumerate(res.tokens):
        print(f"  req{i}: {row[: res.prompt_len].tolist()} -> {row[res.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
