"""Sharding rules: map every parameter / activation / cache leaf to a
PartitionSpec on the production mesh.

Strategy summary (Megatron-style TP over ``model`` + optional FSDP over
``data``; the ColD strategy prepends a contributor axis — see
`repro.core.distributed`):

* attention/FFN matrices: input dim on ``fsdp``, output dim on ``model``
  (transposed for the output projections) — activations stay batch-sharded
  between layers, collectives stay inside layers.
* MoE expert stacks [E, ...]: expert dim on ``model`` (expert parallelism);
  GSPMD inserts the dispatch/combine all-to-alls implied by the einsums.
* Mamba/RWKV channel-parallel leaves: the inner channel dim on ``model``
  (their recurrences are elementwise across channels/heads).
* KV caches: batch on ``data``, kv-heads on ``model`` (falling back to
  head_dim, then sequence, whenever a dim isn't divisible — e.g. MQA kv=1,
  or batch=1 in long_500k where the *sequence* gets context-parallel
  sharded instead).

Every rule is divisibility-checked against the actual mesh axis sizes; a
non-divisible dim falls back to replication rather than failing to lower.
"""
from __future__ import annotations

import re

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.utils.pytree import tree_map_with_name

Axis = Optional[object]  # str | tuple[str, ...] | None

# §Perf lever flags (see EXPERIMENTS.md §Perf); off by default so baseline
# artifacts stay reproducible.
import os
OPT_MOE_SHARD = os.environ.get("REPRO_OPT_MOE_SHARD", "0") == "1"


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape: Tuple[int, ...], want: Sequence[Axis]) -> P:
    """Drop any axis whose size doesn't divide the corresponding dim."""
    spec = []
    for dim, axis in zip(shape, want):
        if axis is not None and dim % _axis_size(mesh, axis) == 0 and dim > 0:
            spec.append(axis)
        else:
            spec.append(None)
    return P(*spec)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

# (regex over the leaf path, wanted axes for the *trailing* dims of the leaf)
_PARAM_RULES = [
    ("embed$", ("model", "fsdp")),          # [V, D]
    ("lm_head$", ("fsdp", "model")),        # [D, V]
    (r"(^|/)pos$", (None, None)),           # learned positions: replicate
    ("attn/wo", ("model", "fsdp")),
    ("xattn/wo", ("model", "fsdp")),
    ("attn/w", ("fsdp", "model")),          # wq/wk/wv
    ("xattn/w", ("fsdp", "model")),
    ("glu/w_down", ("model", "fsdp")),
    ("glu/w", ("fsdp", "model")),
    ("mlp/w_down", ("model", "fsdp")),
    ("mlp/w_up", ("fsdp", "model")),
    ("moe/router", ("fsdp", None)),
    ("moe/w_down", ("model", None, "fsdp")),  # [E, F, D]
    ("moe/w", ("model", "fsdp", None)),       # [E, D, F]
    ("mamba/in_proj", ("fsdp", "model")),
    ("mamba/conv_w", (None, "model")),
    ("mamba/conv_b", ("model",)),
    ("mamba/x_proj", ("model", None)),
    ("mamba/dt_proj", (None, "model")),
    ("mamba/dt_bias", ("model",)),
    ("mamba/A_log", ("model", None)),
    ("mamba/D", ("model",)),
    ("mamba/out_proj", ("model", "fsdp")),
    ("rwkv/wo", ("model", "fsdp")),
    ("rwkv/w", ("fsdp", "model")),          # wr/wk/wv/wg
    ("rwkv/lora_w/a", ("fsdp", None)),
    ("rwkv/lora_w/b", (None, "model")),
    ("rwkv/u", ("model", None)),            # [H, hd]
    ("rwkv/w0", ("model",)),
    ("rwkv/ln_", ("model",)),
    ("head/dense", ("fsdp", "model")),
    ("head/out", ("model", None)),
]


def _sub_axes(axis_map, want: Sequence[Axis]) -> Tuple[Axis, ...]:
    return tuple(axis_map.get(a, None) if isinstance(a, str) else a for a in want)


def param_spec(
    mesh: Mesh,
    name: str,
    leaf,
    *,
    data_axis: Axis = "data",
    model_axis: Axis = "model",
    fsdp: bool = False,
    prefix: Tuple[Axis, ...] = (),
) -> P:
    """PartitionSpec for one named parameter leaf.

    ``prefix`` covers leading stacking dims (scan period repeats get None;
    the ColD contributor dim gets the contributor axes).
    """
    axis_map = {"model": model_axis, "fsdp": data_axis if fsdp else None}
    shape = leaf.shape if hasattr(leaf, "shape") else tuple(leaf)
    n_lead = len(prefix)
    body_shape = shape[n_lead:]
    want: Optional[Sequence[Axis]] = None
    for pat, axes in _PARAM_RULES:
        if re.search(pat, name) and len(axes) == len(body_shape):
            want = _sub_axes(axis_map, axes)
            break
    if want is None:
        want = (None,) * len(body_shape)
    # §Perf lever (REPRO_OPT_MOE_SHARD=1): when num_experts doesn't divide
    # the model axis (mixtral: E=8 on a 16-way axis), move tensor parallelism
    # to the per-expert FFN dim instead of dropping it entirely (baseline:
    # mixtral train_4k optimizer state replicated 16x -> 52.6 GiB peak).
    if (OPT_MOE_SHARD and "moe/w" in name and len(body_shape) == 3
            and want and want[0] is not None
            and body_shape[0] % _axis_size(mesh, want[0]) != 0):
        fsdp_ax = _sub_axes(axis_map, ("fsdp",))[0]
        if "w_down" in name:  # [E, F, D]: shard F on model, D on fsdp
            want = (None, want[0], fsdp_ax)
        else:  # w_gate/w_up [E, D, F]: shard D on fsdp, F on model
            want = (None, fsdp_ax, want[0])
    body = list(_fit(mesh, body_shape, want))
    lead = [
        (a if a is not None and shape[i] % _axis_size(mesh, a) == 0 else None)
        for i, a in enumerate(prefix)
    ]
    return P(*(lead + body))


def params_shardings(
    mesh: Mesh,
    params,
    cfg: ArchConfig,
    *,
    data_axis: Axis = "data",
    model_axis: Axis = "model",
    contrib_axes: Tuple[Axis, ...] = (),
):
    """NamedSharding pytree for a params pytree.

    Leaves under ``scan/`` carry a leading period-stack dim (replicated);
    ``contrib_axes`` (ColD) prepends the contributor dim before that.
    """

    def spec(name: str, leaf):
        prefix: Tuple[Axis, ...] = tuple(contrib_axes)
        if "scan/" in name or name.startswith("scan"):
            prefix = prefix + (None,)
        return NamedSharding(
            mesh,
            param_spec(
                mesh, name, leaf,
                data_axis=data_axis, model_axis=model_axis,
                fsdp=cfg.fsdp, prefix=prefix,
            ),
        )

    return tree_map_with_name(spec, params)


# ---------------------------------------------------------------------------
# optimizer state: follow the params rules (m/v/momentum mirror params;
# adafactor's factored vectors replicate their trailing dim heuristically)
# ---------------------------------------------------------------------------


def opt_state_shardings(mesh: Mesh, opt_state, params_sh):
    """m/v mirror the param sharding; scalars & factored stats replicate on
    non-matching shapes."""
    flat_params = {}

    def record(name, sh):
        flat_params[name] = sh
        return sh

    tree_map_with_name(record, params_sh)

    def spec(name: str, leaf):
        # opt state paths look like "m/<param path>" / "v/<...>" / "step"
        for prefix in ("m/", "v/", "mom/", "v/"):
            if name.startswith(prefix):
                pname = name[len(prefix):]
                sh = flat_params.get(pname)
                if sh is not None and len(sh.spec) == leaf.ndim:
                    return sh
        return NamedSharding(mesh, P())

    return tree_map_with_name(spec, opt_state)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------


def batch_shardings(
    mesh: Mesh,
    batch,
    *,
    data_axis: Axis = "data",
    model_axis: Axis = "model",
    contrib_axes: Tuple[Axis, ...] = (),
):
    """tokens/labels [B, S]: batch over data axes; sequence over data if the
    batch doesn't divide (long-context, batch=1).  positions [3, B, S]
    (M-RoPE) and frames/extra_embeds [B, N, D] handled likewise."""

    def spec(name: str, leaf):
        shape = leaf.shape
        lead = tuple(contrib_axes)
        body = shape[len(lead):]
        if name.endswith("positions") and len(body) == 3:
            want = (None, data_axis, None)
        elif len(body) == 3:  # frames / extra_embeds [B, N, D]
            want = (data_axis, None, None)
        elif len(body) == 2:
            B, S = body
            if B % _axis_size(mesh, data_axis) == 0:
                want = (data_axis, None)
            else:
                want = (None, data_axis)
        elif len(body) == 1:
            want = (data_axis,)
        else:
            want = (None,) * len(body)
        fitted = _fit(mesh, body, want)
        return NamedSharding(mesh, P(*(list(lead) + list(fitted))))

    return tree_map_with_name(spec, batch)


def cache_shardings(
    mesh: Mesh,
    cache,
    cfg: ArchConfig,
    *,
    data_axis: Axis = "data",
    model_axis: Axis = "model",
    contrib_axes: Tuple[Axis, ...] = (),
):
    """Decode-state sharding.

    KV k/v [B, S, Hkv, hd]: batch->data, heads->model (fallback hd->model;
    fallback seq->data when batch=1: context-parallel cache).
    Mamba h [B, di, ds] & conv [B, dc-1, di]: channels->model.
    RWKV S [B, H, hd, hd]: heads->model; shifts [B, 1, D]: D->model.
    """

    def spec(name: str, leaf):
        lead: Tuple[Axis, ...] = tuple(contrib_axes)
        if "scan/" in name or name.startswith("scan"):
            lead = lead + (None,)
        shape = leaf.shape[len(lead):]
        dsz = _axis_size(mesh, data_axis)
        msz = _axis_size(mesh, model_axis)
        want: Sequence[Axis]
        leafname = name.rsplit("/", 1)[-1]
        if leafname in ("k", "v", "xk", "xv") and len(shape) == 4:
            B, S, H, hd = shape
            b_ax = data_axis if B % dsz == 0 else None
            if H % msz == 0:
                want = (b_ax, None if b_ax else data_axis, model_axis, None)
            elif hd % msz == 0:
                want = (b_ax, None if b_ax else data_axis, None, model_axis)
            else:
                want = (b_ax, None if b_ax else data_axis, None, None)
        elif leafname == "h" and len(shape) == 3:  # mamba [B, di, ds]
            want = (data_axis if shape[0] % dsz == 0 else None, model_axis, None)
        elif leafname == "conv" and len(shape) == 3:  # [B, dc-1, di]
            want = (data_axis if shape[0] % dsz == 0 else None, None, model_axis)
        elif leafname == "S" and len(shape) == 4:  # rwkv [B, H, hd, hd]
            want = (data_axis if shape[0] % dsz == 0 else None, model_axis, None, None)
        elif leafname in ("shift", "cm_shift") and len(shape) == 3:
            want = (data_axis if shape[0] % dsz == 0 else None, None, model_axis)
        else:
            want = (None,) * len(shape)
        fitted = _fit(mesh, shape, want)
        return NamedSharding(mesh, P(*(list(lead) + list(fitted))))

    return tree_map_with_name(spec, cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Repository staging buffers (block-cyclic flat layout — docs/sharding.md)
# ---------------------------------------------------------------------------


def norm_axes(axes) -> Tuple[str, ...]:
    """Mesh-axis argument normalization: a bare name or any sequence of
    names -> a tuple of names (the canonical form everywhere in the
    sharded-fuse stack)."""
    return (axes,) if isinstance(axes, str) else tuple(axes)


def axes_entry(axes):
    """The PartitionSpec entry for one dim sharded over ``axes`` (a single
    name collapses out of its tuple, matching jax's P conventions)."""
    axes = norm_axes(axes)
    return axes if len(axes) > 1 else axes[0]


def axes_extent(mesh: Mesh, axes) -> int:
    """Product of the mesh extents of ``axes`` — the shard count S of a
    flat buffer laid out over them."""
    return _axis_size(mesh, norm_axes(axes))


def flat_row_sharding(mesh: Mesh, axes) -> NamedSharding:
    """Sharding of one block-cyclic flat row ``[S, shard_len]``: the shard
    dim over ``axes``, the payload replicated-free (each device holds only
    its own contiguous slice)."""
    return NamedSharding(mesh, P(axes_entry(axes), None))


def flat_stage_sharding(mesh: Mesh, axes) -> NamedSharding:
    """Sharding of the stacked staging buffer ``[K, S, shard_len]``: K whole
    rows, each laid out like ``flat_row_sharding`` — no device ever holds
    more than ``K x shard_len`` elements of the cohort."""
    return NamedSharding(mesh, P(None, axes_entry(axes), None))


def stage_row_from_shards(mesh: Mesh, axes, n_shards: int, shard_len: int,
                          read_shard) -> jax.Array:
    """Build one staged ``[S, shard_len]`` row directly from a per-shard
    host reader — the sharded-spill reload path (docs/async_repository.md).

    ``read_shard(i)`` returns shard ``i``'s ``[shard_len]`` host slice;
    ``jax.make_array_from_callback`` asks for exactly the shard ranges each
    addressable device owns, so the host only ever holds the slices of the
    shards being placed — never the full ``[N]`` row."""
    sharding = flat_row_sharding(mesh, axes)

    def cb(index):
        rng = index[0]
        lo = rng.start or 0
        hi = n_shards if rng.stop is None else rng.stop
        return np.stack([np.asarray(read_shard(i)) for i in range(lo, hi)])

    return jax.make_array_from_callback(
        (n_shards, shard_len), sharding, cb)
