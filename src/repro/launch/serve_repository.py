"""Launch the queue-driven fusion daemon over an on-disk repository.

The operator-facing entry point for the contributor service loop
(docs/service_loop.md): opens (or initializes) a spill-enabled Repository
at ``--root``, wraps it in a ``ColdService``, and polls the contribution
queue until stopped — by SIGINT/SIGTERM (clean quiesce: in-flight fuse
finalized, final status published), by ``--max-iterations``, or by
``--idle-timeout`` seconds of empty queue.

  # serve an existing repository (spill restored from repository.json)
  PYTHONPATH=src python -m repro.launch.serve_repository --root repo/

  # initialize from a base checkpoint, fuse cohorts of >=2, stop after 3
  PYTHONPATH=src python -m repro.launch.serve_repository --root repo/ \\
      --init-npz base.npz --min-cohort 2 --max-iterations 3

``--mesh N`` opens the repository on an N-device mesh (the sharded fuse
path); the device count must already be available — under CPU testing,
export ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.

``--serve-arch NAME`` additionally runs a fuse-to-serve hot-swap worker
(docs/serving.md) in the same process: a ``ServingWorker`` subscribed to
the repository's publishes keeps a serving ``Engine`` on the latest
published base (reduced NAME config), persisting ``serving_state.json``
and swap records alongside the daemon's status.  ``--serve-workers N``
scales that out instead: N worker PROCESSES (``serve/worker_pool.py``,
each with its own namespaced ``serving_state-<id>.json``) follow the
repository cross-process; ``--serve-batch`` enables the per-worker
``BatchScheduler``; ``--serve-queue-depth`` bounds each worker's
request queue (overload sheds explicitly instead of collapsing
latency).  ``status()`` aggregates the whole worker namespace.

``REPRO_HOST_TUNING=1`` applies the opt-in host-throughput recipe
(``repro/launch/host_tuning.py``): tcmalloc ``LD_PRELOAD`` when
installed (the daemon re-execs itself once to pick it up, and pool
children inherit it).
"""
from __future__ import annotations

import argparse
import os
import signal
import sys

from repro.launch import host_tuning

# before jax (via the repro imports below) loads: LD_PRELOAD and
# XLA_FLAGS are read once at process/import start
host_tuning.maybe_reexec()

from repro.checkpoint import io as ckpt
from repro.core.repository import Repository, RepositoryFamily
from repro.serve.cold_service import AdmissionPolicy, ColdService
from repro.serve.probes import ProbeSuite, RegressionGate


def build_service(args) -> ColdService:
    mesh = None
    if args.mesh:
        import jax
        if jax.device_count() < args.mesh:
            raise SystemExit(
                f"--mesh {args.mesh} needs {args.mesh} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.mesh})")
        mesh = jax.make_mesh((args.mesh,), ("model",))
    kw = dict(spill=True, spill_workers=args.spill_workers)
    if mesh is not None:
        kw["mesh"] = mesh
    routed = args.max_bases > 1
    family = None
    if routed:
        if os.path.exists(os.path.join(args.root, "repository.json")):
            family = RepositoryFamily.open(args.root, **kw)
        else:
            if not args.init_npz:
                raise SystemExit(f"{args.root} holds no repository.json — "
                                 "pass --init-npz to initialize a new "
                                 "repository")
            base = ckpt.load(args.init_npz)
            family = RepositoryFamily.create(
                base, root=args.root, screen=not args.no_screen,
                fusion_op=args.fusion_op, **kw)
        repo = family.members["main"]
    elif os.path.exists(os.path.join(args.root, "repository.json")):
        repo = Repository.open(args.root, **kw)
    else:
        if not args.init_npz:
            raise SystemExit(f"{args.root} holds no repository.json — pass "
                             "--init-npz to initialize a new repository")
        base = ckpt.load(args.init_npz)
        repo = Repository(base, root=args.root, screen=not args.no_screen,
                          fusion_op=args.fusion_op, **kw)
    policy = AdmissionPolicy(
        min_cohort=args.min_cohort,
        max_cohort=args.max_cohort,
        max_wait_s=args.max_wait,
        max_staleness=args.max_staleness,
        verify_checksums=args.verify_checksums,
        novelty_threshold=args.novelty_threshold,
        sketch_window=args.sketch_window,
        compact_keep_bases=args.compact_keep,
        max_bases=args.max_bases,
        split_threshold=args.split_threshold,
        cross_fuse_every=args.cross_fuse_every,
    )
    gate = None
    if args.gate:
        repo._ensure_flat_base()  # the probe pool is sized to the flat base
        gate = RegressionGate(
            ProbeSuite(repo._spec.size, n_tasks=args.probe_tasks,
                       n_examples=args.probe_examples,
                       seed=args.probe_seed),
            tolerance=args.probe_tolerance)
    if routed:
        return ColdService(family=family, policy=policy, gate=gate)
    return ColdService(repo, policy=policy, gate=gate)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="queue-driven ColD Fusion daemon (docs/service_loop.md)")
    p.add_argument("--root", required=True, help="repository npz root")
    p.add_argument("--init-npz", default=None,
                   help="base checkpoint to initialize a NEW repository from")
    p.add_argument("--fusion-op", default="average")
    p.add_argument("--no-screen", action="store_true",
                   help="disable the §9 MAD screen (new repositories only)")
    p.add_argument("--mesh", type=int, default=0, metavar="N",
                   help="open on an N-device mesh (sharded fuse)")
    p.add_argument("--spill-workers", type=int, default=0)
    p.add_argument("--min-cohort", type=int, default=1)
    p.add_argument("--max-cohort", type=int, default=64)
    p.add_argument("--max-wait", type=float, default=0.0,
                   help="fuse an undersized cohort after this many seconds")
    p.add_argument("--max-staleness", type=int, default=None,
                   help="reject submissions finetuned from a base more than "
                        "this many iterations old")
    p.add_argument("--verify-checksums", action="store_true")
    p.add_argument("--novelty-threshold", type=float, default=None,
                   metavar="D",
                   help="reject submissions whose content sketch sits "
                        "within this relative distance of a recent "
                        "admission (the cohort novelty screen; default off)")
    p.add_argument("--sketch-window", type=int, default=32,
                   help="recent admissions the novelty screen remembers")
    p.add_argument("--compact-keep", type=int, default=None, metavar="M",
                   help="compact after each publish, keeping M bases")
    p.add_argument("--max-bases", type=int, default=1, metavar="B",
                   help="serve a base FAMILY of up to B members, routing "
                        "each submission to its nearest base by sketch "
                        "distance and spawning a new member when nothing "
                        "is near (docs/service_loop.md; default 1 = the "
                        "single-base loop)")
    p.add_argument("--split-threshold", type=float, default=0.8, metavar="D",
                   help="relative sketch distance beyond which a "
                        "submission founds a new family member "
                        "(--max-bases > 1)")
    p.add_argument("--cross-fuse-every", type=int, default=0, metavar="K",
                   help="after every K publishes, fuse the family members "
                        "into each other (inter-cluster merge; 0 = never)")
    p.add_argument("--gate", action="store_true",
                   help="arm the forgetting regression gate: probe every "
                        "publish against the pre-fuse baseline; on a "
                        "regression, roll the base back and quarantine the "
                        "offending cohort (docs/observability.md)")
    p.add_argument("--probe-tasks", type=int, default=4,
                   help="synthetic tasks in the gate's probe suite")
    p.add_argument("--probe-examples", type=int, default=32,
                   help="eval examples per probe task")
    p.add_argument("--probe-tolerance", type=float, default=0.5, metavar="T",
                   help="per-task probe-loss increase that counts as a "
                        "regression")
    p.add_argument("--probe-seed", type=int, default=0,
                   help="seed fixing the probe batches and readouts")
    p.add_argument("--serve-arch", default=None, metavar="NAME",
                   help="also serve the evolving base: run a hot-swap "
                        "ServingWorker for this arch (reduced config; the "
                        "repository base must be that arch's param tree)")
    p.add_argument("--serve-max-len", type=int, default=64,
                   help="serving engine KV-cache length (--serve-arch)")
    p.add_argument("--serve-workers", type=int, default=0, metavar="N",
                   help="scale the serving side out to N worker "
                        "PROCESSES behind namespaced state files "
                        "(requires --serve-arch; 0 = one in-process "
                        "worker)")
    p.add_argument("--serve-batch", action="store_true",
                   help="coalesce compatible requests per worker via "
                        "the BatchScheduler (--serve-workers)")
    p.add_argument("--serve-queue-depth", type=int, default=64,
                   help="bounded per-worker request queue; overflow is "
                        "shed as rejected:queue_full (--serve-workers)")
    p.add_argument("--poll", type=float, default=0.02, metavar="S",
                   help="idle poll interval (seconds)")
    p.add_argument("--max-iterations", type=int, default=None,
                   help="stop once this base iteration is published")
    p.add_argument("--idle-timeout", type=float, default=None,
                   help="stop after this many seconds without progress "
                        "(no admission, no publish, empty queue)")
    args = p.parse_args(argv)

    svc = build_service(args)

    worker = None
    pool = None
    if args.serve_workers and not args.serve_arch:
        raise SystemExit("--serve-workers requires --serve-arch")
    if args.serve_workers:
        from repro.serve.worker_pool import WorkerPool
        env = host_tuning.host_tuning_env() if host_tuning.enabled() else {}
        pool = WorkerPool(svc.repo.root, args.serve_workers,
                          arch=args.serve_arch,
                          max_len=args.serve_max_len, poll=args.poll,
                          batch=args.serve_batch,
                          queue_depth=args.serve_queue_depth, env=env)
        pool.start()
        print(f"[cold-service] {args.serve_workers} pool workers serving "
              f"{args.serve_arch} (max_len={args.serve_max_len}, "
              f"batch={args.serve_batch}, "
              f"queue_depth={args.serve_queue_depth})", flush=True)
    elif args.serve_arch:
        from repro.configs import get_config, reduce_config
        from repro.serve.hot_swap import ServingWorker
        cfg = reduce_config(get_config(args.serve_arch))
        worker = ServingWorker(cfg, svc.repo.root, repo=svc.repo,
                               max_len=args.serve_max_len)
        worker.start(interval=args.poll)
        print(f"[cold-service] hot-swap worker serving {args.serve_arch} "
              f"(max_len={args.serve_max_len})", flush=True)

    def _stop(signum, frame):
        svc.request_stop()

    signal.signal(signal.SIGTERM, _stop)
    signal.signal(signal.SIGINT, _stop)

    print(f"[cold-service] serving {args.root} from iteration "
          f"{svc.repo.iteration} (min_cohort={svc.policy.min_cohort}, "
          f"mesh={args.mesh or 'none'})", flush=True)
    st = svc.serve_forever(poll_interval=args.poll,
                           max_iterations=args.max_iterations,
                           idle_timeout=args.idle_timeout)
    if worker is not None:
        ws = worker.stop()
        print(f"[cold-service] worker stopped at iteration "
              f"{ws['iteration']}: {ws['swaps_total']} swaps "
              f"({ws['live_swaps']} live), {ws['requests_total']} requests "
              f"({ws['requests_pinned_across_swaps']} pinned across swaps)",
              flush=True)
    if pool is not None:
        states = pool.states()
        codes = pool.stop()
        detail = ", ".join(
            f"{wid}@it{(s or {}).get('iteration')}"
            f"({(s or {}).get('requests_total', 0)} req)"
            for wid, s in sorted(states.items()))
        print(f"[cold-service] pool stopped (exit={codes}): {detail}",
              flush=True)
    fams = st.get("families")
    if fams:
        detail = ", ".join(f"{n}@it{f['iteration']}"
                           for n, f in sorted(fams.items()))
        print(f"[cold-service] family: {detail} "
              f"({st['families_spawned_total']} spawned, "
              f"{st['cross_fuses_total']} cross-fuses)", flush=True)
    print(f"[cold-service] stopped at iteration {st['iteration']}: "
          f"{st['fuses']} fuses, {st['fused_contributions']} contributions "
          f"fused, {st['rejected_total']} rejected "
          f"({st['novelty_rejected_total']} near-duplicates), "
          f"{st['rollbacks_total']} rollbacks "
          f"({st['quarantined_total']} submissions quarantined)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
