import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e/g).

For every (architecture × input shape × mesh) combination this lowers and
compiles the real step function — train_step for train shapes, prefill /
serve steps for inference shapes — against ShapeDtypeStruct inputs (no
allocation), then records:

* ``compiled.memory_analysis()``  (per-chip fit proof),
* ``compiled.cost_analysis()``    (FLOPs / bytes for §Roofline),
* collective traffic parsed from the optimized per-device HLO,
* the derived roofline terms (repro.utils.roofline).

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>[__<strategy>].json``
and are consumed by ``benchmarks/roofline.py`` and EXPERIMENTS.md.

The 512 fake host devices are forced in the FIRST import line above, before
jax initializes; nothing else in the repo sets this flag (tests and benches
see the single real CPU device).

Usage:
  python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh pod1|pod2|both] [--force]
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --strategy cold
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.configs.base import ArchConfig, InputShape
from repro.configs.shapes import SHAPES
from repro.core.distributed import make_cold_train_step, make_fuse_step, ColdSchedule
from repro.kernels import ops as KOPS
from repro.launch import sharding as SH
from repro.launch.mesh import make_cold_mesh, make_production_mesh
from repro.launch.specs import (
    abstract_cache,
    abstract_params,
    abstract_state,
    auto_microbatches,
    input_specs,
)
from repro.optim.optimizers import constant_lr, make_optimizer
from repro.train.step import make_prefill_step, make_serve_step, make_train_step
from repro.utils.hlo_flops import analyze_hlo, wire_bytes as hlo_wire_bytes
from repro.utils.roofline import Roofline, model_flops_per_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

# long_500k eligibility (DESIGN.md §4): SSM / hybrid / windowed archs only.
LONG_CTX_ARCHS = {"rwkv6-7b", "jamba-1.5-large-398b", "mixtral-8x7b", "gemma3-1b"}

# Model-parallel submesh is fixed at 16 by the production mesh.
MODEL_AXIS = 16


def eligible(arch: str, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return arch in LONG_CTX_ARCHS
    return True


def _mesh(kind: str):
    if kind == "pod1":
        return make_production_mesh(multi_pod=False)
    if kind == "pod2":
        return make_production_mesh(multi_pod=True)
    if kind.startswith("cold"):
        # cold mesh: contributors x replicas x model; e.g. "cold8x2"
        spec = kind[4:] or "8x2"
        c, r = (int(x) for x in spec.split("x"))
        return make_cold_mesh(contributors=c, replicas=r, model=MODEL_AXIS)
    raise ValueError(kind)


def _data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data", "contrib", "replica") if a in mesh.axis_names)


def _dp_size(mesh) -> int:
    n = 1
    for a in _data_axes(mesh):
        n *= mesh.shape[a]
    return n


def _stash_hlo(cfg, shape, mesh, hlo: str, extra) -> None:
    """Gzip the optimized HLO next to the JSON so rooflines can be
    recomputed offline (``benchmarks.reanalyze``) without recompiling."""
    import gzip

    hlo_dir = os.path.join(ARTIFACT_DIR, "..", "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    tag = f"{cfg.name}__{shape.name}__{'x'.join(str(v) for v in mesh.shape.values())}"
    if extra and extra.get("strategy"):
        tag += f"__{extra['strategy']}"
    with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
        f.write(hlo)


def _analyze(compiled, mesh, cfg: ArchConfig, shape: InputShape, *, training: bool,
             wall_s: float, microbatches: int, extra: Optional[Dict] = None) -> Dict[str, Any]:
    # raw XLA numbers (NOTE: cost_analysis counts while/scan bodies ONCE —
    # kept for reference only)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    raw_flops = float(ca.get("flops", 0.0))
    raw_hbm = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "peak_memory_in_bytes"):
            mem[k] = int(getattr(ma, k, 0) or 0)
    hlo = compiled.as_text()
    _stash_hlo(cfg, shape, mesh, hlo, extra)
    # trip-count-aware per-chip analysis (repro.utils.hlo_flops)
    an = analyze_hlo(hlo)
    chips = mesh.devices.size
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mf_total = model_flops_per_step(cfg.active_param_count(), tokens, training=training)
    roof = Roofline(
        flops=an.flops,
        hbm_bytes=an.hbm_bytes,
        collective_bytes=float(hlo_wire_bytes(an)),
        model_flops=mf_total / chips,
        chips=chips,
    )
    out = {
        "ok": True,
        "arch": cfg.name,
        "shape": shape.name,
        "mesh_shape": dict(mesh.shape),
        "chips": chips,
        "kind": shape.kind,
        "microbatches": microbatches,
        "compile_wall_s": wall_s,
        "cost_analysis_raw": {"flops": raw_flops, "bytes_accessed": raw_hbm},
        "memory_analysis": mem,
        "collectives": {
            "bytes_by_kind": {k: float(v) for k, v in an.collective_bytes.items()},
            "count_by_kind": {k: int(v) for k, v in an.collective_count.items()},
            "total_bytes": float(an.total_collective_bytes),
            "dynamic_whiles": an.dynamic_whiles,
        },
        "roofline": roof.as_dict(),
        "hlo_chars": len(hlo),
    }
    if extra:
        out.update(extra)
    return out


def _dry_cfg(cfg: ArchConfig) -> ArchConfig:
    """Dry-run numerics policy: bf16 params/compute (DESIGN.md §5)."""
    return dataclasses.replace(cfg, param_dtype="bfloat16", compute_dtype="bfloat16")


def run_one(arch: str, shape_name: str, mesh_kind: str, *, strategy: str = "sync") -> Dict[str, Any]:
    cfg = _dry_cfg(get_config(arch))
    shape = get_shape(shape_name)
    if not eligible(arch, shape):
        return {"ok": False, "skipped": True,
                "reason": f"{arch} is full-attention; long_500k reserved for sub-quadratic archs"}
    mesh = _mesh(mesh_kind)
    t0 = time.time()
    # The CPU backend cannot lower Pallas; dry-runs use the pure-jnp paths.
    KOPS.use_kernels(False)

    # §Perf lever: "dp" layout — batch sharded over BOTH mesh axes, weights
    # replicated (no tensor parallelism).  The right regime for models whose
    # head counts / widths fit badly on a 16-way model axis (e.g. gemma3-1b:
    # 4 heads => attention otherwise runs 16x-replicated per chip).
    data_axis: Any = "data"
    model_axis: Any = "model"
    if strategy == "dp":
        data_axis = ("data", "model") if "pod" not in mesh.axis_names else ("pod", "data", "model")
        model_axis = None

    if shape.is_decode:
        params = abstract_params(cfg)
        cache = abstract_cache(cfg, shape)
        batch = input_specs(cfg, shape)
        params_sh = SH.params_shardings(mesh, params, cfg, data_axis=data_axis, model_axis=model_axis)
        cache_sh = SH.cache_shardings(mesh, cache, cfg, data_axis=data_axis, model_axis=model_axis)
        batch_sh = SH.batch_shardings(mesh, batch, data_axis=data_axis, model_axis=model_axis)
        rep = SH.replicated(mesh)
        serve = make_serve_step(cfg)
        with mesh:
            jitted = jax.jit(
                serve,
                in_shardings=(params_sh, cache_sh, batch_sh["tokens"], rep),
                out_shardings=(None, cache_sh),
            )
            lowered = jitted.lower(params, cache, batch["tokens"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
        return _analyze(compiled, mesh, cfg, shape, training=False,
                        wall_s=time.time() - t0, microbatches=1)

    if shape.kind == "prefill":
        params = abstract_params(cfg)
        batch = input_specs(cfg, shape)
        params_sh = SH.params_shardings(mesh, params, cfg, data_axis=data_axis, model_axis=model_axis)
        batch_sh = SH.batch_shardings(mesh, batch, data_axis=data_axis, model_axis=model_axis)
        prefill = make_prefill_step(cfg)
        with mesh:
            jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh), out_shardings=None)
            lowered = jitted.lower(params, batch)
            compiled = lowered.compile()
        return _analyze(compiled, mesh, cfg, shape, training=False,
                        wall_s=time.time() - t0, microbatches=1)

    # --- training ---------------------------------------------------------
    # §Perf lever: force the factored optimizer (REPRO_OPT_ADAFACTOR=1) — the
    # pure-DP layout replicates optimizer state per chip, so Adam's f32 m+v
    # (8 bytes/param) is the peak-memory driver for ~1B models.
    opt_name = "adafactor" if os.environ.get("REPRO_OPT_ADAFACTOR", "0") == "1" else cfg.optimizer
    opt = make_optimizer(opt_name, constant_lr(1e-4))
    batch = input_specs(cfg, shape)

    if strategy == "cold":
        C = mesh.shape.get("contrib", 1) * mesh.shape.get("pod", 1)
        state1 = abstract_state(cfg, opt)
        state = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((C,) + x.shape, x.dtype), state1
        )
        batch = {k: jax.ShapeDtypeStruct((C, v.shape[0] // C) + v.shape[1:], v.dtype)
                 for k, v in batch.items()}
        mb = auto_microbatches(cfg, shape, _dp_size(mesh))
        step = make_cold_train_step(cfg, opt, microbatches=mb)
        from repro.core.distributed import cold_shardings
        state_sh, batch_sh = cold_shardings(mesh, cfg, state, batch)
        with mesh:
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state, batch)
            compiled = lowered.compile()
        res = _analyze(compiled, mesh, cfg, shape, training=True,
                       wall_s=time.time() - t0, microbatches=mb,
                       extra={"strategy": "cold", "contributors": C})
        # fuse step (the Repository collective), reported separately.
        # flat=False: the flat fuse currently pins its staging buffer to a
        # replicated sharding (GSPMD concat+mean workaround, see
        # make_fuse_step), which at pod scale would charge a full parameter
        # all-gather to the fuse budget; the per-leaf collective is the
        # honest pod-scale model until the sharded flat fuse lands (ROADMAP)
        t1 = time.time()
        fuse = make_fuse_step(cfg, mesh, ColdSchedule(), flat=False)
        with mesh:
            jf = jax.jit(fuse, in_shardings=(state_sh["params"],),
                         out_shardings=state_sh["params"])
            fc = jf.lower(state["params"]).compile()
        res["fuse"] = _analyze(fc, mesh, cfg, shape, training=True,
                               wall_s=time.time() - t1, microbatches=1)
        return res

    state = abstract_state(cfg, opt)
    params_sh = SH.params_shardings(mesh, state["params"], cfg, data_axis=data_axis, model_axis=model_axis)
    opt_sh = SH.opt_state_shardings(mesh, state["opt"], params_sh)
    state_sh = {"params": params_sh, "opt": opt_sh}
    batch_sh = SH.batch_shardings(mesh, batch, data_axis=data_axis, model_axis=model_axis)
    dp = mesh.devices.size if strategy == "dp" else _dp_size(mesh)
    mb = auto_microbatches(cfg, shape, dp)
    step = make_train_step(cfg, opt, microbatches=mb, grad_shardings=params_sh)
    with mesh:
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
        lowered = jitted.lower(state, batch)
        compiled = lowered.compile()
    return _analyze(compiled, mesh, cfg, shape, training=True,
                    wall_s=time.time() - t0, microbatches=mb)


def _artifact_path(arch: str, shape: str, mesh_kind: str, strategy: str) -> str:
    tag = f"{arch}__{shape}__{mesh_kind}"
    if strategy != "sync":
        tag += f"__{strategy}"
    return os.path.abspath(os.path.join(ARTIFACT_DIR, tag + ".json"))


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    p.add_argument("--shape", choices=list(SHAPES), default=None)
    p.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="pod1")
    p.add_argument("--strategy", default="sync",
                   help="sync | cold (cold uses the contributor mesh; combine with --cold-mesh)")
    p.add_argument("--cold-mesh", default="8x2", help="contributors x replicas, e.g. 8x2")
    p.add_argument("--all", action="store_true", help="run every (arch, shape)")
    p.add_argument("--force", action="store_true", help="recompute existing artifacts")
    p.add_argument("--out", default=None)
    args = p.parse_args()

    global ARTIFACT_DIR
    if args.out:
        ARTIFACT_DIR = args.out
    os.makedirs(ARTIFACT_DIR, exist_ok=True)

    archs = list(ARCH_IDS[:10]) if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else [args.shape]
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    if args.strategy.startswith("cold"):
        meshes = [f"cold{args.cold_mesh}"]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = _artifact_path(arch, shape, mesh_kind, args.strategy)
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {os.path.basename(path)}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_kind} ({args.strategy}) ...", flush=True)
                try:
                    res = run_one(arch, shape, mesh_kind, strategy=args.strategy)
                except Exception as e:  # record failures as artifacts too
                    res = {"ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    failures += 1
                    print(f"  FAILED: {res['error']}")
                res.setdefault("arch", arch)
                res.setdefault("shape", shape)
                res.setdefault("mesh", mesh_kind)
                res["strategy"] = args.strategy
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                if res.get("ok"):
                    r = res["roofline"]
                    print(
                        f"  ok in {res['compile_wall_s']:.0f}s: "
                        f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
                        f"collective={r['collective_s']*1e3:.2f}ms -> {r['bottleneck']} "
                        f"(useful={r['useful_flops_ratio']:.2f}, "
                        f"peak={res['memory_analysis'].get('peak_memory_in_bytes', 0)/2**30:.2f}GiB)"
                    )
                elif res.get("skipped"):
                    print(f"  skipped: {res['reason']}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
