"""Optimizers from scratch (optax is not available offline).

Functional API: ``opt = make_optimizer(name, lr_schedule, **kw)`` returns an
object with ``init(params) -> state`` and ``update(grads, state, params) ->
(updates, state)`` where updates are to be *added* to params.

Implemented: SGD(+momentum), AdamW (paper's finetuning optimizer family,
App. B), and Adafactor (factored second moment — used by the ≥70B dry-run
configs to keep optimizer HBM in budget).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


Schedule = Callable[[jax.Array], jax.Array]


def constant_lr(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_decay_lr(lr: float, decay_per_step: float, min_lr: float = 0.0) -> Schedule:
    """Paper App. B: lr 5e-5 with linear decay."""
    return lambda step: jnp.maximum(lr * (1.0 - decay_per_step * step), min_lr)


def warmup_cosine_lr(lr: float, warmup: int, total: int, min_frac: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)
    name: str = ""


def sgd(schedule: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mom"] = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return state

    def update(grads, state, params):
        lr = schedule(state["step"])
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state["mom"], grads
            )
            upd = jax.tree.map(lambda m, p: (-lr * m).astype(p.dtype), mom, params)
            return upd, {"step": state["step"] + 1, "mom": mom}
        upd = jax.tree.map(lambda g, p: (-lr * g).astype(p.dtype), grads, params)
        return upd, {"step": state["step"] + 1}

    return Optimizer(init, update, "sgd")


def adamw(
    schedule: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "m": zeros(), "v": zeros()}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = schedule(state["step"])
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mh = m / bc1
            vh = v / bc2
            u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, "adamw")


def adafactor(
    schedule: Schedule,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), no momentum.

    For a [m, n] matrix the state is m + n floats instead of m*n — the memory
    lever that lets the 72B/398B dry-runs fit optimizer state in HBM.
    """

    def _factored(x):
        return x.ndim >= 2

    def init(params):
        def leaf_state(x):
            if _factored(x):
                return {
                    "vr": jnp.zeros(x.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(x, jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(leaf_state, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = schedule(state["step"])
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if _factored(g):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = gf * rfac[..., None] * cfac[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), new_s

        flat_u, flat_s = [], []
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_s = treedef.flatten_up_to(state["v"])
        leaves_p = jax.tree.leaves(params)
        for g, s, p in zip(leaves_g, leaves_s, leaves_p):
            u, ns = upd(g, s, p)
            flat_u.append(u)
            flat_s.append(ns)
        updates = jax.tree.unflatten(treedef, flat_u)
        new_v = jax.tree.unflatten(treedef, flat_s)
        return updates, {"step": step, "v": new_v}

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, schedule: Schedule, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(schedule, **kw)
    if name == "adamw":
        return adamw(schedule, **kw)
    if name == "adafactor":
        return adafactor(schedule, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
