"""Synthetic multitask suite — the stand-in for the paper's 36 datasets.

The paper's effect rests on *cross-task transfer*: finetuning on many
classification datasets teaches the encoder shared skills that help unseen
datasets (§2.1).  We synthesize that structure explicitly:

* A fixed random token->motif map  Φ ∈ R^{V x M}  (the "latent skill"
  shared by every task; the analog of linguistic features).
* Task k draws a label rule  W_k ∈ R^{M x C_k}: the label of a sequence is
  ``argmax(W_kᵀ · mean_t Φ[tok_t] + noise)``.
* Each task also has its own token distribution (a Dirichlet-sampled unigram
  bias), so tasks differ in *domain* as well as *rule* — mirroring the
  NLI / sentiment / Twitter / topic spread of App. A.

A model can only solve a task by estimating motif activations — knowledge
that transfers to every other task, seen or unseen.  Task rules (W_k) do not
transfer, matching the paper's per-dataset classification heads.

Everything is deterministic in (suite seed, task id).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

DEFAULT_VOCAB = 512
DEFAULT_MOTIFS = 24
# Reserved token ids (mirror RoBERTa special tokens).
PAD, CLS, MASK = 0, 1, 2
N_SPECIAL = 3


@dataclass(frozen=True)
class TaskSpec:
    task_id: int
    name: str
    num_classes: int
    seed: int


@dataclass
class SyntheticSuite:
    """Container for the shared latent structure + task pool."""

    vocab_size: int = DEFAULT_VOCAB
    num_motifs: int = DEFAULT_MOTIFS
    num_tasks: int = 36
    seed: int = 0
    noise: float = 0.35

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Sparse-ish motif map: most tokens activate few motifs.
        phi = rng.normal(0, 1, (self.vocab_size, self.num_motifs))
        gate = rng.random((self.vocab_size, self.num_motifs)) < 0.25
        self.phi = (phi * gate).astype(np.float32)
        self.phi[:N_SPECIAL] = 0.0
        self.tasks: List[TaskSpec] = []
        kinds = ["nli", "sentiment", "topic", "twitter", "qa", "accept"]
        for t in range(self.num_tasks):
            c = int(rng.integers(2, 6))
            self.tasks.append(
                TaskSpec(t, f"{kinds[t % len(kinds)]}-{t:02d}", c, int(rng.integers(2**31)))
            )
        self._task_params: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    # ------------------------------------------------------------------
    def task_params(self, task_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """(W [M, C], unigram distribution [V]) for a task, cached."""
        if task_id not in self._task_params:
            spec = self.tasks[task_id]
            rng = np.random.default_rng(spec.seed)
            W = rng.normal(0, 1, (self.num_motifs, spec.num_classes)).astype(np.float32)
            alpha = np.full(self.vocab_size - N_SPECIAL, 0.3)
            unigram = rng.dirichlet(alpha).astype(np.float64)
            full = np.zeros(self.vocab_size)
            full[N_SPECIAL:] = unigram
            full = full / full.sum()
            self._task_params[task_id] = (W, full)
        return self._task_params[task_id]

    def sample(
        self, task_id: int, n: int, seq_len: int, *, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Draw (tokens [n, seq_len] int32, labels [n] int32) for a task."""
        spec = self.tasks[task_id]
        W, unigram = self.task_params(task_id)
        toks = rng.choice(self.vocab_size, size=(n, seq_len), p=unigram).astype(np.int32)
        toks[:, 0] = CLS
        profile = self.phi[toks].mean(axis=1)  # [n, M]
        logits = profile @ W + self.noise * rng.normal(0, 1, (n, spec.num_classes))
        labels = logits.argmax(axis=1).astype(np.int32)
        return toks, labels

    def dataset(
        self, task_id: int, n_train: int, n_test: int, seq_len: int, *, split_seed: int = 0
    ) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.tasks[task_id].seed, split_seed, 7))
        xtr, ytr = self.sample(task_id, n_train, seq_len, rng=rng)
        xte, yte = self.sample(task_id, n_test, seq_len, rng=rng)
        return {"x_train": xtr, "y_train": ytr, "x_test": xte, "y_test": yte}

    def lm_stream(self, n: int, seq_len: int, *, seed: int = 123) -> np.ndarray:
        """Token sequences from the task-mixture distribution (for the tiny
        MLM 'pretraining' that stands in for RoBERTa's)."""
        rng = np.random.default_rng(seed)
        task_ids = rng.integers(0, self.num_tasks, size=n)
        out = np.empty((n, seq_len), np.int32)
        for i, t in enumerate(task_ids):
            _, unigram = self.task_params(int(t))
            out[i] = rng.choice(self.vocab_size, size=seq_len, p=unigram)
        out[:, 0] = CLS
        return out


def mask_for_mlm(tokens: np.ndarray, rng: np.random.Generator, p: float = 0.15):
    """BERT-style masking.  Returns (inputs, targets, mask)."""
    inputs = tokens.copy()
    mask = (rng.random(tokens.shape) < p) & (tokens >= N_SPECIAL)
    inputs[mask] = MASK
    return inputs, tokens, mask.astype(np.float32)
