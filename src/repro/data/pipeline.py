"""Minimal deterministic data pipeline: shuffled epochs, fixed-size batches,
host->device sharding helpers."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import numpy as np


def batches(
    x: np.ndarray,
    y: Optional[np.ndarray],
    batch_size: int,
    *,
    rng: Optional[np.random.Generator] = None,
    epochs: int = 1,
    drop_remainder: bool = True,
) -> Iterator[Dict[str, np.ndarray]]:
    n = len(x)
    for _ in range(epochs):
        idx = np.arange(n)
        if rng is not None:
            rng.shuffle(idx)
        stop = (n // batch_size) * batch_size if drop_remainder else n
        for i in range(0, stop, batch_size):
            sel = idx[i : i + batch_size]
            out = {"tokens": x[sel]}
            if y is not None:
                out["labels"] = y[sel]
            yield out


def num_steps(n: int, batch_size: int, epochs: int) -> int:
    return (n // batch_size) * epochs


def shard_batch(batch: Dict[str, np.ndarray], sharding) -> Dict[str, jax.Array]:
    """Place a host batch onto devices with the given NamedSharding."""
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
