"""Pytree checkpointing (npz-based; no orbax offline).

Flattens a pytree of arrays into an ``.npz`` keyed by the path string; the
treedef is reconstructed from the keys on load, so files are self-contained
and diff-able.  Used by the host-level Repository (contributors exchange
checkpoints, Fig. 1) and by the training driver.

All writes are atomic: the npz is written to a ``.tmp-<pid>`` sibling and
``os.replace``d into place, so a contributor crashing mid-upload can never
leave a truncated checkpoint in the repository root.

Two formats share the atomic writer:

* **tree** (``save``/``load``) — one npz entry per leaf, human-diffable;
* **flat** (``save_flat``/``load_flat``) — a single contiguous buffer plus
  its ``FlatSpec`` layout (JSON), the Repository's staging/spill format —
  one sequential read brings a contribution back as a fusable ``[N]`` row.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.flat import FlatSpec
from repro.utils.pytree import path_str

_SEP = "::"
_BF16 = "__bf16__"  # npz has no bfloat16: stored as uint16 bit pattern
_FLAT_BUF = "__flat_buffer__"
_FLAT_SPEC = "__flat_spec__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = path_str(path).replace("/", _SEP)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            key += _BF16
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def _unflatten(d: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for key, val in d.items():
        if key.endswith(_BF16):
            key = key[: -len(_BF16)]
            val = val.view(jnp.bfloat16)
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def _atomic_savez(path: str, arrays: Dict[str, np.ndarray]) -> None:
    path = os.path.abspath(path)
    # preserve np.savez semantics: a suffix-less target gets ".npz" appended
    if not path.endswith(".npz"):
        path += ".npz"
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        np.savez(tmp, **arrays)
        # np.savez itself appends .npz when the target lacks the suffix
        if not tmp.endswith(".npz") and os.path.exists(tmp + ".npz"):
            tmp += ".npz"
        os.replace(tmp, path)
    except BaseException:
        for cand in (tmp, tmp + ".npz"):
            if os.path.exists(cand):
                os.remove(cand)
        raise


def save(path: str, tree) -> None:
    _atomic_savez(path, _flatten(tree))


def load(path: str, *, as_jax: bool = True):
    with np.load(path) as data:
        tree = _unflatten({k: data[k] for k in data.files})
    if as_jax:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree


# -- flat-buffer format (Repository staging / spill) ------------------------


def save_flat(path: str, buf, spec: FlatSpec) -> None:
    """Persist a flat parameter buffer + its layout spec in one npz."""
    arr = np.asarray(buf)
    if arr.dtype == jnp.bfloat16:
        arr = arr.view(np.uint16)
    _atomic_savez(path, {
        _FLAT_BUF: arr,
        _FLAT_SPEC: np.frombuffer(
            json.dumps(spec.to_json()).encode(), dtype=np.uint8),
    })


def load_flat(path: str, *, as_jax: bool = True) -> Tuple[Any, FlatSpec]:
    """Load (buffer, spec) written by ``save_flat``."""
    with np.load(path) as data:
        if _FLAT_BUF not in data.files:
            raise ValueError(f"{path} is not a flat checkpoint")
        meta = json.loads(bytes(data[_FLAT_SPEC]).decode())
        spec = FlatSpec.from_json(meta)
        buf = data[_FLAT_BUF]
    if spec.dtype == "bfloat16":
        buf = buf.view(jnp.bfloat16)
    if as_jax:
        buf = jnp.asarray(buf)
    return buf, spec


def is_flat(path: str) -> bool:
    with np.load(path) as data:
        return _FLAT_BUF in data.files
