"""Pytree checkpointing (npz-based; no orbax offline).

Flattens a pytree of arrays into an ``.npz`` keyed by the path string; the
treedef is reconstructed from the keys on load, so files are self-contained
and diff-able.  Used by the host-level Repository (contributors exchange
checkpoints, Fig. 1) and by the training driver.

All writes are atomic: the npz is written to a ``.tmp-<pid>`` sibling and
``os.replace``d into place, so a contributor crashing mid-upload can never
leave a truncated checkpoint in the repository root.

Three formats share the atomic writer:

* **tree** (``save``/``load``) — one npz entry per leaf, human-diffable;
* **flat** (``save_flat``/``load_flat``) — a single contiguous buffer plus
  its ``FlatSpec`` layout (JSON), the Repository's staging/spill format —
  one sequential read brings a contribution back as a fusable ``[N]`` row;
* **flat-sharded** (``save_flat_shards``/``FlatShardReader``) — the same
  row split into its S block-cyclic per-shard slices, one npz entry each,
  so a mesh repository's spilled rows reload shard by shard and the full
  ``[N]`` row never materializes on the host (docs/async_repository.md).

``save_json_atomic`` extends the same crash discipline to the Repository's
spill manifest: a reader can never observe a half-written JSON file.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.flat import DeltaPayload, FlatSpec, ShardedFlatSpec
from repro.utils.pytree import path_str

_SEP = "::"
_BF16 = "__bf16__"  # npz has no bfloat16: stored as uint16 bit pattern
_FLAT_BUF = "__flat_buffer__"
_FLAT_SPEC = "__flat_spec__"
_FLAT_SSPEC = "__flat_shard_spec__"
_FLAT_EXTRA = "__flat_extra__"  # free-form JSON rider (queue submissions)
_SHARD_FMT = "__flat_shard_{:04d}__"
_DELTA_SPEC = "__delta_spec__"      # codec geometry (compressed submissions)
_DELTA_IDX = "__delta_indices__"    # int16 [nb, kb] (or [S, nb, kb])
_DELTA_VAL = "__delta_values__"     # int8  [nb, kb] (or [S, nb, kb])
_DELTA_SCL = "__delta_scales__"     # f32   [nb]     (or [S, nb])


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = path_str(path).replace("/", _SEP)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            key += _BF16
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def _unflatten(d: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for key, val in d.items():
        if key.endswith(_BF16):
            key = key[: -len(_BF16)]
            val = val.view(jnp.bfloat16)
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def _atomic_savez(path: str, arrays: Dict[str, np.ndarray]) -> None:
    path = os.path.abspath(path)
    # preserve np.savez semantics: a suffix-less target gets ".npz" appended
    if not path.endswith(".npz"):
        path += ".npz"
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        np.savez(tmp, **arrays)
        # np.savez itself appends .npz when the target lacks the suffix
        if not tmp.endswith(".npz") and os.path.exists(tmp + ".npz"):
            tmp += ".npz"
        os.replace(tmp, path)
    except BaseException:
        for cand in (tmp, tmp + ".npz"):
            if os.path.exists(cand):
                os.remove(cand)
        raise


def save(path: str, tree) -> None:
    _atomic_savez(path, _flatten(tree))


def load(path: str, *, as_jax: bool = True):
    with np.load(path) as data:
        tree = _unflatten({k: data[k] for k in data.files})
    if as_jax:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree


# -- flat-buffer format (Repository staging / spill) ------------------------


def _extra_entry(extra: Dict[str, Any]) -> np.ndarray:
    return np.frombuffer(json.dumps(extra).encode(), dtype=np.uint8)


def save_flat(path: str, buf, spec: FlatSpec, *,
              extra: Optional[Dict[str, Any]] = None) -> None:
    """Persist a flat parameter buffer + its layout spec in one npz.

    ``extra`` rides along as a free-form JSON entry (surfaced by
    ``flat_row_meta``) — the contribution queue uses it for submission
    metadata (contributor, weight, base iteration, checksum) without
    changing the row format."""
    arr = np.asarray(buf)
    if arr.dtype == jnp.bfloat16:
        arr = arr.view(np.uint16)
    arrays = {
        _FLAT_BUF: arr,
        _FLAT_SPEC: np.frombuffer(
            json.dumps(spec.to_json()).encode(), dtype=np.uint8),
    }
    if extra is not None:
        arrays[_FLAT_EXTRA] = _extra_entry(extra)
    _atomic_savez(path, arrays)


def load_flat(path: str, *, as_jax: bool = True) -> Tuple[Any, FlatSpec]:
    """Load (buffer, spec) written by ``save_flat``."""
    with np.load(path) as data:
        if _FLAT_BUF not in data.files:
            raise ValueError(f"{path} is not a flat checkpoint")
        meta = json.loads(bytes(data[_FLAT_SPEC]).decode())
        spec = FlatSpec.from_json(meta)
        buf = data[_FLAT_BUF]
    if spec.dtype == "bfloat16":
        buf = buf.view(jnp.bfloat16)
    if as_jax:
        buf = jnp.asarray(buf)
    return buf, spec


def is_flat(path: str) -> bool:
    with np.load(path) as data:
        return _FLAT_BUF in data.files


# -- atomic JSON (Repository spill manifest) --------------------------------


def save_json_atomic(path: str, obj: Any, *, default=None,
                     indent: Optional[int] = 2) -> None:
    """Write JSON with the same tmp + ``os.replace`` discipline as the npz
    writer: a crash mid-write can never leave a truncated manifest (or
    repository.json).  ``indent=None`` writes compact single-line JSON —
    for machine-only state rewritten on hot paths (the cohort sketch)."""
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # pid AND thread id: spill-executor threads of one process must not
    # truncate each other's in-progress tmp file
    tmp = f"{path}.tmp-{os.getpid()}-{threading.get_ident()}"
    try:
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=indent, default=default)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def load_json(path: str) -> Any:
    with open(path) as f:
        return json.load(f)


def move_atomic(src: str, dst: str) -> None:
    """Move a file with ``os.replace`` semantics, creating the destination
    directory first.  Same-filesystem renames are atomic: an observer sees
    the file at exactly one of the two paths, never torn or at both — the
    discipline the routed admission path relies on when it re-homes a
    queue file into a family member's queue."""
    dst = os.path.abspath(dst)
    os.makedirs(os.path.dirname(dst), exist_ok=True)
    os.replace(src, dst)


# -- append-only JSONL (service metrics time series) ------------------------
#
# The atomic tmp+replace discipline above is wrong for a *time series*: a
# metrics log is appended hundreds of times per run and must never be
# rewritten whole.  Instead the file is strictly append-only — one JSON
# object per line — and readers tolerate exactly the damage a kill -9 can
# inflict on an O_APPEND writer: a torn FINAL line (no interior line can
# tear, because every earlier append completed before the next began).


def append_jsonl(path: str, obj: Any, *, default=None,
                 rotate_bytes: int | None = None) -> None:
    """Append one record to a JSONL file as a single ``\\n``-terminated
    line.  The line is built before the file is touched, so a serialization
    error appends nothing; a crash mid-``write`` leaves at most a torn
    final line, which ``read_jsonl``/``repair_jsonl_tail`` skip.

    ``rotate_bytes`` caps the active file: when it already holds at least
    that many bytes, it is rotated to ``<path>.1`` (replacing any previous
    rotation) before the append, so the active file never grows unboundedly
    under sustained load.  Rotation must have a SINGLE rotator — concurrent
    appenders are safe (O_APPEND), concurrent rotators are not; in the
    serving stack only the daemon rotates, pool workers plain-append."""
    line = json.dumps(obj, default=default)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    if rotate_bytes is not None:
        rotate_jsonl(path, rotate_bytes)
    with open(path, "a") as f:
        f.write(line + "\n")


def rotate_jsonl(path: str, max_bytes: int) -> bool:
    """Rotate ``path`` to ``path.1`` if it holds >= ``max_bytes`` bytes
    (single rotation slot: a previous ``path.1`` is replaced).  The rename
    is atomic, so a concurrent O_APPEND writer loses no records — a write
    racing the rename lands whole in exactly one of the two files; the
    next append recreates the active file.  Torn-tail repair and the
    read-side skip still apply to the ACTIVE file only: rotation moves a
    complete-records prefix (the torn tail, if any, is always the newest
    write, which postdates the size check).  Returns True if rotated."""
    try:
        size = os.path.getsize(path)
    except OSError:
        return False
    if size < max_bytes:
        return False
    os.replace(path, path + ".1")
    return True


def read_jsonl(path: str, *, warn: bool = True,
               include_rotated: bool = False) -> list:
    """Parse a JSONL file, returning the records in order.  A torn tail —
    an unterminated or unparseable FINAL line, the only damage an
    append-only writer's death can cause — is skipped (with a warning by
    default), never raised: a monitoring reader must not stall the daemon
    or the operator.  A malformed line anywhere *else* raises ``ValueError``
    — that is corruption, not a crash artifact.  A missing file is an
    empty series, not an error (the reader may start before the first
    append).  ``include_rotated=True`` prepends the records of the
    rotation slot ``<path>.1`` (see ``rotate_jsonl``), yielding the full
    retained series in time order."""
    if include_rotated:
        return (read_jsonl(path + ".1", warn=warn)
                + read_jsonl(path, warn=warn))
    out = []
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return out
    for i, line in enumerate(lines):
        stripped = line.strip()
        if not stripped:
            continue
        try:
            rec = json.loads(stripped)
        except json.JSONDecodeError as err:
            if i == len(lines) - 1:
                if warn:
                    import warnings
                    warnings.warn(f"{path}: skipping torn final line "
                                  f"({len(stripped)} bytes): {err}")
                break
            raise ValueError(
                f"{path}: malformed record at line {i + 1} (not the torn "
                f"tail a crash can leave): {err}") from err
        out.append(rec)
    return out


def repair_jsonl_tail(path: str) -> int:
    """Truncate a torn final line off a JSONL file so future appends start
    on a record boundary (appending after a torn tail would corrupt a
    MID-file line, which ``read_jsonl`` treats as fatal).  Complete records
    are never modified — the file stays append-only in the only sense that
    matters.  Returns the number of bytes truncated (0 when intact); a
    missing file is a no-op."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return 0
    keep = len(data)
    while keep > 0:
        if data[:keep].endswith(b"\n"):
            # the final terminated line must itself parse, or it is torn
            # too (a partial line that happened to flush its newline)
            last = data[:keep].rstrip(b"\n").rsplit(b"\n", 1)[-1]
            try:
                if last.strip():
                    json.loads(last.decode())
                break
            except (json.JSONDecodeError, UnicodeDecodeError):
                keep = len(data[:keep].rstrip(b"\n").rsplit(b"\n", 1)[0])
                if keep:
                    keep += 1  # keep the preceding line's newline
                continue
        keep -= 1
    torn = len(data) - keep
    if torn:
        with open(path, "r+b") as f:
            f.truncate(keep)
    return torn


# -- per-shard flat format (sharded spill) ----------------------------------


def _spec_entry(spec: FlatSpec) -> np.ndarray:
    return np.frombuffer(json.dumps(spec.to_json()).encode(), dtype=np.uint8)


def save_flat_shards(path: str, slices: Sequence[np.ndarray],
                     spec: FlatSpec, sspec: ShardedFlatSpec, *,
                     extra: Optional[Dict[str, Any]] = None) -> None:
    """Persist one flat row as its S block-cyclic per-shard slices
    (``ShardedFlatSpec.shard_slices``), one npz entry per shard, plus both
    layout specs.  Written atomically like every checkpoint.  ``extra`` is
    the same free-form JSON rider ``save_flat`` accepts."""
    if len(slices) != sspec.n_shards:
        raise ValueError(f"{len(slices)} slices != n_shards {sspec.n_shards}")
    arrays: Dict[str, np.ndarray] = {
        _FLAT_SPEC: _spec_entry(spec),
        _FLAT_SSPEC: np.frombuffer(
            json.dumps(sspec.to_json()).encode(), dtype=np.uint8),
    }
    if extra is not None:
        arrays[_FLAT_EXTRA] = _extra_entry(extra)
    for i, s in enumerate(slices):
        arr = np.asarray(s)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[_SHARD_FMT.format(i)] = arr
    _atomic_savez(path, arrays)


def is_flat_sharded(path: str) -> bool:
    with np.load(path) as data:
        return _FLAT_SSPEC in data.files


class FlatShardReader:
    """Lazy per-shard reader over a ``save_flat_shards`` npz.

    ``np.load`` decompresses entries on access, so ``shard(i)`` brings only
    that shard's ``[shard_len]`` slice onto the host — the reload path of
    the sharded spill never holds the full ``[N]`` row.  Use as a context
    manager (the underlying zip file stays open between reads).
    """

    def __init__(self, path: str):
        self.path = path
        self._data = np.load(path)
        if _FLAT_SSPEC not in self._data.files:
            self._data.close()
            raise ValueError(f"{path} is not a sharded flat checkpoint")
        self.spec = FlatSpec.from_json(
            json.loads(bytes(self._data[_FLAT_SPEC]).decode()))
        self.sspec = ShardedFlatSpec.from_json(
            json.loads(bytes(self._data[_FLAT_SSPEC]).decode()))

    def shard(self, i: int) -> np.ndarray:
        """One ``[shard_len]`` slice, host-side."""
        buf = self._data[_SHARD_FMT.format(i)]
        if self.spec.dtype == "bfloat16":
            buf = buf.view(jnp.bfloat16)
        return buf

    def full_row(self) -> np.ndarray:
        """Reassemble the portable ``[N]`` row (the fallback when the spill
        layout does not match the mesh the repository reopened under — this
        path DOES materialize the row on host, by design)."""
        return self.sspec.unshard_slices(
            [self.shard(i) for i in range(self.sspec.n_shards)])

    def close(self) -> None:
        self._data.close()

    def __enter__(self) -> "FlatShardReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def flat_row_meta(path: str) -> Dict[str, Any]:
    """Peek a spilled row's layout without touching its buffer entries:
    returns the ``FlatSpec`` JSON dict plus ``{"sharded": bool}`` (and the
    ``ShardedFlatSpec`` JSON under ``"shard_spec"`` when sharded).  A
    delta-compressed row (``save_flat_delta``) additionally carries
    ``{"compressed": True, "delta_spec": {...}}``.  Used by crash recovery
    to validate manifest entries cheaply."""
    with np.load(path) as data:
        if _FLAT_SPEC not in data.files:
            raise ValueError(f"{path} is not a flat checkpoint")
        meta = json.loads(bytes(data[_FLAT_SPEC]).decode())
        meta["sharded"] = _FLAT_SSPEC in data.files
        if meta["sharded"]:
            meta["shard_spec"] = json.loads(bytes(data[_FLAT_SSPEC]).decode())
        meta["compressed"] = _DELTA_SPEC in data.files
        if meta["compressed"]:
            meta["delta_spec"] = json.loads(bytes(data[_DELTA_SPEC]).decode())
        if _FLAT_EXTRA in data.files:
            meta["extra"] = json.loads(bytes(data[_FLAT_EXTRA]).decode())
    return meta


# -- delta-compressed flat format (compressed queue submissions) ------------
#
# A compressed submission never carries the dense [N] row: it persists the
# DeltaPayload arrays (per-block top-k int16 offsets, int8 values, f32
# scales — repro.utils.flat.delta_encode) plus the SAME FlatSpec/
# ShardedFlatSpec layout entries the dense formats write, so
# ``flat_row_meta`` validation and by-reference ingest work unchanged.  The
# sharded variant stacks the S per-shard payloads along a leading axis
# (every shard has identical codec geometry: shard_len is uniform by
# construction), one npz entry per array — not per shard — keeping the
# file layout O(1) in S.


def save_flat_delta(path: str, payloads, spec: FlatSpec, *,
                    sspec: Optional[ShardedFlatSpec] = None,
                    extra: Optional[Dict[str, Any]] = None) -> None:
    """Persist a compressed contribution: one ``DeltaPayload`` (whole-row)
    or a list of S per-shard payloads with their ``sspec`` (the compressed
    analog of ``save_flat``/``save_flat_shards``).  Written atomically;
    ``extra`` is the same free-form JSON rider."""
    if isinstance(payloads, DeltaPayload):
        if sspec is not None:
            raise ValueError("whole-row payload with a shard spec")
        plist = [payloads]
    else:
        plist = list(payloads)
        if sspec is None:
            raise ValueError("a payload list requires its ShardedFlatSpec")
        if len(plist) != sspec.n_shards:
            raise ValueError(
                f"{len(plist)} payloads != n_shards {sspec.n_shards}")
    p0 = plist[0]
    for p in plist:
        if (p.size, p.block, p.indices.shape) != \
                (p0.size, p0.block, p0.indices.shape):
            raise ValueError("per-shard payload geometries differ")
    dspec = {
        "version": 1,
        "size": p0.size,
        "block": p0.block,
        "k_per_block": p0.k_per_block,
        "sharded": sspec is not None,
    }
    arrays: Dict[str, np.ndarray] = {
        _FLAT_SPEC: _spec_entry(spec),
        _DELTA_SPEC: np.frombuffer(
            json.dumps(dspec).encode(), dtype=np.uint8),
        _DELTA_IDX: np.stack([p.indices for p in plist]),
        _DELTA_VAL: np.stack([p.values for p in plist]),
        _DELTA_SCL: np.stack([p.scales for p in plist]),
    }
    if sspec is None:
        for k in (_DELTA_IDX, _DELTA_VAL, _DELTA_SCL):
            arrays[k] = arrays[k][0]
    else:
        arrays[_FLAT_SSPEC] = np.frombuffer(
            json.dumps(sspec.to_json()).encode(), dtype=np.uint8)
    if extra is not None:
        arrays[_FLAT_EXTRA] = _extra_entry(extra)
    _atomic_savez(path, arrays)


def load_flat_delta(path: str) -> Tuple[list, Dict[str, Any]]:
    """Load a ``save_flat_delta`` file: returns (payloads, meta) where
    ``payloads`` is the list of ``DeltaPayload`` (length 1 whole-row, S
    sharded) and ``meta`` is the ``flat_row_meta`` dict.  Every geometry
    mismatch — wrong dtypes, inconsistent shapes, out-of-range offsets —
    raises (``DeltaPayload`` validates on construction), as does any zip-
    or entry-level truncation: a torn compressed file is a rejection,
    never a stall or a silent mis-decode."""
    meta = flat_row_meta(path)
    if not meta.get("compressed"):
        raise ValueError(f"{path} is not a compressed flat checkpoint")
    dspec = meta["delta_spec"]
    size, block = int(dspec["size"]), int(dspec["block"])
    kb = int(dspec["k_per_block"])
    sharded = bool(dspec["sharded"])
    with np.load(path) as data:
        for k in (_DELTA_IDX, _DELTA_VAL, _DELTA_SCL):
            if k not in data.files:
                raise ValueError(f"{path}: missing delta entry {k}")
        idx, val, scl = data[_DELTA_IDX], data[_DELTA_VAL], data[_DELTA_SCL]
    if not sharded:
        idx, val, scl = idx[None], val[None], scl[None]
    n = idx.shape[0]
    if sharded:
        ss = ShardedFlatSpec.from_json(meta["shard_spec"])
        if n != ss.n_shards:
            raise ValueError(
                f"{path}: {n} payloads != n_shards {ss.n_shards}")
        if size != ss.shard_len:
            raise ValueError(
                f"{path}: payload size {size} != shard_len {ss.shard_len}")
    if val.shape[0] != n or scl.shape[0] != n:
        raise ValueError(f"{path}: delta entry leading dims disagree")
    payloads = []
    for i in range(n):
        p = DeltaPayload(idx[i], val[i], scl[i], size, block)
        if p.k_per_block != kb:
            raise ValueError(
                f"{path}: k_per_block {p.k_per_block} != declared {kb}")
        payloads.append(p)
    return payloads, meta


def is_flat_compressed(path: str) -> bool:
    with np.load(path) as data:
        return _DELTA_SPEC in data.files
