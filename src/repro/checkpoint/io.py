"""Pytree checkpointing (npz-based; no orbax offline).

Flattens a pytree of arrays into an ``.npz`` keyed by the path string; the
treedef is reconstructed from the keys on load, so files are self-contained
and diff-able.  Used by the host-level Repository (contributors exchange
checkpoints, Fig. 1) and by the training driver.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import path_str

_SEP = "::"
_BF16 = "__bf16__"  # npz has no bfloat16: stored as uint16 bit pattern


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = path_str(path).replace("/", _SEP)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            key += _BF16
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def _unflatten(d: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for key, val in d.items():
        if key.endswith(_BF16):
            key = key[: -len(_BF16)]
            val = val.view(jnp.bfloat16)
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, *, as_jax: bool = True):
    with np.load(path) as data:
        tree = _unflatten({k: data[k] for k in data.files})
    if as_jax:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree
