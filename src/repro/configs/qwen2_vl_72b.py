"""Qwen2-VL-72B language backbone — M-RoPE, dynamic-resolution vision.
[arXiv:2409.12191]  Vision encoder (ViT) is the stub frontend: input_specs
supplies patch embeddings; M-RoPE position ids carry the (t, h, w) streams."""
from .base import ArchConfig, BlockCfg, RopeCfg

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    max_seq_len=32768,
    pattern=(BlockCfg(mixer="attn", ffn="glu"),),
    rope=RopeCfg(theta=1_000_000.0, kind="mrope", mrope_sections=(16, 24, 24)),
    norm="rmsnorm",
    act="silu",
    num_frontend_tokens=256,  # stub ViT patch embeddings
    optimizer="adafactor",
    fsdp=True,
)
