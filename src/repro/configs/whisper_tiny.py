"""Whisper-tiny — encoder-decoder ASR backbone; mel+conv frontend is the
stub (input_specs supplies 1500 frame embeddings).  [arXiv:2212.04356]"""
from .base import ArchConfig, BlockCfg, RopeCfg

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,        # decoder layers
    encoder_layers=4,
    encoder_seq=1500,    # 30s of audio after conv frontend
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    max_seq_len=32768,   # assignment decode shapes exceed the real 448 cap
    pattern=(BlockCfg(mixer="attn", ffn="mlp"),),
    rope=RopeCfg(kind="none"),  # learned absolute positions
    norm="layernorm",
    act="gelu",
    is_encoder_decoder=True,
    num_frontend_tokens=1500,
    tie_embeddings=True,
    optimizer="adamw",
)
