"""Mistral-Nemo-Base-2407 — 12B dense decoder, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407]"""
from .base import ArchConfig, BlockCfg, RopeCfg

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,  # explicit in the model card (not d_model/heads)
    d_ff=14336,
    vocab_size=131072,
    max_seq_len=131072,
    pattern=(BlockCfg(mixer="attn", window=None, ffn="glu"),),
    rope=RopeCfg(theta=1_000_000.0),
    norm="rmsnorm",
    act="silu",
    optimizer="adamw",
    fsdp=True,
)
