from .base import ArchConfig, BlockCfg, InputShape, MoECfg, RopeCfg, SSMCfg
from .registry import ARCH_IDS, all_configs, get_config, reduce_config
from .shapes import SHAPES, get_shape

__all__ = [
    "ArchConfig", "BlockCfg", "InputShape", "MoECfg", "RopeCfg", "SSMCfg",
    "ARCH_IDS", "all_configs", "get_config", "reduce_config", "SHAPES", "get_shape",
]
