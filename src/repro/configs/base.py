"""Architecture / run configuration dataclasses.

Every assigned architecture is described by an :class:`ArchConfig`.  The model
stack is driven entirely by the per-layer ``BlockCfg`` pattern so that dense,
MoE, SSM (RWKV6 / Mamba) and hybrid (Jamba) families are all instances of the
same composable decoder — only Whisper (enc-dec) and the RoBERTa-style
encoder used by the paper reproduction have dedicated stacks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class BlockCfg:
    """Configuration of a single transformer-ish block (mixer + FFN)."""

    mixer: str = "attn"  # "attn" | "mamba" | "rwkv"
    # Sliding-window size for local attention; None => full (causal) attention.
    window: Optional[int] = None
    # FFN flavour: "glu" (SwiGLU/GeGLU), "mlp" (plain 2-layer), "moe",
    # "rwkv_cm" (RWKV channel mix).
    ffn: str = "glu"
    # Per-layer RoPE theta override (gemma3: 10k local / 1M global); None =>
    # ArchConfig.rope.theta.
    rope_theta: Optional[float] = None


@dataclass(frozen=True)
class MoECfg:
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # Weight of the auxiliary load-balance loss (Switch/GShard style).
    aux_loss_weight: float = 0.01
    # Routing implementation: "gshard" (one-hot dispatch einsum, default) or
    # "dense" (all experts on all tokens; only for tiny smoke configs).
    routing: str = "gshard"


@dataclass(frozen=True)
class SSMCfg:
    """State-space / RWKV hyper-parameters."""

    # Mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model / 16)
    # RWKV6
    head_dim: int = 64
    decay_lora: int = 64  # low-rank size of the data-dependent decay MLP


@dataclass(frozen=True)
class RopeCfg:
    theta: float = 10_000.0
    kind: str = "default"  # "default" | "mrope" | "none"
    # M-RoPE (Qwen2-VL): head_dim is split into (t, h, w) sections.
    mrope_sections: Tuple[int, ...] = ()
    # Linear position scaling factor (used to stretch past native ctx in the
    # long_500k dry-run for gemma3; noted in DESIGN.md).
    scaling: float = 1.0


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | audio | hybrid | encoder
    source: str  # citation / model card, from the assignment table

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 => d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    max_seq_len: int = 131_072

    # Per-layer pattern, applied cyclically: layer i uses
    # pattern[i % len(pattern)].
    pattern: Tuple[BlockCfg, ...] = (BlockCfg(),)

    moe: MoECfg = field(default_factory=MoECfg)
    ssm: SSMCfg = field(default_factory=SSMCfg)
    rope: RopeCfg = field(default_factory=RopeCfg)

    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    act: str = "silu"  # "silu" | "gelu"
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # Scale token embeddings by sqrt(d_model) (gemma family).
    scale_embed: bool = False

    # --- encoder / encoder-decoder extras -------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder context (whisper: 1500)
    # Number of stub modality-embedding tokens prepended for vlm/audio.
    num_frontend_tokens: int = 0

    # --- numerics / distribution policy ---------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adafactor | sgd
    remat: bool = True
    # Microbatches the global batch is split into inside train_step
    # (gradient accumulation via lax.scan); 0 => auto from shape table.
    microbatches: int = 0
    # Shard parameters over the data axis too (FSDP) — required >~12B.
    fsdp: bool = False

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm.dt_rank == 0 and self.d_model:
            object.__setattr__(
                self, "ssm", dataclasses.replace(self.ssm, dt_rank=max(1, -(-self.d_model // 16)))
            )

    @property
    def blocks(self) -> Tuple[BlockCfg, ...]:
        """Full per-layer block list (pattern applied cyclically)."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if every layer is windowed attention or an SSM mixer."""
        return all(b.mixer != "attn" or b.window is not None for b in self.pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (whisper is enc-dec)

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for b in self.blocks:
            if b.mixer == "attn":
                total += d * n_q + 2 * d * n_kv + n_q * d
            elif b.mixer == "mamba":
                di = self.ssm.expand * d
                dtr = self.ssm.dt_rank
                total += d * 2 * di + di * self.ssm.d_conv
                total += di * (dtr + 2 * self.ssm.d_state) + dtr * di
                total += di * self.ssm.d_state + di  # A_log, D
                total += di * d
            elif b.mixer == "rwkv":
                # r,k,v,g,o projections + low-rank decay/mix
                total += 5 * d * d + 2 * self.ssm.decay_lora * d * 6
            if b.ffn == "glu":
                total += 3 * d * f
            elif b.ffn == "mlp":
                total += 2 * d * f
            elif b.ffn == "moe":
                total += self.moe.num_experts * 3 * d * f + d * self.moe.num_experts
            elif b.ffn == "rwkv_cm":
                total += 2 * d * f + d * d
            total += 2 * d  # two norms
        total += d  # final norm
        if self.is_encoder_decoder:
            # encoder blocks + decoder cross-attention, rough analytic count
            total += self.encoder_layers * (4 * d * d + 2 * d * f + 2 * d)
            total += self.num_layers * (4 * d * d + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.moe.num_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_total = self.param_count()
        n_moe_layers = sum(1 for b in self.blocks if b.ffn == "moe")
        inactive = (self.moe.num_experts - self.moe.experts_per_token) * 3 * d * f
        return dense_total - n_moe_layers * inactive


@dataclass(frozen=True)
class InputShape:
    """One entry of the assigned input-shape table."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"
