"""RoBERTa-base — the paper's own architecture (§4.2), plus the tiny
variant the laptop-scale reproduction trains for real."""
import dataclasses

from .base import ArchConfig, BlockCfg, RopeCfg

CONFIG = ArchConfig(
    name="roberta-base",
    family="encoder",
    source="hf:roberta-base (Liu et al., 2019)",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=50265,
    max_seq_len=512,
    pattern=(BlockCfg(mixer="attn", ffn="mlp"),),
    rope=RopeCfg(kind="none"),  # learned absolute positions
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    optimizer="adamw",
)

# Tiny variant actually trained in benchmarks/ (CPU budget).
TINY = dataclasses.replace(
    CONFIG,
    name="roberta-tiny",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    max_seq_len=64,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
