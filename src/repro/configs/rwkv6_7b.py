"""RWKV6 "Finch" 7B — attention-free, data-dependent decay time-mix +
channel-mix FFN.  [arXiv:2404.05892]"""
from .base import ArchConfig, BlockCfg, RopeCfg, SSMCfg

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,  # rwkv head size
    d_ff=14336,
    vocab_size=65536,
    max_seq_len=1048576,
    pattern=(BlockCfg(mixer="rwkv", ffn="rwkv_cm"),),
    ssm=SSMCfg(head_dim=64, decay_lora=64),
    rope=RopeCfg(kind="none"),
    norm="layernorm",
    act="relu",
    optimizer="adamw",
    fsdp=True,
)
