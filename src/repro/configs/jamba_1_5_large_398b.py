"""Jamba-1.5-Large — 398B hybrid: 1:7 attention:Mamba interleave, MoE (16
experts top-2) on every other layer.  [arXiv:2403.19887]"""
from .base import ArchConfig, BlockCfg, MoECfg, RopeCfg, SSMCfg

# Period of 8: attention at position 4 (Jamba places attn mid-period),
# Mamba elsewhere; MoE every other layer.
_PATTERN = tuple(
    BlockCfg(
        mixer="attn" if i == 4 else "mamba",
        ffn="moe" if i % 2 == 1 else "glu",
    )
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    max_seq_len=262144,
    pattern=_PATTERN,
    moe=MoECfg(num_experts=16, experts_per_token=2),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2),
    rope=RopeCfg(kind="none"),  # Jamba uses no positional encoding
    norm="rmsnorm",
    act="silu",
    optimizer="adafactor",
    fsdp=True,
)
