"""IBM Granite 3.0 1B-A400M — fine-grained MoE, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ArchConfig, BlockCfg, MoECfg, RopeCfg

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,  # per-expert FFN width (fine-grained experts)
    vocab_size=49155,
    max_seq_len=32768,
    pattern=(BlockCfg(mixer="attn", ffn="moe"),),
    moe=MoECfg(num_experts=32, experts_per_token=8),
    rope=RopeCfg(theta=10_000.0),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
    optimizer="adamw",
)
