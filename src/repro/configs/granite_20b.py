"""IBM Granite 20B (code) — llama-arch dense decoder with MQA (kv=1).
[arXiv:2405.04324]"""
from .base import ArchConfig, BlockCfg, RopeCfg

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    max_seq_len=8192,
    pattern=(BlockCfg(mixer="attn", ffn="mlp"),),
    rope=RopeCfg(theta=10_000.0),
    norm="layernorm",
    act="gelu",
    optimizer="adamw",
    fsdp=True,
)
