"""Architecture registry: ``--arch <id>`` resolution + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from .base import ArchConfig, BlockCfg, MoECfg, SSMCfg

ARCH_IDS = (
    "mistral-nemo-12b",
    "granite-moe-1b-a400m",
    "qwen2-vl-72b",
    "gemma3-1b",
    "stablelm-12b",
    "granite-20b",
    "mixtral-8x7b",
    "rwkv6-7b",
    "whisper-tiny",
    "jamba-1.5-large-398b",
    # the paper's own architecture (RoBERTa-base encoder)
    "roberta-base",
)

_MODULES = {i: "repro.configs." + i.replace("-", "_").replace(".", "_") for i in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduce_config(cfg: ArchConfig, *, d_model: int = 128, vocab: int = 512) -> ArchConfig:
    """Smoke-test variant: ≤`period` layers (so every block type in the
    pattern is exercised), d_model ≤ 512, ≤4 experts, tiny vocab, f32."""
    period = len(cfg.pattern)
    num_layers = 2 if period == 1 else min(period, 8)
    heads = max(2, min(4, cfg.num_heads))
    kv = 1 if cfg.num_kv_heads == 1 else min(2, heads)
    head_dim = d_model // heads
    moe = cfg.moe
    pattern = cfg.pattern[:num_layers] if period > 1 else cfg.pattern
    if moe.num_experts:
        ne = min(4, moe.num_experts)
        kt = min(2, moe.experts_per_token)
        # no-drop capacity (= T) so decode exactly matches prefill in tests
        moe = dataclasses.replace(
            moe, num_experts=ne, experts_per_token=kt, capacity_factor=float(ne) / kt
        )
    ssm = dataclasses.replace(cfg.ssm, head_dim=min(32, cfg.ssm.head_dim), d_state=8, decay_lora=8, dt_rank=8)
    rope = cfg.rope
    if rope.kind == "mrope":
        half = head_dim // 2
        t = half // 4
        rope = dataclasses.replace(rope, mrope_sections=(t, (half - t) // 2, half - t - (half - t) // 2))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=max(4 * d_model // 2, 64) if cfg.d_ff else 0,
        vocab_size=vocab,
        max_seq_len=256,
        pattern=pattern,
        moe=moe,
        ssm=ssm,
        rope=rope,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16) if cfg.encoder_seq else 0,
        num_frontend_tokens=min(cfg.num_frontend_tokens, 4),
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        fsdp=False,
        microbatches=0,
        optimizer="adamw",
    )
