"""Gemma 3 1B — 5:1 local:global attention interleave, 512-token sliding
window on local layers, dual RoPE theta (10k local / 1M global), 262k vocab.
[hf:google/gemma-3-1b-pt]"""
from .base import ArchConfig, BlockCfg, RopeCfg

_LOCAL = BlockCfg(mixer="attn", window=512, ffn="glu", rope_theta=10_000.0)
_GLOBAL = BlockCfg(mixer="attn", window=None, ffn="glu", rope_theta=1_000_000.0)

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    max_seq_len=131072,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    rope=RopeCfg(theta=1_000_000.0),
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    optimizer="adamw",
)
