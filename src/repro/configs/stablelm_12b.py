"""StableLM-2 12B — dense decoder, LayerNorm, GQA kv=8.
[hf:stabilityai/stablelm-2-1_6b (12B variant of the family)]"""
from .base import ArchConfig, BlockCfg, RopeCfg

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-12b",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    max_seq_len=4096,
    pattern=(BlockCfg(mixer="attn", ffn="glu"),),
    rope=RopeCfg(theta=10_000.0),
    norm="layernorm",
    act="silu",
    optimizer="adamw",
    fsdp=True,
)
