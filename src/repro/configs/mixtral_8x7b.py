"""Mixtral-8x7B — 8-expert top-2 MoE with 4096-token sliding-window
attention (per the assignment spec).  [arXiv:2401.04088]"""
from .base import ArchConfig, BlockCfg, MoECfg, RopeCfg

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    max_seq_len=131072,
    pattern=(BlockCfg(mixer="attn", window=4096, ffn="moe"),),
    moe=MoECfg(num_experts=8, experts_per_token=2),
    rope=RopeCfg(theta=1_000_000.0),
    norm="rmsnorm",
    act="silu",
    optimizer="adamw",
    fsdp=True,
)
