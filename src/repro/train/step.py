"""Train / eval / serve step builders for the decoder-LM families.

``make_train_step`` returns a pure function ``(state, batch) -> (state,
metrics)`` with gradient accumulation over microbatches via ``lax.scan`` —
the global batch never materializes activations at once (required for
train_4k on the big archs).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import forward_lm, init_cache
from repro.models import whisper as W
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.train.losses import lm_loss


def make_train_state(params, optimizer: Optimizer) -> Dict[str, Any]:
    return {"params": params, "opt": optimizer.init(params)}


def _lm_loss_fn(cfg: ArchConfig, params, batch, aux_weight: float):
    if cfg.is_encoder_decoder:
        enc_out = W.whisper_encode(cfg, params, batch["frames"])
        logits, aux, _ = W.whisper_decode(cfg, params, batch["tokens"], enc_out)
    else:
        logits, aux, _ = forward_lm(
            cfg, params, batch["tokens"],
            positions=batch.get("positions"),
            extra_embeds=batch.get("extra_embeds"),
        )
    loss = lm_loss(logits, batch["tokens"], batch.get("mask"))
    return loss + aux_weight * aux, (loss, aux)


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    clip_norm: float = 1.0,
    aux_weight: Optional[float] = None,
    grad_sync: Optional[Callable] = None,
    grad_shardings=None,
) -> Callable:
    """Build the jittable train step.

    ``grad_sync(grads) -> grads``: hook the distribution strategy uses to
    all-reduce gradients across the right mesh axes (sync DP: all of them;
    ColD local step: only within-contributor axes).  Identity by default —
    under ``jax.jit`` + sharded batch, GSPMD inserts the reduction implied by
    the output sharding instead.

    ``grad_shardings``: pytree of NamedSharding matching params.  Pins the
    f32 gradient accumulator of the microbatch scan to the parameter layout —
    without it GSPMD replicates the accumulator per chip (§Perf iteration 1:
    +350 GiB peak and a 30x per-chip FLOP skew on granite-20b).
    """
    aux_w = cfg.moe.aux_loss_weight if aux_weight is None else aux_weight

    def loss_fn(params, mb):
        return _lm_loss_fn(cfg, params, mb, aux_w)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, grad_shardings)

    def train_step(state, batch):
        params = state["params"]
        if microbatches > 1:
            B_global = batch["tokens"].shape[0]

            def mb_slice(i, x):
                # batch dim is axis 0 except for M-RoPE positions [3, B, S]
                axis = 0 if x.shape[0] == B_global else 1
                mb = x.shape[axis] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=axis)

            def body(carry, i):
                gacc, lacc = carry
                mb = jax.tree.map(lambda x: mb_slice(i, x), batch)
                (tot, (loss, aux)), grads = grad_fn(params, mb)
                gacc = _pin(jax.tree.map(jnp.add, gacc, grads))
                return (gacc, lacc + loss), None

            g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), jnp.arange(microbatches)
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
        else:
            (tot, (loss, aux)), grads = grad_fn(params, batch)
        if grad_sync is not None:
            grads = grad_sync(grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, new_opt = optimizer.update(grads, state["opt"], params)
        new_params = jax.tree.map(jnp.add, params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ArchConfig) -> Callable:
    def eval_step(params, batch):
        loss, _ = _lm_loss_fn(cfg, params, batch, 0.0)[0], None
        return loss

    return eval_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    """Forward pass of the full prompt (inference-prefill shape): logits only,
    no gradient, no cache materialization beyond the step output."""

    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            enc_out = W.whisper_encode(cfg, params, batch["frames"])
            logits, _, _ = W.whisper_decode(cfg, params, batch["tokens"], enc_out)
        else:
            logits, _, _ = forward_lm(
                cfg, params, batch["tokens"],
                positions=batch.get("positions"),
                extra_embeds=batch.get("extra_embeds"),
            )
        # return only the last-position logits (next-token distribution)
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    """One-token decode against a KV/state cache of length seq_len."""

    def serve_step(params, cache, tokens, cache_index):
        if cfg.is_encoder_decoder:
            logits, _, new_cache = W.whisper_decode(
                cfg, params, tokens, cache=cache, cache_index=cache_index
            )
        else:
            logits, _, new_cache = forward_lm(
                cfg, params, tokens, cache=cache, cache_index=cache_index
            )
        return logits[:, -1], new_cache

    return serve_step
