"""Tiny MLM pretraining — produces the "pretrained model" θ₀ the ColD
Fusion experiments start from (the stand-in for RoBERTa-base).

Masked-token prediction over the synthetic token mixture teaches the
encoder the token co-occurrence / motif structure the way MLM teaches
RoBERTa linguistic structure, so "pretrained vs ColD-fused" comparisons
have the same shape as the paper's.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.synthetic import SyntheticSuite, mask_for_mlm
from repro.models import encoder as E
from repro.optim.optimizers import adamw, clip_by_global_norm, warmup_cosine_lr
from repro.train.losses import softmax_xent


def pretrain_mlm(
    cfg: ArchConfig,
    suite: SyntheticSuite,
    *,
    steps: int = 400,
    batch_size: int = 64,
    seq_len: int = 24,
    lr: float = 2e-3,
    seed: int = 0,
) -> Tuple[Dict, Dict]:
    """Returns (body, metrics)."""
    key = jax.random.PRNGKey(seed)
    body = E.init_encoder_body(cfg, key)
    opt = adamw(warmup_cosine_lr(lr, warmup=max(10, steps // 20), total=steps))
    opt_state = opt.init(body)

    def loss_fn(body, batch):
        logits = E.mlm_logits(cfg, body, batch["inputs"])
        return softmax_xent(logits, batch["targets"], batch["mask"])

    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def step(body, opt_state, batch):
        loss, grads = grad_fn(body, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, body)
        return jax.tree.map(jnp.add, body, updates), opt_state, loss

    rng = np.random.default_rng(seed)
    stream = suite.lm_stream(steps * batch_size, seq_len, seed=seed + 17)
    losses = []
    for i in range(steps):
        toks = stream[i * batch_size : (i + 1) * batch_size]
        inputs, targets, mask = mask_for_mlm(toks, rng)
        body, opt_state, loss = step(
            body, opt_state, {"inputs": inputs, "targets": targets, "mask": mask}
        )
        losses.append(float(loss))
    return body, {"loss": losses}
