"""Loss functions."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array, mask=None, z_loss: float = 0.0):
    """Mean token cross-entropy.  logits [..., V] f-any, labels [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(logz)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(logits: jax.Array, tokens: jax.Array, mask=None):
    """Next-token prediction: logits [B,S,V] vs tokens [B,S]."""
    shift_logits = logits[:, :-1]
    shift_labels = tokens[:, 1:]
    shift_mask = None if mask is None else mask[:, 1:]
    return softmax_xent(shift_logits, shift_labels, shift_mask)


def cls_loss(logits: jax.Array, labels: jax.Array):
    return softmax_xent(logits, labels)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
