"""Standard multitask baseline (paper §4.2): ONE shared body trained jointly
over all datasets with a dedicated classification head per dataset — the
centralized upper-baseline ColD Fusion is compared against (Fig. 2).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import encoder as E
from repro.optim.optimizers import adamw, clip_by_global_norm, constant_lr
from repro.train.losses import cls_loss


def train_multitask(
    cfg: ArchConfig,
    body,
    datasets: Sequence[Tuple[int, np.ndarray, np.ndarray, int]],  # (task_id, x, y, n_cls)
    *,
    steps: int,
    batch_size: int = 32,
    lr: float = 5e-4,
    seed: int = 0,
) -> Tuple[Dict, Dict[int, Dict]]:
    """Returns (body, heads keyed by task_id).

    Each step samples one dataset uniformly and takes one gradient step on
    the shared body + that dataset's head (standard round-robin multitask).
    """
    opt = adamw(constant_lr(lr))
    heads = {}
    for tid, _, _, n_cls in datasets:
        heads[tid] = E.init_cls_head(cfg, jax.random.PRNGKey(seed * 997 + tid), n_cls)

    # one jitted step per head-width (jit cache keyed by shapes)
    def make_step():
        def loss_fn(trainable, batch):
            logits = E.classify(cfg, trainable["body"], trainable["head"], batch["tokens"])
            return cls_loss(logits, batch["labels"])

        grad_fn = jax.value_and_grad(loss_fn)

        @jax.jit
        def step(trainable, opt_state, batch):
            loss, grads = grad_fn(trainable, batch)
            grads, _ = clip_by_global_norm(grads, 1.0)
            upd, opt_state = opt.update(grads, opt_state, trainable)
            return jax.tree.map(jnp.add, trainable, upd), opt_state, loss

        return step

    step_fn = make_step()
    rng = np.random.default_rng(seed)
    # ONE shared Adam state for the body (true joint multitask optimization);
    # per-task states only for the private heads.
    body_opt = opt.init({"body": body})
    head_opts = {tid: opt.init({"head": heads[tid]}) for tid, *_ in datasets}
    for it in range(steps):
        tid, x, y, _ = datasets[rng.integers(len(datasets))]
        idx = rng.integers(0, len(x), size=batch_size)
        batch = {"tokens": x[idx], "labels": y[idx]}
        trainable = {"body": body, "head": heads[tid]}
        opt_state = {
            "step": body_opt["step"],
            "m": {"body": body_opt["m"]["body"], "head": head_opts[tid]["m"]["head"]},
            "v": {"body": body_opt["v"]["body"], "head": head_opts[tid]["v"]["head"]},
        }
        trainable, opt_state, loss = step_fn(trainable, opt_state, batch)
        body = trainable["body"]
        heads[tid] = trainable["head"]
        body_opt = {"step": opt_state["step"],
                    "m": {"body": opt_state["m"]["body"]},
                    "v": {"body": opt_state["v"]["body"]}}
        head_opts[tid] = {"step": opt_state["step"],
                          "m": {"head": opt_state["m"]["head"]},
                          "v": {"head": opt_state["v"]["head"]}}
    return body, heads
