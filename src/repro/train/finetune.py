"""Classifier finetuning driver (the paper's §4.3 finetuning procedure,
scaled to the tiny synthetic suite).

Used both (a) inside the ColD Fusion loop (each contributor finetunes the
base model on their dataset) and (b) for evaluation of a base model —
full finetuning or linear probing ("ColD-Frozen").

Jitted steps are cached per (config, num_classes, frozen, batch shape) so
the 30-iteration × many-contributor loops don't recompile.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import batches
from repro.models import encoder as E
from repro.optim.optimizers import adamw, clip_by_global_norm, linear_decay_lr
from repro.train.losses import accuracy, cls_loss


@functools.lru_cache(maxsize=None)
def _steps(cfg: ArchConfig, num_classes: int, frozen: bool, lr: float, decay: float):
    opt = adamw(linear_decay_lr(lr, decay))

    def loss_fn(trainable, static_body, batch):
        body = trainable.get("body", static_body)
        logits = E.classify(cfg, body, trainable["head"], batch["tokens"])
        return cls_loss(logits, batch["labels"]), logits

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def train_step(trainable, opt_state, static_body, batch):
        (loss, logits), grads = grad_fn(trainable, static_body, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        trainable = jax.tree.map(jnp.add, trainable, updates)
        return trainable, opt_state, loss, accuracy(logits, batch["labels"])

    @jax.jit
    def eval_step(body, head, batch):
        logits = E.classify(cfg, body, head, batch["tokens"])
        return jnp.sum((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.int32))

    return opt, train_step, eval_step


def finetune(
    cfg: ArchConfig,
    body,
    head,
    x: np.ndarray,
    y: np.ndarray,
    *,
    steps: int,
    batch_size: int = 32,
    lr: float = 5e-4,
    lr_decay: float = 0.0,
    frozen_body: bool = False,
    seed: int = 0,
) -> Tuple[Dict, Dict, Dict]:
    """Finetune (body, head) on (x, y).  Returns (body, head, metrics).

    ``frozen_body=True`` trains only the classification head — the paper's
    linear-probing evaluation (ColD-Frozen).
    """
    num_classes = int(head["out"].shape[-1])
    opt, train_step, _ = _steps(cfg, num_classes, frozen_body, lr, lr_decay)
    trainable = {"head": head} if frozen_body else {"head": head, "body": body}
    opt_state = opt.init(trainable)
    rng = np.random.default_rng(seed)
    losses, accs = [], []
    it = batches(x, y, batch_size, rng=rng, epochs=10_000)  # steps bound below
    for _ in range(steps):
        b = next(it)
        trainable, opt_state, loss, acc = train_step(trainable, opt_state, body, b)
        losses.append(float(loss))
        accs.append(float(acc))
    new_body = trainable.get("body", body)
    return new_body, trainable["head"], {"loss": losses, "train_acc": accs}


def compute_fisher(
    cfg: ArchConfig, body, head, x: np.ndarray, y: np.ndarray,
    *, batches_n: int = 8, batch_size: int = 32, seed: int = 0,
):
    """Diagonal empirical Fisher of the body params (mean squared grad of the
    log-likelihood over minibatches) — the contributor-side statistic for
    Fisher-weighted fusion (Matena & Raffel 2021; paper §8 future work)."""
    from repro.train.losses import cls_loss

    def loss_fn(body, batch):
        logits = E.classify(cfg, body, head, batch["tokens"])
        return cls_loss(logits, batch["labels"])

    grad_fn = jax.jit(jax.grad(loss_fn))
    rng = np.random.default_rng(seed)
    fisher = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), body)
    for b in list(batches(x, y, batch_size, rng=rng))[:batches_n]:
        g = grad_fn(body, b)
        fisher = jax.tree.map(lambda f, gi: f + jnp.square(gi.astype(jnp.float32)), fisher, g)
    return jax.tree.map(lambda f: f / batches_n, fisher)


def evaluate(cfg: ArchConfig, body, head, x: np.ndarray, y: np.ndarray, batch_size: int = 64) -> float:
    num_classes = int(head["out"].shape[-1])
    _, _, eval_step = _steps(cfg, num_classes, False, 1e-3, 0.0)
    correct, total = 0, 0
    for b in batches(x, y, batch_size, drop_remainder=False):
        correct += int(eval_step(body, head, b))
        total += len(b["labels"])
    return correct / max(total, 1)
