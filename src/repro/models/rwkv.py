"""RWKV6 ("Finch", arXiv:2404.05892) — attention-free mixer with
data-dependent decay, plus the RWKV channel-mix FFN.

Time-mix recurrence per head (state S in R^{hd x hd}, f32):

    out_t = r_t · (diag(u) k_t v_tᵀ + S_t)
    S_t+1 = diag(w_t) S_t + k_t v_tᵀ

with per-token per-channel decay w_t = exp(-exp(w0 + LoRA_w(x̄_t))) — the
data-dependent decay that distinguishes RWKV6 from RWKV4/5.  Token-shift
interpolation (ddlerp) is applied with data-dependent low-rank mixes.
``lax.scan`` streams the recurrence; the blocked Pallas kernel
(`repro.kernels.rwkv6_scan`) is the TPU hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init


def num_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.ssm.head_dim


def _lora_init(key, d: int, r: int, dtype):
    k1, k2 = jax.random.split(key)
    return {"a": dense_init(k1, d, r, dtype), "b": (jax.random.normal(k2, (r, d), jnp.float32) * 0.01).astype(dtype)}


def _lora(p, x):
    return jnp.tanh(x @ p["a"]) @ p["b"]


def init_time_mix(cfg: ArchConfig, key, dtype):
    d, r = cfg.d_model, cfg.ssm.decay_lora
    ks = jax.random.split(key, 12)
    H, hd = num_heads(cfg), cfg.ssm.head_dim
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),  # static lerp base (w,k,v,r,g)
        "lora_mix": _lora_init(ks[1], d, 32, dtype),  # shared data-dependent mix delta
        "lora_w": _lora_init(ks[2], d, r, dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "u": (jax.random.normal(ks[3], (H, hd), jnp.float32) * 0.1),  # "bonus" for current token
        "wr": dense_init(ks[4], d, d, dtype),
        "wk": dense_init(ks[5], d, d, dtype),
        "wv": dense_init(ks[6], d, d, dtype),
        "wg": dense_init(ks[7], d, d, dtype),
        "wo": dense_init(ks[8], d, d, dtype),
        "ln_scale": jnp.ones((d,), dtype),  # per-head group norm
        "ln_bias": jnp.zeros((d,), dtype),
    }


def _token_shift(x, last=None):
    """Previous-token features; ``last`` [B,1,D] carries decode state."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """Data-dependent lerp between current (x) and shifted (xx) features."""
    base = xx + (x - xx) * p["mu"][0].astype(x.dtype)  # coarse mix for the delta net
    delta = _lora(p["lora_mix"], base)  # [B,S,D]
    mixes = []
    for i in range(5):
        m = p["mu"][i].astype(x.dtype) + delta
        mixes.append(xx + (x - xx) * m)
    return mixes  # order: w,k,v,r,g


def time_mix_fwd(cfg: ArchConfig, p, x, *, state=None, return_state=False):
    """x: [B,S,D] -> (y [B,S,D], new_state).  state={"S":[B,H,hd,hd] f32,
    "shift":[B,1,D]}."""
    B, S, D = x.shape
    H, hd = num_heads(cfg), cfg.ssm.head_dim
    last = state["shift"] if state is not None else None
    xx = _token_shift(x, last)
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)

    r = (xr @ p["wr"]).reshape(B, S, H, hd)
    k = (xk @ p["wk"]).reshape(B, S, H, hd)
    v = (xv @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay in (0,1): exp(-exp(.))
    w = jnp.exp(-jnp.exp(p["w0"] + _lora(p["lora_w"], xw).astype(jnp.float32)))
    w = w.reshape(B, S, H, hd)
    u = p["u"]  # [H,hd]

    S0 = state["S"] if state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(Sm, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,hd] each
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B,H,hd,hd]
        y = jnp.einsum("bhi,bhij->bhj", r_t, u[None, :, :, None] * kv + Sm)
        Sm = w_t[..., :, None] * Sm + kv
        return Sm, y

    inputs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w)
    )  # [S,B,H,hd]
    ST, ys = jax.lax.scan(step, S0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)  # [B,S,D]

    # per-head group norm
    yh = y.reshape(B, S, H, hd)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, D) * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)

    out = (y.astype(x.dtype) * g) @ p["wo"]
    new_state = None
    if return_state:
        new_state = {"S": ST, "shift": x[:, -1:]}
    return out, new_state


def init_channel_mix(cfg: ArchConfig, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], d, f, dtype),
        "wv": dense_init(ks[1], f, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
    }


def channel_mix_fwd(cfg: ArchConfig, p, x, *, last=None, return_state=False):
    xx = _token_shift(x, last)
    xk = xx + (x - xx) * p["mu_k"]
    xr = xx + (x - xx) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return (out, x[:, -1:]) if return_state else (out, None)


def init_rwkv_state(cfg: ArchConfig, batch: int, dtype):
    H, hd = num_heads(cfg), cfg.ssm.head_dim
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "cm_shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }
