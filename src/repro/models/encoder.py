"""RoBERTa-style bidirectional encoder + classification heads — the model
family the paper actually runs ColD Fusion on (RoBERTa-base, §4.2).

The laptop-scale reproduction instantiates a tiny variant of this family and
feeds it the synthetic multitask suite.  Design notes:

* ColD Fusion averages the *shared body*; each contributor keeps a private
  per-dataset classification head (the paper's multitask baseline likewise
  uses dedicated heads, §4.2).
* Linear probing (paper's "ColD-Frozen", §4.4) = training only the head with
  the body frozen — see ``repro.train.probe``.
* Pre-LayerNorm is used (vs RoBERTa's post-LN) for optimization stability at
  tiny scale; noted as a deviation in DESIGN.md §6.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def init_encoder_body(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2 + cfg.num_layers)
    params: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "pos": (jax.random.normal(ks[1], (cfg.max_seq_len, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "final_norm": L.init_norm(cfg, dtype),
        "layers": {},
    }
    for i in range(cfg.num_layers):
        k1, k2 = jax.random.split(ks[2 + i])
        params["layers"][f"layer{i}"] = {
            "norm1": L.init_norm(cfg, dtype),
            "attn": L.init_attention(cfg, k1, dtype),
            "norm2": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(cfg, k2, dtype),
        }
    return params


def encode(cfg: ArchConfig, body, tokens: jax.Array) -> jax.Array:
    """tokens [B, S] -> hidden states [B, S, D]."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    x = body["embed"][tokens].astype(cdt) + body["pos"][None, :S].astype(cdt)
    for i in range(cfg.num_layers):
        p = body["layers"][f"layer{i}"]
        h = L.norm_fwd(cfg, p["norm1"], x)
        out, _ = L.attention_fwd(cfg, p["attn"], h, angles=None, causal=False)
        x = x + out
        h2 = L.norm_fwd(cfg, p["norm2"], x)
        x = x + L.mlp_fwd(cfg, p["mlp"], h2)
    return L.norm_fwd(cfg, body["final_norm"], x)


def init_cls_head(cfg: ArchConfig, key, num_classes: int) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "dense": L.dense_init(k1, cfg.d_model, cfg.d_model, dtype),
        "out": L.dense_init(k2, cfg.d_model, num_classes, dtype),
        "bias": jnp.zeros((num_classes,), dtype),
    }


def classify(cfg: ArchConfig, body, head, tokens: jax.Array) -> jax.Array:
    """Sequence classification from mean-pooled hidden states -> [B, C]."""
    h = encode(cfg, body, tokens)
    pooled = jnp.tanh(jnp.mean(h, axis=1) @ head["dense"])
    return pooled @ head["out"] + head["bias"]


def mlm_logits(cfg: ArchConfig, body, tokens: jax.Array) -> jax.Array:
    """Masked-LM logits with tied embeddings (used to 'pretrain' the tiny
    model before the ColD experiments)."""
    h = encode(cfg, body, tokens)
    return h @ body["embed"].T.astype(h.dtype)
