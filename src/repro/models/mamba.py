"""Mamba (selective SSM) mixer — the recurrent 7/8 of Jamba's layer stack.

Faithful to Gu & Dao 2023 / Jamba (arXiv:2403.19887): input-dependent
(Δ, B, C), depthwise causal conv, gated output.  The sequence dimension is
processed with ``lax.scan`` (TPU-friendly streaming recurrence; the chunked
parallel-scan variant is a §Perf lever).  Decode keeps an O(1) state:
(h [B, d_inner, d_state], conv window [B, d_conv-1, d_inner]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def init_mamba(cfg: ArchConfig, key, dtype):
    d, di, ds = cfg.d_model, d_inner(cfg), cfg.ssm.d_state
    dtr, dc = cfg.ssm.dt_rank, cfg.ssm.d_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _ssm_inputs(cfg: ArchConfig, p, xc):
    """xc: [B, S, di] post-conv activations -> (dA, dBx, C) scan inputs."""
    ds, dtr = cfg.ssm.d_state, cfg.ssm.dt_rank
    proj = xc @ p["x_proj"]  # [B,S,dtr+2ds]
    dt_low, Bmat, Cmat = jnp.split(proj.astype(jnp.float32), [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"])  # [di, ds]
    dA = jnp.exp(dt[..., None] * A)  # [B,S,di,ds]
    # dt*x [B,S,di] outer B [B,S,ds] -> [B,S,di,ds]
    dBx = (dt * xc.astype(jnp.float32))[..., :, None] * Bmat[..., None, :]
    return dA, dBx, Cmat


def _conv(cfg: ArchConfig, p, x, prepend=None):
    """Depthwise causal conv over time.  x: [B,S,di]."""
    dc = cfg.ssm.d_conv
    pad = x[:, :0] if prepend is not None else jnp.zeros_like(x[:, :1]).repeat(dc - 1, axis=1)
    ctx = jnp.concatenate([prepend if prepend is not None else pad, x], axis=1)
    # sliding window dot with conv_w [dc, di]
    out = jnp.zeros_like(x)
    for i in range(dc):
        out = out + ctx[:, i : i + x.shape[1]] * p["conv_w"][i]
    return jax.nn.silu(out + p["conv_b"])


def mamba_fwd(cfg: ArchConfig, p, x, *, state=None, return_state=False):
    """x: [B, S, D] -> [B, S, D].

    ``state``: optional dict {"h": [B,di,ds] f32, "conv": [B,dc-1,di]} for
    incremental decoding (S may be 1).  Returns (y, new_state|None).
    """
    B, S, _ = x.shape
    di, ds, dc = d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # [B,S,di] each
    prepend = state["conv"] if state is not None else None
    xc = _conv(cfg, p, xi, prepend=prepend)
    dA, dBx, Cmat = _ssm_inputs(cfg, p, xc)  # [B,S,di,ds]x2, [B,S,ds]

    h0 = state["h"] if state is not None else jnp.zeros((B, di, ds), jnp.float32)

    def step(h, inp):
        dA_t, dBx_t, C_t = inp  # [B,di,ds],[B,di,ds],[B,ds]
        h = dA_t * h + dBx_t
        y = jnp.einsum("bns,bs->bn", h, C_t)
        return h, y

    inputs = (
        jnp.swapaxes(dA, 0, 1),
        jnp.swapaxes(dBx, 0, 1),
        jnp.swapaxes(Cmat, 0, 1),
    )
    hT, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.swapaxes(ys, 0, 1)  # [B,S,di]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = None
    if return_state:
        conv_ctx = jnp.concatenate(
            [prepend if prepend is not None else jnp.zeros((B, dc - 1, di), x.dtype), xi], axis=1
        )[:, -(dc - 1) :]
        new_state = {"h": hT, "conv": conv_ctx}
    return out, new_state


def init_mamba_state(cfg: ArchConfig, batch: int, dtype):
    di, ds, dc = d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
    }
