"""Composable decoder LM.

A single stack covers the dense / MoE / SSM / hybrid / VLM families: the
per-layer :class:`BlockCfg` pattern selects the mixer (GQA attention with
optional sliding window, Mamba, RWKV6 time-mix) and FFN (SwiGLU, MLP, MoE,
RWKV channel-mix) of each layer.

Layers are grouped into repeating *periods* (the pattern) and the full
periods are executed with ``lax.scan`` over stacked parameters — compile
time and HLO size scale with the pattern length, not ``num_layers`` (the
MaxText-style scan-over-layers idiom).  The remainder layers (when
``num_layers % period != 0``) run unrolled.

Decode-time state (attention KV caches, Mamba/RWKV recurrent states) is
stacked the same way and threaded through the scan.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockCfg
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv as R

# §Perf lever (EXPERIMENTS.md §Perf): window-sized ring-buffer KV caches for
# sliding-window layers; off by default for baseline reproducibility.
RING_CACHE = os.environ.get("REPRO_OPT_RING_CACHE", "0") == "1"


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ArchConfig, blk: BlockCfg, key, dtype) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg, dtype), "norm2": L.init_norm(cfg, dtype)}
    if blk.mixer == "attn":
        p["attn"] = L.init_attention(cfg, k1, dtype)
    elif blk.mixer == "mamba":
        p["mamba"] = M.init_mamba(cfg, k1, dtype)
    elif blk.mixer == "rwkv":
        p["rwkv"] = R.init_time_mix(cfg, k1, dtype)
    else:
        raise ValueError(f"unknown mixer {blk.mixer!r}")
    if blk.ffn == "glu":
        p["glu"] = L.init_glu(cfg, k2, dtype)
    elif blk.ffn == "mlp":
        p["mlp"] = L.init_mlp(cfg, k2, dtype)
    elif blk.ffn == "moe":
        p["moe"] = MOE.init_moe(cfg, k2, dtype)
    elif blk.ffn == "rwkv_cm":
        p["rwkv_cm"] = R.init_channel_mix(cfg, k2, dtype)
    else:
        raise ValueError(f"unknown ffn {blk.ffn!r}")
    return p


def split_layers(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_full_periods, n_tail_layers)."""
    return cfg.num_layers // cfg.period, cfg.num_layers % cfg.period


def init_lm(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    n_full, n_tail = split_layers(cfg)
    keys = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)

    lkeys = jax.random.split(keys[2], cfg.num_layers)
    scan_params: Dict[str, Any] = {}
    for pos, blk in enumerate(cfg.pattern):
        if n_full == 0:
            break
        per_layer = [
            _init_block(cfg, blk, lkeys[rep * cfg.period + pos], dtype) for rep in range(n_full)
        ]
        scan_params[f"pos{pos}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    params["scan"] = scan_params
    tail: Dict[str, Any] = {}
    for t in range(n_tail):
        li = n_full * cfg.period + t
        tail[f"layer{li}"] = _init_block(cfg, cfg.blocks[li], lkeys[li], dtype)
    params["tail"] = tail
    return params


# ---------------------------------------------------------------------------
# caches (decode state)
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ArchConfig, blk: BlockCfg, batch: int, max_len: int, dtype):
    if blk.mixer == "attn":
        hd, nkv = cfg.head_dim, cfg.num_kv_heads
        length = max_len
        # §Perf lever: sliding-window layers keep a ring buffer of exactly
        # `window` slots (mixtral long_500k: 524288 -> 4096 per layer).
        if RING_CACHE and blk.window is not None:
            length = min(max_len, blk.window)
        return {
            "k": jnp.zeros((batch, length, nkv, hd), dtype),
            "v": jnp.zeros((batch, length, nkv, hd), dtype),
        }
    if blk.mixer == "mamba":
        return M.init_mamba_state(cfg, batch, dtype)
    if blk.mixer == "rwkv":
        return R.init_rwkv_state(cfg, batch, dtype)
    raise ValueError(blk.mixer)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    n_full, n_tail = split_layers(cfg)
    cache: Dict[str, Any] = {"scan": {}, "tail": {}}
    for pos, blk in enumerate(cfg.pattern):
        if n_full == 0:
            break
        one = _init_block_cache(cfg, blk, batch, max_len, dtype)
        cache["scan"][f"pos{pos}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_full,) + x.shape).copy(), one
        )
    for t in range(n_tail):
        li = n_full * cfg.period + t
        cache["tail"][f"layer{li}"] = _init_block_cache(cfg, cfg.blocks[li], batch, max_len, dtype)
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _rope_angles(cfg: ArchConfig, positions, seq: int, batch: int):
    """Pre-compute rotation angles for every distinct theta in the pattern.

    Returns {theta: [B, S, head_dim//2]} or None for rope-free models.
    """
    if cfg.rope.kind == "none":
        return None
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
    if cfg.rope.kind == "mrope":
        if positions.ndim == 2:  # plain text: t=h=w
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        ang = L.mrope_merge_angles(cfg.rope, positions, cfg.head_dim)
        return {cfg.rope.theta: ang}
    thetas = {blk.rope_theta or cfg.rope.theta for blk in cfg.pattern}
    out = {}
    for th in thetas:
        rc = cfg.rope
        rc = type(rc)(theta=th, kind=rc.kind, mrope_sections=rc.mrope_sections, scaling=rc.scaling)
        out[th] = L.rope_angles(rc, positions, cfg.head_dim)
    return out


def _apply_block(cfg: ArchConfig, blk: BlockCfg, p, x, angles, *, cache=None,
                 cache_index=None, q_offset):
    """One block.  Returns (x, aux_loss, new_cache)."""
    h = L.norm_fwd(cfg, p["norm1"], x)
    new_cache = cache
    if blk.mixer == "attn":
        ang = None if angles is None else angles[blk.rope_theta or cfg.rope.theta]
        out, kv = L.attention_fwd(
            cfg, p["attn"], h, angles=ang, causal=True, window=blk.window,
            q_offset=q_offset, kv_cache=cache, cache_index=cache_index,
        )
        if cache is not None:
            new_cache = kv
    elif blk.mixer == "mamba":
        out, st = M.mamba_fwd(cfg, p["mamba"], h, state=cache, return_state=cache is not None)
        if cache is not None:
            new_cache = st
    elif blk.mixer == "rwkv":
        tm_state = None if cache is None else {"S": cache["S"], "shift": cache["shift"]}
        out, st = R.time_mix_fwd(cfg, p["rwkv"], h, state=tm_state, return_state=cache is not None)
        if cache is not None:
            new_cache = dict(cache, **st)
    x = x + out
    h2 = L.norm_fwd(cfg, p["norm2"], x)
    aux = jnp.zeros((), jnp.float32)
    if blk.ffn == "glu":
        f = L.glu_fwd(cfg, p["glu"], h2)
    elif blk.ffn == "mlp":
        f = L.mlp_fwd(cfg, p["mlp"], h2)
    elif blk.ffn == "moe":
        f, aux = MOE.moe_fwd(cfg, p["moe"], h2)
    elif blk.ffn == "rwkv_cm":
        last = None if cache is None else cache["cm_shift"]
        f, cm = R.channel_mix_fwd(cfg, p["rwkv_cm"], h2, last=last, return_state=cache is not None)
        if cache is not None:
            new_cache = dict(new_cache, cm_shift=cm)
    x = x + f
    return x, aux, new_cache


def forward_lm(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    extra_embeds: Optional[jax.Array] = None,
    cache: Optional[Dict[str, Any]] = None,
    cache_index=None,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, Any]]]:
    """Run the LM.

    tokens: [B, S] int32.  ``extra_embeds`` ([B, N, D]; the stub modality
    frontend output for vlm/audio families) overrides the embeddings of the
    first N positions.  When ``cache`` is given the step is incremental:
    attention attends over the cache and recurrent mixers resume their state;
    ``cache_index`` is the write offset (== number of tokens already decoded).

    Returns (logits [B, S, V], aux_loss scalar, new_cache | None).
    """
    B, S = tokens.shape
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(compute_dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    if extra_embeds is not None:
        n = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(compute_dtype), x[:, n:]], axis=1)

    if positions is None and cache_index is not None:
        base = jnp.arange(S)[None] + cache_index
        positions = jnp.broadcast_to(base, (B, S))
    angles = _rope_angles(cfg, positions, S, B)
    q_offset = 0 if cache_index is None else cache_index

    n_full, n_tail = split_layers(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def period_fn(carry, xs):
        x, aux = carry
        pparams, pcache = xs
        new_pcache = {}
        for pos, blk in enumerate(cfg.pattern):
            c = None if pcache is None else pcache[f"pos{pos}"]
            x, a, nc = _apply_block(
                cfg, blk, pparams[f"pos{pos}"], x, angles,
                cache=c, cache_index=cache_index, q_offset=q_offset,
            )
            aux = aux + a
            if pcache is not None:
                new_pcache[f"pos{pos}"] = nc
        return (x, aux), (new_pcache if pcache is not None else None)

    new_cache: Optional[Dict[str, Any]] = None
    if n_full > 0:
        scan_cache = None if cache is None else cache["scan"]
        body = period_fn
        if cfg.remat:
            body = jax.checkpoint(period_fn)
        (x, aux_total), scan_cache_out = jax.lax.scan(
            body, (x, aux_total), (params["scan"], scan_cache)
        )
        if cache is not None:
            new_cache = {"scan": scan_cache_out, "tail": {}}
    elif cache is not None:
        new_cache = {"scan": {}, "tail": {}}

    for t in range(n_tail):
        li = n_full * cfg.period + t
        blk = cfg.blocks[li]
        c = None if cache is None else cache["tail"][f"layer{li}"]
        x, a, nc = _apply_block(
            cfg, blk, params["tail"][f"layer{li}"], x, angles,
            cache=c, cache_index=cache_index, q_offset=q_offset,
        )
        aux_total = aux_total + a
        if cache is not None:
            new_cache["tail"][f"layer{li}"] = nc

    x = L.norm_fwd(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, aux_total, new_cache
