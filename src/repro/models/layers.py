"""Shared neural-net layers: norms, activations, RoPE/M-RoPE, GQA attention,
dense FFNs.  Pure functional style — ``init_*`` returns a params pytree,
``*_fwd`` applies it.  No flax.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RopeCfg

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def norm_fwd(cfg: ArchConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(rope: RopeCfg, head_dim: int) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (rope.theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(rope: RopeCfg, positions: jax.Array, head_dim: int) -> jax.Array:
    """positions [..., S] -> angles [..., S, head_dim//2] (f32)."""
    inv = rope_freqs(rope, head_dim)
    pos = positions.astype(jnp.float32) / rope.scaling
    return pos[..., None] * inv


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: [B, S, H, hd]; angles: [B, S, hd//2] (already M-RoPE-merged if any)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # [B,S,1,half]
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    # rotate-half convention (HF Llama/Mistral/Gemma)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_merge_angles(rope: RopeCfg, positions_3d: jax.Array, head_dim: int) -> jax.Array:
    """Qwen2-VL M-RoPE.

    positions_3d: [3, B, S] (temporal, height, width position ids).  head_dim/2
    frequency slots are split into ``mrope_sections`` (t, h, w) chunks, each
    driven by its own position stream.  Text tokens carry identical t/h/w ids,
    which reduces to ordinary RoPE — the stub frontend supplies patch ids.
    Returns angles [B, S, head_dim//2].
    """
    inv = rope_freqs(rope, head_dim)  # [half]
    sections = rope.mrope_sections
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    pos = positions_3d.astype(jnp.float32) / rope.scaling  # [3,B,S]
    ang_all = pos[..., None] * inv  # [3,B,S,half]
    chunks = []
    start = 0
    for axis, sec in enumerate(sections):
        chunks.append(ang_all[axis, ..., start : start + sec])
        start += sec
    return jnp.concatenate(chunks, axis=-1)  # [B,S,half]


# ---------------------------------------------------------------------------
# attention (GQA, causal, optional sliding window, optional cross-attn)
# ---------------------------------------------------------------------------


def init_attention(cfg: ArchConfig, key, dtype, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, nq * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nq * hd, d, dtype),
    }


# Query lengths at or above this threshold use the blocked (XLA-flash) path
# so [Sq, Sk] score matrices never materialize in full.
CHUNKED_THRESHOLD = 2048
CHUNK_Q = 512

# §Perf lever (EXPERIMENTS.md): when enabled, sliding-window layers only
# score keys inside [q0 - window, q0 + chunk) instead of the full key range —
# exact same outputs, ~Sk/(window+chunk) x less attention work.  Off by
# default so baseline artifacts stay reproducible; perf runs set
# REPRO_OPT_WINDOW=1.
OPT_WINDOW_SLICING = os.environ.get("REPRO_OPT_WINDOW", "0") == "1"


def _sdpa_chunked(q, k, v, *, causal: bool, window: Optional[int], q_offset, chunk=CHUNK_Q):
    """Blocked attention: lax.scan over query chunks; scores materialize only
    per [chunk, Sk] block.  Same semantics as ``_sdpa`` (the pure-XLA analog
    of kernels/flash_attention.py; used where Pallas can't lower — CPU
    dry-runs — and as the remat-friendly long-context path)."""
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    rep = Hq // k.shape[2]
    nq = Sq // chunk
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    qs = jnp.moveaxis(q.reshape(B, nq, chunk, Hq, hd), 1, 0)  # [nq, B, c, H, hd]

    # window-limited key width (static): only keys in (q0-window, q0+chunk]
    # can be visible to a chunk of queries starting at q0.
    W = Sk
    if OPT_WINDOW_SLICING and window is not None and causal:
        W = min(Sk, window + chunk)

    def block(carry, inp):
        qi, qb = inp
        qf = qb.astype(jnp.float32) * (hd ** -0.5)
        q0 = q_offset + qi * chunk
        if W < Sk:
            start = jnp.clip(q0 - window + 1, 0, Sk - W)
            kw = jax.lax.dynamic_slice_in_dim(kf, start, W, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(vf, start, W, axis=1)
            k_pos = start + jnp.arange(W)[None, :]
        else:
            kw, vw = kf, vf
            k_pos = jnp.arange(Sk)[None, :]
        scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kw.astype(jnp.float32))
        q_pos = q0 + jnp.arange(chunk)[:, None]
        mask = jnp.ones((chunk, k_pos.shape[1]), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = jnp.where(jnp.isnan(probs), 0.0, probs)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), vw)
        return carry, out

    _, outs = jax.lax.scan(block, (), (jnp.arange(nq), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, hd)


def _sdpa(q, k, v, *, causal: bool, window: Optional[int], q_offset, softcap: float = 0.0,
          bias: Optional[jax.Array] = None, k_positions: Optional[jax.Array] = None):
    """Scaled dot-product attention with GQA broadcast.

    q: [B, Sq, Hq, hd]; k/v: [B, Sk, Hkv, hd].  ``q_offset`` is the absolute
    position of q[0] (scalar, traced ok) so that decode (Sq=1 at position P)
    masks correctly against a longer key cache.  ``k_positions`` overrides
    the absolute position of each key slot (ring-buffer caches; entries < 0
    are always masked).
    """
    if (q.shape[1] >= CHUNKED_THRESHOLD and q.shape[1] % CHUNK_Q == 0
            and softcap == 0.0 and bias is None and k_positions is None):
        return _sdpa_chunked(q, k, v, causal=causal, window=window, q_offset=q_offset)
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    # GQA: broadcast kv heads to query heads
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)  # [B,Hq,Sq,Sk]
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    Sk = k.shape[1]
    q_pos = q_offset + jnp.arange(Sq)[:, None]  # [Sq,1]
    if k_positions is not None:
        k_pos = k_positions[None, :]
        mask = jnp.broadcast_to(k_pos >= 0, (Sq, Sk))
    else:
        k_pos = jnp.arange(Sk)[None, :]  # [1,Sk]
        mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), vf)
    return out


def attention_fwd(cfg: ArchConfig, p, x, *, angles=None, causal=True,
                  window: Optional[int] = None, q_offset=0,
                  kv_cache=None, cache_index=None, kv_source=None):
    """Self- (or cross-) attention.

    ``kv_cache``: optional dict {"k": [B, S_cache, Hkv, hd], "v": ...}; when
    given together with ``cache_index`` (scalar int), new k/v are scattered at
    that offset and attention runs over the whole cache (decode path).  If the
    cache length equals ``window`` (< the sequence), it is treated as a
    sliding-window RING buffer (§Perf lever REPRO_OPT_RING_CACHE): writes go
    to ``cache_index % window`` and masking uses reconstructed positions.
    ``kv_source``: if given, keys/values are projected from it (cross-attn)
    and no positional rotation is applied to k.
    Returns (out [B,Sq,D], new_cache).
    """
    B, Sq, _ = x.shape
    hd, nq, nkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = (x @ p["wq"]).reshape(B, Sq, nq, hd)
    src = x if kv_source is None else kv_source
    Skv = src.shape[1]
    k = (src @ p["wk"]).reshape(B, Skv, nkv, hd)
    v = (src @ p["wv"]).reshape(B, Skv, nkv, hd)
    if angles is not None:
        q = apply_rope(q, angles)
        if kv_source is None:
            k = apply_rope(k, angles)
    new_cache = None
    k_positions = None
    if kv_cache is not None:
        ck, cv = kv_cache["k"], kv_cache["v"]
        if cache_index is not None:
            if window is not None and ck.shape[1] == window and Sq == 1:
                # ring buffer: p(s) = i - ((i - s) mod W); unwritten slots < 0
                slot = jnp.mod(cache_index, window)
                ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
                s_idx = jnp.arange(window)
                k_positions = cache_index - jnp.mod(cache_index - s_idx, window)
            else:
                ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
    out = _sdpa(q, k, v, causal=causal and kv_source is None, window=window,
                q_offset=q_offset, softcap=0.0, k_positions=k_positions)
    out = out.reshape(B, Sq, nq * hd) @ p["wo"]
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# dense FFNs
# ---------------------------------------------------------------------------


def init_glu(cfg: ArchConfig, key, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, f, dtype),
        "w_up": dense_init(ks[1], d, f, dtype),
        "w_down": dense_init(ks[2], f, d, dtype),
    }


def glu_fwd(cfg: ArchConfig, p, x):
    act = activation(cfg.act)
    return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_mlp(cfg: ArchConfig, key, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 2)
    return {"w_up": dense_init(ks[0], d, f, dtype), "w_down": dense_init(ks[1], f, d, dtype)}


def mlp_fwd(cfg: ArchConfig, p, x):
    act = activation(cfg.act)
    return act(x @ p["w_up"]) @ p["w_down"]
