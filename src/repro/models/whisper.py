"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: the encoder consumes precomputed frame embeddings [B, n_frames, D]
supplied by ``input_specs()``.  Everything downstream — encoder stack,
decoder with self+cross attention, KV caches — is real.

Whisper-tiny is 4 layers, so the stack is unrolled (no scan needed); learned
positional embeddings, pre-LayerNorm, GELU MLPs, full (non-GQA) attention
with kv_heads == heads.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def _init_dec_block(cfg: ArchConfig, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(cfg, k1, dtype),
        "norm_x": L.init_norm(cfg, dtype),
        "xattn": L.init_attention(cfg, k2, dtype),
        "norm2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(cfg, k3, dtype),
    }


def _init_enc_block(cfg: ArchConfig, key, dtype):
    k1, k2 = jax.random.split(key, 2)
    return {
        "norm1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(cfg, k1, dtype),
        "norm2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(cfg, k2, dtype),
    }


def init_whisper(cfg: ArchConfig, key, max_target_len: Optional[int] = None) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    max_target_len = max_target_len or cfg.max_seq_len
    ks = jax.random.split(key, 4 + cfg.encoder_layers + cfg.num_layers)
    params: Dict[str, Any] = {
        "enc": {
            "pos": (jax.random.normal(ks[0], (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.01).astype(dtype),
            "final_norm": L.init_norm(cfg, dtype),
            "layers": {
                f"layer{i}": _init_enc_block(cfg, ks[4 + i], dtype)
                for i in range(cfg.encoder_layers)
            },
        },
        "dec": {
            "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
            "pos": (jax.random.normal(ks[2], (max_target_len, cfg.d_model), jnp.float32) * 0.01).astype(dtype),
            "final_norm": L.init_norm(cfg, dtype),
            "layers": {
                f"layer{i}": _init_dec_block(cfg, ks[4 + cfg.encoder_layers + i], dtype)
                for i in range(cfg.num_layers)
            },
        },
    }
    return params


def whisper_encode(cfg: ArchConfig, params, frames: jax.Array) -> jax.Array:
    """frames: [B, n_frames, D] stub-frontend embeddings -> encoder states."""
    enc = params["enc"]
    x = frames.astype(jnp.dtype(cfg.compute_dtype)) + enc["pos"][None, : frames.shape[1]].astype(
        jnp.dtype(cfg.compute_dtype)
    )
    for i in range(cfg.encoder_layers):
        p = enc["layers"][f"layer{i}"]
        h = L.norm_fwd(cfg, p["norm1"], x)
        out, _ = L.attention_fwd(cfg, p["attn"], h, angles=None, causal=False)
        x = x + out
        h2 = L.norm_fwd(cfg, p["norm2"], x)
        x = x + L.mlp_fwd(cfg, p["mlp"], h2)
    return L.norm_fwd(cfg, enc["final_norm"], x)


def init_whisper_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hd, nkv = cfg.head_dim, cfg.num_kv_heads
    cache: Dict[str, Any] = {}
    for i in range(cfg.num_layers):
        cache[f"layer{i}"] = {
            "k": jnp.zeros((batch, max_len, nkv, hd), dtype),
            "v": jnp.zeros((batch, max_len, nkv, hd), dtype),
            # cross-attention k/v: projected once from encoder states
            "xk": jnp.zeros((batch, cfg.encoder_seq, nkv, hd), dtype),
            "xv": jnp.zeros((batch, cfg.encoder_seq, nkv, hd), dtype),
        }
    return cache


def prime_cross_cache(cfg: ArchConfig, params, cache, enc_out: jax.Array):
    """Project encoder states into every decoder layer's cross k/v."""
    B, Se, _ = enc_out.shape
    hd, nkv = cfg.head_dim, cfg.num_kv_heads
    for i in range(cfg.num_layers):
        p = params["dec"]["layers"][f"layer{i}"]["xattn"]
        cache[f"layer{i}"]["xk"] = (enc_out @ p["wk"]).reshape(B, Se, nkv, hd)
        cache[f"layer{i}"]["xv"] = (enc_out @ p["wv"]).reshape(B, Se, nkv, hd)
    return cache


def whisper_decode(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,
    enc_out: Optional[jax.Array] = None,
    *,
    cache: Optional[Dict[str, Any]] = None,
    cache_index=None,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, Any]]]:
    """Decoder forward.  Either ``enc_out`` (training / prefill) or a primed
    ``cache`` (incremental decode) must provide the cross-attention source.

    Returns (logits, aux=0, new_cache).
    """
    dec = params["dec"]
    B, S = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    offset = 0 if cache_index is None else cache_index
    pos = jax.lax.dynamic_slice_in_dim(dec["pos"], offset, S, axis=0) if cache_index is not None else dec["pos"][:S]
    x = dec["embed"][tokens].astype(cdt) + pos[None].astype(cdt)
    new_cache = {} if cache is not None else None
    for i in range(cfg.num_layers):
        p = dec["layers"][f"layer{i}"]
        c = None if cache is None else cache[f"layer{i}"]
        h = L.norm_fwd(cfg, p["norm1"], x)
        self_cache = None if c is None else {"k": c["k"], "v": c["v"]}
        out, kv = L.attention_fwd(
            cfg, p["attn"], h, angles=None, causal=True,
            q_offset=offset, kv_cache=self_cache, cache_index=cache_index,
        )
        x = x + out
        hx = L.norm_fwd(cfg, p["norm_x"], x)
        if c is not None:
            # cached cross kv: attend directly
            xout = L._sdpa(
                (hx @ p["xattn"]["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim),
                c["xk"], c["xv"], causal=False, window=None, q_offset=0,
            ).reshape(B, S, cfg.num_heads * cfg.head_dim) @ p["xattn"]["wo"]
        else:
            xout, _ = L.attention_fwd(cfg, p["xattn"], hx, angles=None, kv_source=enc_out)
        x = x + xout.astype(x.dtype)
        h2 = L.norm_fwd(cfg, p["norm2"], x)
        x = x + L.mlp_fwd(cfg, p["mlp"], h2)
        if cache is not None:
            new_cache[f"layer{i}"] = {"k": kv["k"], "v": kv["v"], "xk": c["xk"], "xv": c["xv"]}
    x = L.norm_fwd(cfg, dec["final_norm"], x)
    logits = x @ dec["embed"].T.astype(x.dtype)
    return logits, jnp.zeros((), jnp.float32), new_cache
