"""Mixture-of-Experts FFN (GShard/Switch-style top-k capacity routing).

Two routing implementations:

* ``gshard`` — one-hot dispatch/combine einsums with a per-expert capacity.
  This is the pjit-friendly formulation: sharding the expert dimension over
  the ``model``/``expert`` mesh axis makes GSPMD insert the all-to-alls, and
  compute scales with top-k (not num_experts).
* ``dense``  — every expert on every token, combined by router probs.  Only
  for tiny smoke/CPU configs and as the correctness oracle for routing tests.

The auxiliary load-balance loss follows Switch Transformer:
``aux = E * sum_e f_e * p_e`` with f_e the fraction of tokens dispatched to
expert e (top-1 assignment) and p_e the mean router prob.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import activation, dense_init

# §Perf lever: route gshard-configured layers through the sort/gather
# implementation (EXPERIMENTS.md §Perf); off by default.
OPT_MOE_SORT = os.environ.get("REPRO_OPT_MOE_SORT", "0") == "1"


def init_moe(cfg: ArchConfig, key, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)

    def expert_stack(k, d_in, d_out):
        keys = jax.random.split(k, e)
        return jnp.stack([dense_init(ki, d_in, d_out, dtype) for ki in keys])

    return {
        "router": dense_init(ks[0], d, e, dtype),
        "w_gate": expert_stack(ks[1], d, f),  # [E, D, F]
        "w_up": expert_stack(ks[2], d, f),
        "w_down": expert_stack(ks[3], f, d),  # [E, F, D]
    }


def _router(cfg: ArchConfig, p, x):
    """x: [T, D] -> (probs [T, E] f32, topk_idx [T, K], topk_w [T, K] f32)."""
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.moe.experts_per_token
    topk_w, topk_idx = jax.lax.top_k(probs, k)
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)  # renormalize over top-k
    return probs, topk_idx, topk_w


def _expert_ffn(cfg: ArchConfig, p, xe):
    """xe: [E, C, D] -> [E, C, D]; batched over the expert dim."""
    act = activation(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_fwd(cfg: ArchConfig, p, x):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar f32)."""
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.experts_per_token
    xt = x.reshape(B * S, D)
    probs, topk_idx, topk_w = _router(cfg, p, xt)
    T = B * S

    # Switch-style load-balance aux loss (top-1 assignment fractions).
    top1 = topk_idx[:, 0]
    f_e = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e)

    routing = cfg.moe.routing
    if OPT_MOE_SORT and routing == "gshard":
        routing = "sort"

    if routing == "dense":
        # all experts, prob-combined; oracle path
        ye = _expert_ffn(cfg, p, jnp.broadcast_to(xt, (E, T, D)))  # [E,T,D]
        combine = jnp.zeros((T, E), xt.dtype)
        combine = combine.at[jnp.arange(T)[:, None], topk_idx].set(topk_w.astype(xt.dtype))
        out = jnp.einsum("te,etd->td", combine, ye)
        return out.reshape(B, S, D), aux

    if routing == "sort":
        # §Perf lever: gather/scatter dispatch instead of one-hot einsums.
        # The GShard dispatch einsum costs 2·T·E·C·D FLOPs and a [T,E,C]
        # tensor; here dispatch is a pure gather (x[idx]) and combine a pure
        # gather of expert outputs — zero matmul FLOPs beyond the experts
        # themselves.  Same capacity semantics (over-capacity tokens drop).
        capacity = max(int(cfg.moe.capacity_factor * T * K / E), K)
        onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.int32)  # [T,K,E]
        flat = onehot.reshape(T * K, E)
        pos = (jnp.cumsum(flat, axis=0) * flat - 1).reshape(T, K, E)
        slot = jnp.take_along_axis(pos, topk_idx[..., None], axis=-1)[..., 0]  # [T,K]
        keep = (slot >= 0) & (slot < capacity)
        slot_c = jnp.clip(slot, 0, capacity - 1)
        # token index table per (expert, slot): scatter token ids
        idx = jnp.full((E, capacity), T, jnp.int32)  # T = sentinel -> zero row
        tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K))
        # dropped (t, k) write out-of-range and are discarded by mode="drop"
        idx = idx.at[topk_idx, jnp.where(keep, slot_c, capacity)].set(tok, mode="drop")
        x_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
        xe = x_pad[idx]  # [E, C, D] gather
        ye = _expert_ffn(cfg, p, xe)  # [E, C, D]
        ye_pad = jnp.concatenate([ye, jnp.zeros((E, 1, D), ye.dtype)], axis=1)
        gath = ye_pad[topk_idx, jnp.where(keep, slot_c, capacity)]  # [T,K,D]
        out = jnp.einsum("tk,tkd->td", topk_w.astype(xt.dtype), gath)
        return out.reshape(B, S, D), aux

    # --- GShard capacity routing -------------------------------------
    capacity = int(cfg.moe.capacity_factor * T * K / E)
    capacity = max(capacity, K)
    # position of each (token, k) within its expert's queue
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.int32)  # [T,K,E]
    flat = onehot.reshape(T * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1  # [T*K,E]
    pos = pos_in_expert.reshape(T, K, E)
    within_cap = (pos >= 0) & (pos < capacity)
    # dispatch tensor [T, E, C]
    dispatch = jnp.zeros((T, E, capacity), x.dtype)
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K))
    exp = topk_idx
    slot = jnp.clip(jnp.take_along_axis(pos, topk_idx[..., None], axis=-1)[..., 0], 0, capacity - 1)
    keep = within_cap.any(axis=-1) & jnp.take_along_axis(
        within_cap, topk_idx[..., None], axis=-1
    )[..., 0]
    dispatch = dispatch.at[tok, exp, slot].add(keep.astype(x.dtype))
    # combine weights: same sparsity as dispatch scaled by router weight
    w_full = jnp.zeros((T, E), jnp.float32)
    w_full = w_full.at[tok, exp].add(jnp.where(keep, topk_w, 0.0))
    combine = dispatch * w_full[..., None].astype(x.dtype)  # [T,E,C]

    xe = jnp.einsum("td,tec->ecd", xt, dispatch)  # [E,C,D]
    ye = _expert_ffn(cfg, p, xe)  # [E,C,D]
    out = jnp.einsum("tec,ecd->td", combine, ye)
    return out.reshape(B, S, D), aux
