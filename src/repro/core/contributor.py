"""A ColD Fusion contributor: a party with a private dataset that downloads
the base model, finetunes it locally (paper §3 — any loss-minimizing
procedure), and uploads the result.  The classification head stays private
(per-dataset heads, §4.2); only the shared body is contributed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import encoder as E
from repro.train import finetune as FT


@dataclass
class Contributor:
    cfg: ArchConfig
    task_id: int
    num_classes: int
    x: np.ndarray
    y: np.ndarray
    steps: int = 30
    batch_size: int = 32
    lr: float = 5e-4
    seed: int = 0
    # Private head persists across iterations (re-initialized heads also
    # work; persistent heads converge faster — flagged in EXPERIMENTS.md).
    reset_head_each_iter: bool = False
    # Compute a diagonal Fisher alongside the contribution (enables the
    # Repository's fusion_op="fisher"; Matena & Raffel 2021, paper §8).
    with_fisher: bool = False
    last_fisher: Optional[Dict] = field(default=None, repr=False)
    _head: Optional[Dict] = field(default=None, repr=False)
    _iter: int = 0

    def _ensure_head(self):
        if self._head is None or self.reset_head_each_iter:
            key = jax.random.PRNGKey((self.seed, self.task_id, self._iter)[0] * 7919 + self.task_id * 131 + self._iter)
            self._head = E.init_cls_head(self.cfg, key, self.num_classes)
        return self._head

    def contribute(self, base_body) -> Dict:
        """One ColD iteration: finetune the downloaded base on local data and
        return the updated body (the upload)."""
        head = self._ensure_head()
        body, head, _ = FT.finetune(
            self.cfg, base_body, head, self.x, self.y,
            steps=self.steps, batch_size=self.batch_size, lr=self.lr,
            seed=self.seed * 1000 + self._iter,
        )
        self._head = head
        if self.with_fisher:
            self.last_fisher = FT.compute_fisher(
                self.cfg, body, head, self.x, self.y, seed=self.seed)
        self._iter += 1
        return body
