from repro.core import fusion
from repro.core.cold_fusion import ColdFusionRun, EvalTask, evaluate_base_model, run_cold_fusion
from repro.core.contributor import Contributor
from repro.core.distributed import (
    ColdSchedule,
    cold_shardings,
    make_cold_train_step,
    make_fuse_step,
    num_contributors,
    stack_for_contributors,
)
from repro.core.repository import Repository
from repro.core.validation import screen_contributions

__all__ = [
    "fusion", "ColdFusionRun", "EvalTask", "evaluate_base_model", "run_cold_fusion",
    "Contributor", "ColdSchedule", "cold_shardings", "make_cold_train_step",
    "make_fuse_step", "num_contributors", "stack_for_contributors",
    "Repository", "screen_contributions",
]
