"""Mesh-level ColD Fusion — the paper's schedule as a TPU training strategy.

The host-level `Repository`/`Contributor` objects exchange checkpoints; at
pod scale the same mathematics maps onto the device mesh (DESIGN.md §2):

* mesh ("pod"?, "contrib", "replica", "model");
* every parameter gains a leading contributor dim C sharded over
  ("pod", "contrib") — each contributor slab holds its own full replica of
  the model (sharded over its "replica" x "model" sub-mesh);
* ``cold_train_step`` = vmap of the ordinary train step over the contributor
  dim.  GSPMD inserts gradient all-reduces **only** over "replica"/"model"
  (params are sharded over "contrib", so no cross-contributor traffic);
* ``fuse_step`` = parameter mean over the contributor dim, broadcast back —
  a single all-reduce over ("pod", "contrib") every H steps.  With damping
  α it implements the paper-§8 "iteration learning rate".

Amortized collective traffic over contributor axes: 2·P/H bytes/step vs
2·P for synchronous data parallelism — the measurable systems win of the
paper's schedule, quantified from lowered HLO in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.launch import sharding as SH
from repro.optim.optimizers import Optimizer
from repro.train.step import make_train_step
from repro.utils.flat import ShardedFlatSpec, StagedBuffer


@dataclass(frozen=True)
class ColdSchedule:
    """Hyper-parameters of the distributed schedule."""

    fusion_interval: int = 50  # H: local steps between fusions
    alpha: float = 1.0         # damped-fusion coefficient (1.0 = paper)
    reset_opt_on_fuse: bool = False  # fresh optimizer each iteration (paper)


def contrib_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "contrib") if a in mesh.axis_names)


def num_contributors(mesh: Mesh) -> int:
    n = 1
    for a in contrib_axes_of(mesh):
        n *= mesh.shape[a]
    return n


def stack_for_contributors(tree, n: int):
    """Broadcast a pytree to a leading contributor dim of size n."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), tree)


def make_cold_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
) -> Callable:
    """vmap(local_train_step) over the leading contributor dim.

    state: pytree with leading contributor dim C on every leaf;
    batch: {"tokens": [C, B_local, S], ...}.  Pair with ``cold_shardings``
    under ``jax.jit`` — params sharded over contrib ⇒ zero cross-contributor
    gradient traffic.
    """
    local = make_train_step(cfg, optimizer, microbatches=microbatches)
    return jax.vmap(local)


def shard_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the flat fuse buffer is block-cyclically sharded over (the
    non-contributor part of the ColD mesh)."""
    return tuple(a for a in ("replica", "model") if a in mesh.axis_names)


def make_fuse_step(cfg: ArchConfig, mesh: Mesh, schedule: ColdSchedule,
                   *, flat: bool = True) -> Callable:
    """The Repository collective: θ ← θ_base + α·(mean_c θ_c − θ_base),
    broadcast back to every contributor slab.

    ``flat=True`` (default) runs the fuse over ONE ``[C, N]`` flat buffer
    instead of one reduction per leaf — the mesh-level face of the sharded
    flat engine (docs/sharding.md): the buffer is laid out block-cyclically
    (``ShardedFlatSpec``) with C over the contributor axes and N over the
    replica/model axes, and ``ops.cohort_fuse_sharded`` computes a
    per-device partial sum over its local slabs that exactly ONE psum over
    the contributor axes completes.  ``flat=False`` keeps the per-leaf path
    as the oracle.

    This shares the Repository fuse's implementation (the same layout, the
    same partial+one-all-reduce structure — only the reduced dim differs)
    and it *retires* the old GSPMD workaround: jax 0.4.37 CPU miscompiled
    ``concat -> mean`` over a sharded leading axis into a SUM when the
    concat inputs carried heterogeneous shardings, which previously forced
    every piece to be pinned to ``P(contrib, None)`` — replicating the
    staged buffer over the model/replica axes.  With the mean computed
    manually under ``shard_map`` no GSPMD mean ever lowers, no pin is
    needed, and each device holds only its ``1/S`` block-cyclic slice of
    the buffer through the fuse.
    """

    def leaf_fuse(x):
        mean = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        if schedule.alpha != 1.0:
            # damped fusion: each slab relaxes toward the cohort mean
            mean = x.astype(jnp.float32) * (1 - schedule.alpha) + mean * schedule.alpha
        return jnp.broadcast_to(mean, x.shape).astype(x.dtype)

    def fuse_per_leaf(params):
        return jax.tree.map(leaf_fuse, params)

    contrib = contrib_axes_of(mesh)
    if not (flat and contrib):
        # no contributor axis (plain data/model mesh): nothing to fuse over
        # a mesh dim — the per-leaf reduction handles any mesh
        return fuse_per_leaf
    shard_axes = shard_axes_of(mesh)
    n_shards = SH.axes_extent(mesh, shard_axes)

    def fuse_flat(params):
        leaves, treedef = jax.tree.flatten(params)
        C = leaves[0].shape[0]
        shapes = [l.shape for l in leaves]
        dtypes = [l.dtype for l in leaves]
        sizes = [int(np.prod(s[1:])) for s in shapes]
        buf = jnp.concatenate(
            [l.reshape(C, -1).astype(jnp.float32) for l in leaves], axis=1)
        sspec = ShardedFlatSpec.for_size(buf.shape[1], n_shards)
        # hand the staged cohort to the fuse as an explicit buffer handle —
        # the same operand contract the async Repository uses
        fused = ops.cohort_fuse_sharded(
            StagedBuffer(sspec.shard(buf)), mesh=mesh, contrib_axes=contrib,
            shard_axes=shard_axes, alpha=schedule.alpha)
        fused = sspec.unshard(fused)
        outs = []
        off = 0
        for shape, dtype, n in zip(shapes, dtypes, sizes):
            outs.append(fused[:, off:off + n].reshape(shape).astype(dtype))
            off += n
        return jax.tree.unflatten(treedef, outs)

    return fuse_flat


def cold_shardings(mesh: Mesh, cfg: ArchConfig, state, batch):
    """Convenience: full (state, batch) NamedSharding trees for jit."""
    contrib = contrib_axes_of(mesh)
    contrib_spec: Tuple = (contrib if len(contrib) > 1 else contrib[0],)
    params_sh = SH.params_shardings(
        mesh, state["params"], cfg,
        data_axis="replica", model_axis="model", contrib_axes=contrib_spec,
    )
    opt_sh = SH.opt_state_shardings(mesh, state["opt"], params_sh)
    batch_sh = SH.batch_shardings(
        mesh, batch, data_axis="replica", contrib_axes=contrib_spec,
    )
    return {"params": params_sh, "opt": opt_sh}, batch_sh
