"""Mesh-level ColD Fusion — the paper's schedule as a TPU training strategy.

The host-level `Repository`/`Contributor` objects exchange checkpoints; at
pod scale the same mathematics maps onto the device mesh (DESIGN.md §2):

* mesh ("pod"?, "contrib", "replica", "model");
* every parameter gains a leading contributor dim C sharded over
  ("pod", "contrib") — each contributor slab holds its own full replica of
  the model (sharded over its "replica" x "model" sub-mesh);
* ``cold_train_step`` = vmap of the ordinary train step over the contributor
  dim.  GSPMD inserts gradient all-reduces **only** over "replica"/"model"
  (params are sharded over "contrib", so no cross-contributor traffic);
* ``fuse_step`` = parameter mean over the contributor dim, broadcast back —
  a single all-reduce over ("pod", "contrib") every H steps.  With damping
  α it implements the paper-§8 "iteration learning rate".

Amortized collective traffic over contributor axes: 2·P/H bytes/step vs
2·P for synchronous data parallelism — the measurable systems win of the
paper's schedule, quantified from lowered HLO in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch import sharding as SH
from repro.optim.optimizers import Optimizer
from repro.train.step import make_train_step


@dataclass(frozen=True)
class ColdSchedule:
    """Hyper-parameters of the distributed schedule."""

    fusion_interval: int = 50  # H: local steps between fusions
    alpha: float = 1.0         # damped-fusion coefficient (1.0 = paper)
    reset_opt_on_fuse: bool = False  # fresh optimizer each iteration (paper)


def contrib_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "contrib") if a in mesh.axis_names)


def num_contributors(mesh: Mesh) -> int:
    n = 1
    for a in contrib_axes_of(mesh):
        n *= mesh.shape[a]
    return n


def stack_for_contributors(tree, n: int):
    """Broadcast a pytree to a leading contributor dim of size n."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), tree)


def make_cold_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
) -> Callable:
    """vmap(local_train_step) over the leading contributor dim.

    state: pytree with leading contributor dim C on every leaf;
    batch: {"tokens": [C, B_local, S], ...}.  Pair with ``cold_shardings``
    under ``jax.jit`` — params sharded over contrib ⇒ zero cross-contributor
    gradient traffic.
    """
    local = make_train_step(cfg, optimizer, microbatches=microbatches)
    return jax.vmap(local)


def make_fuse_step(cfg: ArchConfig, mesh: Mesh, schedule: ColdSchedule) -> Callable:
    """The Repository collective: θ ← θ_base + α·(mean_c θ_c − θ_base),
    broadcast back to every contributor slab."""

    def fuse(params):
        def leaf_fuse(x):
            mean = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
            if schedule.alpha != 1.0:
                # damped fusion: each slab relaxes toward the cohort mean
                mean = x.astype(jnp.float32) * (1 - schedule.alpha) + mean * schedule.alpha
            return jnp.broadcast_to(mean, x.shape).astype(x.dtype)

        return jax.tree.map(leaf_fuse, params)

    return fuse


def cold_shardings(mesh: Mesh, cfg: ArchConfig, state, batch):
    """Convenience: full (state, batch) NamedSharding trees for jit."""
    contrib = contrib_axes_of(mesh)
    contrib_spec: Tuple = (contrib if len(contrib) > 1 else contrib[0],)
    params_sh = SH.params_shardings(
        mesh, state["params"], cfg,
        data_axis="replica", model_axis="model", contrib_axes=contrib_spec,
    )
    opt_sh = SH.opt_state_shardings(mesh, state["opt"], params_sh)
    batch_sh = SH.batch_shardings(
        mesh, batch, data_axis="replica", contrib_axes=contrib_spec,
    )
    return {"params": params_sh, "opt": opt_sh}, batch_sh
