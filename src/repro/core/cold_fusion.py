"""The ColD Fusion iterative loop (paper §3, Fig. 1) and its evaluation
protocol (§4.4): at each iteration sample contributors, let each finetune
the current base on their private dataset, fuse the uploads, and evaluate
the new base both ways —

* **ColD** (base-model goal): full finetune on each eval dataset, report
  test accuracy;
* **ColD-Frozen** (single-model goal): linear probe (head-only training).

This is the host-level simulation driver used by the paper-reproduction
benchmarks; the pod-scale mesh implementation of the same schedule lives in
`repro.core.distributed`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.contributor import Contributor
from repro.core.repository import Repository
from repro.models import encoder as E
from repro.train import finetune as FT


@dataclass
class EvalTask:
    task_id: int
    num_classes: int
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray


def evaluate_base_model(
    cfg: ArchConfig,
    body,
    tasks: Sequence[EvalTask],
    *,
    frozen: bool,
    steps: int = 30,
    lr: float = 5e-4,
    batch_size: int = 32,
    seed: int = 0,
    few_shot: Optional[int] = None,
) -> Dict[int, float]:
    """Finetune (or probe) the base on each task's train split; test acc."""
    out = {}
    for t in tasks:
        key = jax.random.PRNGKey(seed * 7919 + t.task_id)
        head = E.init_cls_head(cfg, key, t.num_classes)
        x, y = t.x_train, t.y_train
        if few_shot is not None:
            x, y = x[:few_shot], y[:few_shot]
        body_ft, head, _ = FT.finetune(
            cfg, body, head, x, y,
            steps=steps, batch_size=min(batch_size, len(x)), lr=lr,
            frozen_body=frozen, seed=seed,
        )
        out[t.task_id] = FT.evaluate(cfg, body_ft, head, t.x_test, t.y_test)
    return out


@dataclass
class ColdFusionRun:
    """Result log: per-iteration eval scores + repository history."""

    seen_finetuned: List[Dict[int, float]] = field(default_factory=list)
    seen_frozen: List[Dict[int, float]] = field(default_factory=list)
    unseen_finetuned: List[Dict[int, float]] = field(default_factory=list)
    unseen_frozen: List[Dict[int, float]] = field(default_factory=list)

    def mean(self, series: str) -> List[float]:
        rows = getattr(self, series)
        return [float(np.mean(list(r.values()))) for r in rows]


def run_cold_fusion(
    cfg: ArchConfig,
    repo: Repository,
    contributors: Sequence[Contributor],
    *,
    iterations: int,
    contributors_per_iter: Optional[int] = None,
    eval_seen: Sequence[EvalTask] = (),
    eval_unseen: Sequence[EvalTask] = (),
    eval_every: int = 1,
    eval_steps: int = 30,
    eval_lr: float = 5e-4,
    seed: int = 0,
    progress: bool = False,
) -> ColdFusionRun:
    """Run the full ColD Fusion loop (paper §4.4).

    Each iteration samples ``contributors_per_iter`` contributors (all, if
    None — the single-dataset experiments use fixed cohorts), collects their
    finetuned bodies, and fuses.  Evaluation follows §4.4: both multitask
    goals, on seen and/or unseen task groups.
    """
    rng = np.random.default_rng(seed)
    log = ColdFusionRun()

    def _eval(body, it):
        if eval_seen:
            log.seen_finetuned.append(
                evaluate_base_model(cfg, body, eval_seen, frozen=False, steps=eval_steps, lr=eval_lr, seed=seed)
            )
            log.seen_frozen.append(
                evaluate_base_model(cfg, body, eval_seen, frozen=True, steps=eval_steps, lr=eval_lr, seed=seed)
            )
        if eval_unseen:
            log.unseen_finetuned.append(
                evaluate_base_model(cfg, body, eval_unseen, frozen=False, steps=eval_steps, lr=eval_lr, seed=seed)
            )
            log.unseen_frozen.append(
                evaluate_base_model(cfg, body, eval_unseen, frozen=True, steps=eval_steps, lr=eval_lr, seed=seed)
            )

    for it in range(iterations):
        pool = list(contributors)
        if contributors_per_iter is not None and contributors_per_iter < len(pool):
            idx = rng.choice(len(pool), size=contributors_per_iter, replace=False)
            pool = [pool[i] for i in idx]
        base = repo.download()
        for c in pool:
            body = c.contribute(base)
            repo.upload(body, fisher=getattr(c, "last_fisher", None))
        rec = repo.fuse_pending()
        if progress:
            print(
                f"[cold] iter {it + 1}/{iterations}: fused {rec.n_accepted}/{rec.n_contributions} "
                f"contributions (op={rec.op})"
            )
        if (it + 1) % eval_every == 0 or it == iterations - 1:
            _eval(repo.download(), it)
    return log
