"""The central Repository (paper Fig. 1): versioned base-model store that
accepts contributions, screens them (§9), fuses them (§3), and publishes the
next base model.  Performs no training — only the minimal computation the
ColD constraints allow (§2.3).

Two transports share this logic:

* **in-memory** — the simulation / single-process driver keeps pytrees.
* **on-disk**   — contributions arrive as npz checkpoints in a directory
  (the stand-in for the HF-hub exchange); useful across processes.

Two fuse engines share the contributor-facing API:

* **streaming flat engine** (default for ``average``/``damped``/
  ``task_arithmetic`` when kernels are enabled) — ``upload`` immediately
  folds each contribution into a flat ``[N]`` staging row (the pytree is
  dropped, bounding peak memory to the staging buffer — optionally spilled
  to the npz root) and ``fuse_pending`` performs screen+fuse in a SINGLE
  streaming pass: the Pallas ``cold_fuse`` kernel emits the fused model and
  the per-contributor ``sq_diff`` screening statistic together, the §9 MAD
  screen runs on those norms, and any rejected contributors get weight 0 in
  one cheap second pass over the already-staged buffer.  No contribution is
  ever re-read as a pytree.
* **per-leaf pytree engine** — the seed path (`repro.core.fusion`), kept
  verbatim as the ``REPRO_NO_KERNELS`` oracle and for operators the kernel
  does not cover (``fisher``, ``ties``).

The staging side is **double-buffered** (paper §8, asynchronous updates):
uploads stage into the *front* buffer while ``fuse_pending(wait=False)``
runs the screen+fuse on the *back* buffer — jax's asynchronous dispatch
overlaps the device fuse with the host-side staging work of the next
cohort, no Python threads required.  ``flush()`` (or the next
``fuse_pending``/``download``) finalizes the in-flight fuse: screening,
the optional weight-zeroed re-pass, and the publish.  See
docs/async_repository.md.

``spill=True`` makes the staging buffer **resumable**: every staged row is
written atomically into the npz root together with a small JSON manifest
(``staging_manifest.json``), and ``Repository.open`` recovers
staged-but-unfused rows after a crash — re-staged into the correct buffer
and, under ``mesh=``, the correct per-shard placement (spill files hold
per-shard slices, so the reload never materializes a full ``[N]`` row on
the host).

Passing ``mesh=`` (with optional ``mesh_axes=``) distributes the flat
engine: ``upload`` stages each row directly into its block-cyclic shard
placement (``ShardedFlatSpec``), ``fuse_pending`` runs the screen+fuse
per-shard under ``shard_map`` with exactly ONE all-reduce (the ``sq_diff``
partials), and no device ever materializes the full ``[K, N]`` staging
buffer.  Cohort capacity then scales with the mesh instead of a single
device's HBM.  See docs/sharding.md.

See docs/fusion_engine.md and docs/repository.md for the full contract.
"""
from __future__ import annotations

import functools
import json
import os
import re
import threading
import time
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.core import fusion
from repro.core.validation import (ScreenReport, norms_from_sq,
                                   screen_contributions, screen_norms)
from repro.kernels import ops
from repro.launch import sharding as SH
from repro.utils import faults
from repro.utils.flat import (SKETCH_BUCKETS, BufferPair, CohortSketch,
                              FlatSpec, ShardedFlatSpec, StagedBuffer,
                              StagingSide, delta_decode, delta_decode_sharded,
                              delta_entries, sketch_apply_delta)

# operators the streaming flat engine covers; everything else (fisher, ties)
# falls back to the per-leaf pytree engine
FLAT_OPS = ("average", "damped", "task_arithmetic")

MANIFEST = "staging_manifest.json"
SKETCH_FILE = "cohort_sketch.json"

# on-disk artifact naming in the npz root (compact() walks these)
_BASE_RE = re.compile(r"^base_iter(\d{4,})\.npz$")
_ROW_RE = re.compile(r"^iter\d{4,}_contrib\d{3,}\.npz$")


@dataclass
class FusionRecord:
    iteration: int
    n_contributions: int
    n_accepted: int
    op: str
    diff_norms: List[float]
    wall_time: float


@dataclass
class PendingFusion:
    """Handle to an in-flight fuse: dispatched to the device, not yet
    screened or published.  ``Repository.flush()`` (or the next
    ``fuse_pending``/``download``) finalizes it; ``record`` is set once the
    publish happened."""

    # StagedBuffer (dense cohort) or MixedStage (compressed rows present);
    # kept only while a screen re-pass may need it
    stage: Optional[Any]
    fused: jax.Array
    sq: jax.Array
    weights: jax.Array
    k: int
    t0: float
    record: Optional[FusionRecord] = None
    # per-fusion overrides (fuse_pending(buffer=..., alpha=/screen=/op=));
    # None defers to the repository's configuration
    alpha: Optional[float] = None
    use_screen: Optional[bool] = None
    op: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.record is not None


@dataclass
class MixedStage:
    """Fuse operand for a cohort that mixes dense staged rows with
    delta-compressed submissions (docs/service_loop.md).  ``dense`` holds
    the stacked dense rows (cohort positions ``dense_pos``); the
    compressed rows ride as their stacked codec arrays (``comp_pos``) and
    are decoded *inside* the fuse (``ops.fuse_flat_compressed``) — a dense
    ``[N]`` row per compressed contributor never materializes.  Kept as
    the ``PendingFusion`` stage so the §9 screen's zero-weight re-pass can
    re-fuse with adjusted cohort-order weights, exactly like a dense
    ``StagedBuffer``."""

    dense: Optional[StagedBuffer]
    indices: jax.Array   # [C, nb, kb] int16 ([C, S, nb, kb] sharded)
    values: jax.Array    # [C, nb, kb] int8
    scales: jax.Array    # [C, nb] f32 ([C, S, nb] sharded)
    block: int
    dense_pos: np.ndarray  # cohort positions of the dense rows, in order
    comp_pos: np.ndarray   # cohort positions of the compressed rows

    @property
    def k(self) -> int:
        return len(self.dense_pos) + len(self.comp_pos)


@functools.lru_cache(maxsize=32)
def _stack_fn(k: int, sharding):
    """Jitted K-row stack with the staging out-sharding: each device
    concatenates its local shard slices, so stacking never gathers the
    cohort onto one device.  Cached per (K, sharding) to avoid re-tracing
    every fuse."""
    del k  # shapes key the jit cache; K only keys the lru entry
    return jax.jit(lambda *rows: jnp.stack(rows), out_shardings=sharding)


@functools.lru_cache(maxsize=32)
def _stack_plain_fn(k: int):
    """Jitted single-device K-row stack.  Eager ops on the CPU backend
    execute synchronously; only jitted computations dispatch asynchronously
    — and the stack must dispatch async for the double-buffered fuse to
    overlap uploads (docs/async_repository.md)."""
    del k
    return jax.jit(lambda *rows: jnp.stack(rows))


def _json_default(o):
    if isinstance(o, (np.ndarray, np.generic, jax.Array)):
        return np.asarray(o).tolist()
    return str(o)


class Repository:
    def __init__(
        self,
        base_params,
        *,
        fusion_op: str = "average",
        fusion_kwargs: Optional[Dict[str, Any]] = None,
        screen: bool = True,
        mad_threshold: float = 5.0,
        root: Optional[str] = None,
        keep_history: bool = False,
        use_flat: Optional[bool] = None,
        spill: bool = False,
        spill_workers: int = 0,
        mesh: Optional[Any] = None,
        mesh_axes: Optional[Any] = None,
    ):
        self._base = base_params
        self.fusion_op = fusion_op
        self.fusion_kwargs = dict(fusion_kwargs or {})
        self.screen = screen
        self.mad_threshold = mad_threshold
        self.iteration = 0
        self.root = root
        self.keep_history = keep_history
        if use_flat is None:
            # the sharded engine is plain XLA under shard_map, so a mesh
            # forces the flat path regardless of the kernel toggle
            use_flat = fusion_op in FLAT_OPS and (
                mesh is not None or ops.kernels_enabled())
        elif use_flat and fusion_op not in FLAT_OPS:
            raise ValueError(f"flat engine does not cover fusion_op={fusion_op!r}")
        if mesh is not None and not use_flat:
            raise ValueError("mesh= requires the flat engine "
                             f"(fusion_op={fusion_op!r}, use_flat={use_flat})")
        self.use_flat = use_flat
        self.mesh = mesh
        if mesh is not None:
            axes = SH.norm_axes(
                mesh.axis_names if mesh_axes is None else mesh_axes)
            missing = [a for a in axes if a not in mesh.axis_names]
            if missing:
                raise ValueError(f"mesh_axes {missing} not in mesh {mesh.axis_names}")
            self.mesh_axes = axes
            self._n_shards = SH.axes_extent(mesh, axes)
        else:
            self.mesh_axes = ()
            self._n_shards = 1
        if spill and not root:
            raise ValueError("spill=True requires an on-disk root")
        if spill and not use_flat:
            raise ValueError("spill=True requires the flat engine "
                             f"(fusion_op={fusion_op!r}, use_flat={use_flat})")
        self.spill = spill
        self.history: List[FusionRecord] = []
        # double-buffered staging: uploads fill the FRONT side; a dispatched
        # fuse owns the BACK side until it publishes (docs/async_repository.md)
        self._buffers = BufferPair()
        self._inflight: Optional[PendingFusion] = None
        self._snapshots: List[Any] = []
        self._spec: Optional[FlatSpec] = None
        self._sspec: Optional[ShardedFlatSpec] = None
        self._base_flat: Optional[jax.Array] = None
        # optional executor draining host-side spill writes off the upload path
        self._spill_pool = (
            ThreadPoolExecutor(max_workers=spill_workers,
                               thread_name_prefix="repo-spill")
            if spill and spill_workers > 0 else None)
        self._spill_futures: List[Future] = []
        self._row_futures: Dict[str, Future] = {}
        self._manifest_lock = threading.Lock()
        self._publish_lock = threading.Lock()
        self._persisted_iteration = -1
        # in-process publish subscribers (the fuse-to-serve hot path,
        # docs/serving.md): notified AFTER the iteration bump with a
        # consistent (iteration, base, flat) snapshot — raw cross-thread
        # polling of (iteration, _base) can pair iteration k with k+1's
        # weights because _publish_flat installs the base first
        self._publish_listeners: List[Any] = []
        # novelty admission state (docs/service_loop.md): None until the
        # service (or a caller) enables it via enable_cohort_sketch
        self.cohort_sketch: Optional[CohortSketch] = None
        # base-family membership (docs/service_loop.md): set by
        # RepositoryFamily; None for a standalone repository.  extra_meta
        # rides along in repository.json verbatim — the family manifest
        # lives there, and a plain open+publish must never drop it.
        self.family_name: Optional[str] = None
        self.extra_meta: Dict[str, Any] = {}
        if root:
            os.makedirs(root, exist_ok=True)
            self._persist_base()

    # -- staging-list views (front buffer) ------------------------------
    # The parallel per-contribution lists keep their historical names; they
    # always alias the FRONT side of the double buffer.
    @property
    def _pending(self) -> List[Any]:
        return self._buffers.front.rows

    @_pending.setter
    def _pending(self, v: List[Any]) -> None:
        self._buffers.front.rows = list(v)

    @property
    def _pending_fishers(self) -> List[Any]:
        return self._buffers.front.fishers

    @_pending_fishers.setter
    def _pending_fishers(self, v: List[Any]) -> None:
        self._buffers.front.fishers = list(v)

    @property
    def _pending_weights(self) -> List[Any]:
        return self._buffers.front.weights

    @_pending_weights.setter
    def _pending_weights(self, v: List[Any]) -> None:
        self._buffers.front.weights = list(v)

    # -- public staging introspection (service loop) --------------------
    @property
    def n_staged(self) -> int:
        """Rows staged in the front buffer (not yet part of any fuse)."""
        return len(self._buffers.front.rows)

    @property
    def inflight(self) -> bool:
        """True while a dispatched fuse awaits finalize/publish."""
        return self._inflight is not None

    def staged_spill_files(self) -> set:
        """Root-relative file names of every manifest-tracked staged row —
        front AND in-flight back cohort.  This is exactly the set a crash
        right now would recover, which is what lets the service loop decide
        'consumed' by set difference (docs/service_loop.md)."""
        with self._manifest_lock:
            return {e["file"] for e in self._buffers.manifest_entries()}

    # -- flat staging ---------------------------------------------------
    def _ensure_flat_base(self):
        if self._spec is None:
            self._spec = FlatSpec.from_tree(self._base)
        if self.mesh is not None and self._sspec is None:
            self._sspec = ShardedFlatSpec.from_spec(self._spec, self._n_shards)
        if self._base_flat is None:
            flat = self._spec.flatten(self._base)
            self._base_flat = self._stage_row(flat) if self.mesh is not None else flat

    def _stage_row(self, row: jax.Array) -> jax.Array:
        """[N] row -> its block-cyclic [S, shard_len] placement: each device
        receives only its own slice, at upload time — the full row never
        needs to exist on a fuse device."""
        return jax.device_put(
            self._sspec.shard(row), SH.flat_row_sharding(self.mesh, self.mesh_axes))

    def _load_staged_row(self, p):
        """A pending entry -> its staged array form.  In-memory rows pass
        through; spilled rows load from disk — per shard for the sharded
        layout (``FlatShardReader`` + ``stage_row_from_shards``: the host
        only ever holds one shard's slice, never the full [N] row), or as a
        portable [N] row for the flat layout (re-sharded by _stack_stage
        under a mesh)."""
        if not isinstance(p, str):
            return p
        fut = self._row_futures.pop(p, None)
        if fut is not None:
            fut.result()  # wait for (and surface errors from) THIS row's write
        # compressed before sharded: a sharded compressed file carries the
        # shard-spec entry too, and FlatShardReader has no buffers to read
        if ckpt.is_flat_compressed(p):
            # generic (non-fuse) access to a compressed submission — e.g.
            # recovery without spill, or a layout-mismatch restage: decode
            # the dense row against the current base.  The fuse itself
            # never takes this path (_stage_mixed keeps payloads sparse).
            payloads, meta = ckpt.load_flat_delta(p)
            row = self._decode_compressed_dense(payloads, meta)
            return self._stage_row(row) if self.mesh is not None else row
        if ckpt.is_flat_sharded(p):
            with ckpt.FlatShardReader(p) as r:
                if self.mesh is not None and r.sspec == self._sspec:
                    return SH.stage_row_from_shards(
                        self.mesh, self.mesh_axes, r.sspec.n_shards,
                        r.sspec.shard_len, r.shard)
                # layout mismatch (repository reopened under a different
                # mesh): fall back to host reassembly + restage
                row = jnp.asarray(r.full_row())
            return self._stage_row(row) if self.mesh is not None else row
        row, _ = ckpt.load_flat(p)
        return row

    def _stack_stage(self, rows: List[jax.Array]) -> jax.Array:
        """Stack K staged rows into the fuse operand.  On a mesh the stack
        runs under jit with the staging out-sharding, so each device
        concatenates its local slices — the [K, N] buffer is never
        materialized on one device."""
        if self.mesh is None:
            return _stack_plain_fn(len(rows))(*rows)
        rows = [r if r.ndim == 2 else self._stage_row(r) for r in rows]  # [N] rows re-shard
        stack = _stack_fn(
            len(rows), SH.flat_stage_sharding(self.mesh, self.mesh_axes))
        return stack(*rows)

    def _fuse_flat(self, stage, weights, alpha, *, donate: bool):
        if isinstance(stage, MixedStage):
            return self._fuse_mixed(stage, weights, alpha)
        if self.mesh is not None:
            return ops.fuse_flat_sharded(
                self._base_flat, stage, weights, alpha,
                mesh=self.mesh, axes=self.mesh_axes)
        return ops.fuse_flat(self._base_flat, stage, weights, alpha, donate=donate)

    def _fuse_mixed(self, ms: MixedStage, weights, alpha):
        """Screen+fuse a mixed cohort: compressed deltas are decoded and
        accumulated on device in the same pass as the fuse — never into a
        dense ``[N]`` row per contributor — and the sq statistics come
        back scattered to cohort order, so ``_finalize_flat``'s screen and
        zero-weight re-pass see the same ``[K]`` layout as a dense fuse.
        Never donates: the payload stacks must survive a re-pass."""
        w = jnp.asarray(weights, jnp.float32)
        dpos = jnp.asarray(ms.dense_pos, jnp.int32)
        cpos = jnp.asarray(ms.comp_pos, jnp.int32)
        wc = jnp.take(w, cpos)
        dense = ms.dense if len(ms.dense_pos) else None
        wd = jnp.take(w, dpos) if len(ms.dense_pos) else None
        if self.mesh is not None:
            fused, sq_split = ops.fuse_flat_compressed_sharded(
                self._base_flat, ms.indices, ms.values, ms.scales, wc, alpha,
                mesh=self.mesh, axes=self.mesh_axes, block=ms.block,
                dense=dense, dense_weights=wd)
        else:
            fused, sq_split = ops.fuse_flat_compressed(
                self._base_flat, ms.indices, ms.values, ms.scales, wc, alpha,
                block=ms.block, dense=dense, dense_weights=wd)
        # sq_split is (dense..., compressed...); scatter back to cohort order
        perm = jnp.concatenate([dpos, cpos])
        sq = jnp.zeros((ms.k,), jnp.float32).at[perm].set(sq_split)
        return fused, sq

    def _decode_compressed_dense(self, payloads, meta, *, base=None):
        """Slow-path decode of a compressed submission to a dense host
        ``[N]`` row (layout/geometry fallbacks only): Δ scattered dense,
        plus ``base`` (default: the current base)."""
        if base is None:
            base = self.flat_base_host()
        if meta.get("sharded") and meta.get("shard_spec"):
            ss = ShardedFlatSpec.from_json(meta["shard_spec"])
            return jnp.asarray(delta_decode_sharded(payloads, ss, base))
        return jnp.asarray(delta_decode(payloads[0], base))

    def _decode_vs_declared(self, payloads, meta, declared: int):
        """Vintage-mismatch fallback (belt and braces under the service's
        admission pin): decode against the base the rider *declared*,
        loaded from its retained ``base_iterNNNN.npz`` — a compressed row
        is never decoded against a base it was not computed from."""
        path = (os.path.join(self.root, f"base_iter{declared:04d}.npz")
                if self.root else None)
        if path is None or not os.path.exists(path):
            raise ValueError(
                f"compressed row declares base_iteration={declared} but the "
                f"repository is at iteration {self.iteration} and "
                f"base_iter{declared:04d}.npz is not on disk — cannot decode "
                "(compact keep_bases must cover the declared vintage)")
        base = np.asarray(self._spec.flatten(ckpt.load(path)))
        return self._decode_compressed_dense(payloads, meta, base=base)

    def _publish_flat(self, fused: jax.Array):
        """Fused flat buffer -> the new base pytree (+ cached flat form)."""
        row = self._sspec.unshard(fused) if self.mesh is not None else fused
        self._base = self._spec.unflatten(row)
        self._base_flat = fused

    # -- publish subscription (fuse-to-serve hot path) ------------------
    def add_publish_listener(self, fn) -> None:
        """Register ``fn(iteration, base, flat)`` to run after every base
        movement — cohort publish, async contribution, and ``rollback``
        (where ``iteration`` moves *backwards*).  Called on whichever
        thread published, after the iteration bump, with a consistent
        snapshot: ``base`` is the immutable published pytree and ``flat``
        its cached flat form (``None`` when the engine keeps no flat
        cache, e.g. after a rollback restore).  Listeners must be cheap
        and must not raise; a ``ServingWorker`` stores the snapshot and
        does the device transfer on its own thread (docs/serving.md)."""
        self._publish_listeners.append(fn)

    def _notify_publish(self) -> None:
        for fn in list(self._publish_listeners):
            fn(self.iteration, self._base, self._base_flat)

    def _staging_iteration(self) -> int:
        """The iteration newly staged uploads belong to: one ahead of the
        repository while a fuse is in flight (its publish will advance
        ``iteration`` before the staged cohort fuses)."""
        return self.iteration + (1 if self._inflight is not None else 0)

    def _contrib_path(self, idx: int) -> str:
        return os.path.join(
            self.root,
            f"iter{self._staging_iteration():04d}_contrib{idx:03d}.npz")

    # -- spill manifest -------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST)

    def _write_manifest(self) -> None:
        """Persist the staged-but-unfused row list (back + front sides).
        Called with the row file already on disk, so a crash between row
        write and manifest write only loses the newest row — never records
        a row that does not exist."""
        ckpt.save_json_atomic(self._manifest_path(), {
            "version": 1,
            "entries": self._buffers.manifest_entries(),
        })

    def _spill_row(self, row: jax.Array, idx: int, weight) -> str:
        """Write one staged row to the npz root (per-shard slices under a
        mesh, portable [N] otherwise), then append it to the manifest —
        synchronously, or on the spill executor when ``spill_workers>0``."""
        path = self._contrib_path(idx)
        side = self._buffers.front
        spec, sspec, mesh = self._spec, self._sspec, self.mesh
        row_host = np.asarray(row)
        entry = {
            "file": os.path.basename(path),
            "idx": idx,
            # the iteration this row will fuse INTO the publish of: a
            # manifest entry with staged_at < the recorded repository
            # iteration was already consumed (its publish landed before the
            # manifest rewrite did) and recovery must skip it, or a crash
            # in that window would double-apply the cohort
            "staged_at": self._staging_iteration(),
            "weight": None if weight is None else float(weight),
            "dtype": spec.dtype,
            "size": spec.size,
            "sharded": mesh is not None,
        }
        if mesh is not None:
            entry["shard_spec"] = sspec.to_json()

        def write():
            if mesh is not None:
                ckpt.save_flat_shards(
                    path, sspec.shard_slices(row_host), spec, sspec)
            else:
                ckpt.save_flat(path, row_host, spec)
            with self._manifest_lock:
                side.manifest.append(entry)
                self._write_manifest()

        if self._spill_pool is not None:
            fut = self._spill_pool.submit(write)
            self._spill_futures.append(fut)
            # readback waits on exactly THIS row's write, not the whole
            # queue — the fuse's spill loads pipeline against the writer
            self._row_futures[path] = fut
        else:
            write()
        return path

    def _drain_spill(self) -> None:
        """Wait for ALL queued spill/publish writes (no-op when
        synchronous); re-raise the first failure so a lost row cannot be
        silently fused over."""
        futures, self._spill_futures = self._spill_futures, []
        self._row_futures.clear()
        for f in futures:
            f.result()

    # -- contributor-facing API ----------------------------------------
    def download(self):
        """Contributor pulls the current base model (Fig. 1, step 1).
        Finalizes any in-flight fuse first, so the published base is always
        the latest."""
        self._finalize_inflight()
        return self._base

    def upload(self, params, fisher=None, weight: Optional[float] = None) -> int:
        """Contributor pushes a finetuned model (Fig. 1, step 3), optionally
        with its diagonal Fisher (for fusion_op="fisher") and a contribution
        weight (§8 "assigning individual weights to each contributor" — e.g.
        dataset size; used when fusion_op="average"/"damped").  Returns a
        contribution ticket id.

        On the flat engine the pytree is folded into a contiguous staging
        row right here and released — the Repository never holds K live
        pytrees.  Rows stage into the FRONT buffer, so uploads proceed while
        an async fuse runs on the back buffer.  With ``spill=True`` the row
        goes to the npz root instead (atomic write + manifest append: the
        row survives a crash) and only its path stays in memory."""
        side = self._buffers.front
        idx = len(side.rows)
        if self.use_flat:
            self._ensure_flat_base()
            row = self._spec.flatten(params)
            if self.spill:
                side.rows.append(self._spill_row(row, idx, weight))
            else:
                if self.root:
                    # archived contribution stays the portable [N] form
                    ckpt.save_flat(self._contrib_path(idx), row, self._spec)
                side.rows.append(
                    self._stage_row(row) if self.mesh is not None else row)
        else:
            side.rows.append(params)
            if self.root:
                ckpt.save(self._contrib_path(idx), params)
        side.fishers.append(fisher)
        side.weights.append(weight)
        return idx

    def ingest_spilled(self, path: str, *, weight: Optional[float] = None,
                       meta: Optional[Dict[str, Any]] = None) -> int:
        """Queue-ingest entry point (docs/service_loop.md): register an
        already-on-disk flat row — e.g. a contribution-queue submission —
        as a staged contribution **without copying it**.  The file itself
        becomes the spill row: its (root-relative) path is appended to the
        staging manifest atomically, so from this call on the row enjoys
        the same exactly-once crash guarantees as a spilled ``upload``
        (recovered by ``open``, retired only by the publish that consumed
        it).  The row's recorded FlatSpec is validated against the base;
        torn or mismatched files raise without touching the manifest.

        Requires ``spill=True`` — without the manifest there is nothing to
        make the hand-off durable.  ``meta=`` accepts a pre-read
        ``flat_row_meta`` result (the service's admission peek) so the row
        header is not parsed twice.  Returns the contribution ticket id."""
        if not self.spill:
            raise ValueError("ingest_spilled requires spill=True — the "
                             "staging manifest is what makes queue ingest "
                             "crash-safe")
        self._ensure_flat_base()
        path = os.path.abspath(path)
        rel = os.path.relpath(path, os.path.abspath(self.root))
        if rel.startswith(".."):
            raise ValueError(f"ingested rows must live under root= "
                             f"({path} is outside {self.root})")
        if meta is None:
            meta = ckpt.flat_row_meta(path)  # raises on torn / non-flat files
        if meta["dtype"] != self._spec.dtype or int(meta["size"]) != self._spec.size:
            raise ValueError(
                f"row {os.path.basename(path)} has FlatSpec(dtype="
                f"{meta['dtype']}, N={meta['size']}) but the repository base "
                f"is (dtype={self._spec.dtype}, N={self._spec.size}) — "
                "refusing to ingest a mismatched row")
        side = self._buffers.front
        idx = len(side.rows)
        entry = {
            "file": rel.replace(os.sep, "/"),
            "idx": idx,
            "staged_at": self._staging_iteration(),
            "weight": None if weight is None else float(weight),
            "dtype": self._spec.dtype,
            "size": self._spec.size,
            "sharded": bool(meta["sharded"]),
        }
        if meta.get("shard_spec"):
            entry["shard_spec"] = meta["shard_spec"]
        if meta.get("compressed"):
            # by-reference compressed staging: the queue npz holds the
            # DeltaPayload(s), decoded only at dispatch.  The declared
            # vintage rides in the manifest so dispatch and recovery can
            # re-check it (a delta only means anything against the exact
            # base it was computed from — docs/service_loop.md).
            entry["compressed"] = True
            entry["codec"] = meta.get("delta_spec")
            extra = meta.get("extra") or {}
            bi = extra.get("base_iteration")
            if bi is not None:
                entry["base_iteration"] = int(bi)
            # family-vintage backstop: a delta is only decodable against
            # the exact base it was encoded from, and under a base family
            # that base is named.  The service's routed admission rejects
            # cross-family deltas before ingest; this guard makes the
            # invariant unconditional for direct callers too.
            if self.family_name is not None:
                declared = str(extra.get("family") or "main")
                if declared != self.family_name:
                    raise ValueError(
                        f"stale: delta encoded against family "
                        f"{declared!r}, but this member is "
                        f"{self.family_name!r} — refusing to decode "
                        "against the wrong base")
                entry["family"] = declared
        side.rows.append(path)
        side.fishers.append(None)
        side.weights.append(weight)
        with self._manifest_lock:
            side.manifest.append(entry)
            self._write_manifest()
        return idx

    # -- novelty admission sketch (docs/service_loop.md) -----------------
    def _sketch_path(self) -> str:
        return os.path.join(self.root, SKETCH_FILE)

    def enable_cohort_sketch(self, *, window: int = 32,
                             n_buckets: int = SKETCH_BUCKETS) -> CohortSketch:
        """Create (or adopt) the persisted ``CohortSketch`` the novelty
        admission screen queries.  An on-disk ``cohort_sketch.json``
        (recovered by ``open``) is reused when its layout matches —
        ``window`` always follows the caller (the admission policy wins
        over whatever a previous service instance ran with) — otherwise a
        fresh sketch is built.  The current base's sketch is computed and
        the state persisted atomically before returning, so the screen's
        history is durable from the first admission on."""
        if not self.use_flat:
            raise ValueError("cohort sketch requires the flat engine — the "
                             "row sketch is a statistic over flat [N] rows")
        self._ensure_flat_base()
        sk = self.cohort_sketch
        if sk is not None and (sk.size != self._spec.size
                               or sk.n_buckets != n_buckets):
            warnings.warn(
                f"cohort sketch (size={sk.size}, n_buckets={sk.n_buckets}) "
                f"does not match the requested layout (size="
                f"{self._spec.size}, n_buckets={n_buckets}) — rebuilding; "
                "the screen history restarts empty")
            sk = None
        if sk is None:
            sk = CohortSketch(self._spec.size, n_buckets, window)
        else:
            sk.window = int(window)
            del sk.entries[: -sk.window]
        self.cohort_sketch = sk
        self._refresh_base_sketch()
        return sk

    def save_cohort_sketch(self) -> None:
        """Persist the cohort sketch with the manifest's atomic-write
        discipline (no-op for an in-memory repository or before
        ``enable_cohort_sketch``)."""
        if self.cohort_sketch is not None and self.root:
            # compact form: this file is rewritten once per admission, and
            # it is machine state (nobody diffs a sketch by eye)
            ckpt.save_json_atomic(self._sketch_path(),
                                  self.cohort_sketch.to_json(), indent=None)

    def _sketch_of_staged(self, arr) -> np.ndarray:
        """Sketch a staged row — ``[N]`` single-device or ``[S, shard_len]``
        block-cyclic (per-shard partials, one psum) — to host float32."""
        nb = (self.cohort_sketch.n_buckets if self.cohort_sketch is not None
              else SKETCH_BUCKETS)
        if getattr(arr, "ndim", 1) == 2:
            out = ops.row_sketch_sharded(
                arr, mesh=self.mesh, axes=self.mesh_axes,
                block=self._sspec.block, n_buckets=nb)
        else:
            out = ops.row_sketch(arr, nb)
        return np.asarray(jax.device_get(out))

    def _refresh_base_sketch(self) -> None:
        """Recompute the base's sketch (the screen's distance
        normalizer) and persist — called at every publish so a restarted
        daemon screens against the same scale.  The sketch file is
        advisory state: a crash that loses this write only leaves the
        previous base's sketch as the normalizer, never double-fuses.
        No-op on the per-leaf engine (a repository reopened there keeps
        its recovered sketch history untouched for the next flat run)."""
        if self.cohort_sketch is None or not self.use_flat:
            return
        self._ensure_flat_base()  # rebuilt lazily after publish/rollback
        self.cohort_sketch.set_base(self._sketch_of_staged(self._base_flat),
                                    iteration=self.iteration)
        self.save_cohort_sketch()

    def sketch_row_file(self, path: str, *, meta: Optional[Dict[str, Any]] = None
                        ) -> np.ndarray:
        """Content sketch of an on-disk flat row (a queue submission), in
        one read: sharded files matching the mesh layout are sketched
        per shard with a single psum (the full ``[N]`` row never
        materializes on host); everything else reads the portable row.
        Raises on torn/unreadable files — callers quarantine like any
        other unreadable submission.  ``meta=`` reuses a pre-read
        ``flat_row_meta`` peek (skips re-opening the npz header)."""
        self._ensure_flat_base()
        compressed = (ckpt.is_flat_compressed(path) if meta is None
                      else bool(meta.get("compressed")))
        if compressed:
            return self.sketch_delta_file(path)
        sharded = (ckpt.is_flat_sharded(path) if meta is None
                   else bool(meta["sharded"]))
        if not sharded:
            row, _ = ckpt.load_flat(path)
            return self._sketch_of_staged(row)
        return self._sketch_of_staged(self._load_staged_row(path))

    def sketch_delta_file(self, path: str, *,
                          meta: Optional[Dict[str, Any]] = None) -> np.ndarray:
        """Content sketch of a delta-compressed submission without ever
        materializing its dense row: the current base's sketch is
        corrected bucket-wise from the sparse decoded delta
        (``repro.utils.flat.sketch_apply_delta``), reading base values
        only at the delta's own indices.  Matches ``row_sketch_host`` of
        the decoded row up to float rounding, so the novelty screen's
        distances are interchangeable between dense and compressed
        submissions."""
        del meta  # the payload load re-reads the header regardless
        self._ensure_flat_base()
        payloads, dmeta = ckpt.load_flat_delta(path)
        nb = (self.cohort_sketch.n_buckets if self.cohort_sketch is not None
              else SKETCH_BUCKETS)
        if (self.cohort_sketch is not None
                and self.cohort_sketch.base is not None):
            base_sk = np.asarray(self.cohort_sketch.base, np.float64)
        else:
            base_sk = self._sketch_of_staged(self._base_flat).astype(np.float64)
        gis: List[np.ndarray] = []
        dvs: List[np.ndarray] = []
        if bool(dmeta["delta_spec"].get("sharded")):
            ss = ShardedFlatSpec.from_json(dmeta["shard_spec"])
            for s, p in enumerate(payloads):
                li, dv = delta_entries(p)
                gi = ss.global_of(s, li)
                keep = gi < self._spec.size  # drop block-grid padding slots
                gis.append(gi[keep])
                dvs.append(dv[keep])
        else:
            li, dv = delta_entries(payloads[0])
            gis.append(np.asarray(li, np.int64))
            dvs.append(dv)
        gi = np.concatenate(gis) if gis else np.zeros((0,), np.int64)
        dv = np.concatenate(dvs) if dvs else np.zeros((0,), np.float32)
        base_at = self.flat_base_host()[gi]
        sk = sketch_apply_delta(base_sk, gi, dv, base_at, n_buckets=nb)
        return np.asarray(sk, np.float32)

    def contribute_async(self, params, *, alpha: Optional[float] = None) -> FusionRecord:
        """Asynchronous contribution (paper §8: "it would be beneficial if
        the repository was updated asynchronously"): immediately merge ONE
        finetuned model into the base via a damped task-arithmetic update
        θ ← θ + α·(θ_c − θ), without waiting for a cohort (Ilharco et al.
        2022).  α defaults to 1/(1 + iteration) — early contributions move
        the base more, later ones refine it (Polyak-style averaging).

        On the flat engine this is one streaming kernel pass: the same
        launch yields the merged model and the screening norm; if the screen
        rejects, the merged buffer is simply discarded."""
        self.flush()  # quiesce: its publish below must not race queued writes
        a = alpha if alpha is not None else 1.0 / (1.0 + self.iteration)
        t0 = time.time()
        if self.use_flat:
            self._ensure_flat_base()
            row = self._spec.flatten(params)
            if self.mesh is not None:
                stage = self._stage_row(row)[None]
            else:
                stage = row[None, :]
            fused, sq = self._fuse_flat(stage, jnp.ones((1,), jnp.float32), a,
                                        donate=False)
            if self.screen:
                norm = norms_from_sq(jax.device_get(sq))[0]
                report = screen_norms([norm], mad_threshold=self.mad_threshold)
                if not report.accepted:
                    raise RuntimeError(f"async contribution rejected: {report.reasons}")
            fused.block_until_ready()
            if self.mesh is not None:
                new_base = self._spec.unflatten(self._sspec.unshard(fused))
            else:
                new_base = self._spec.unflatten(fused)
            new_flat = fused
        else:
            if self.screen:
                report = screen_contributions(
                    self._base, [params], mad_threshold=self.mad_threshold)
                if not report.accepted:
                    raise RuntimeError(f"async contribution rejected: {report.reasons}")
            new_base = fusion.damped(self._base, [params], alpha=a)
            new_flat = None
        rec = FusionRecord(
            iteration=self.iteration, n_contributions=1, n_accepted=1,
            op=f"async-damped({a:.3f})", diff_norms=[], wall_time=time.time() - t0,
        )
        self.history.append(rec)
        if self.keep_history:
            self._snapshots.append(self._base)
        self._base = new_base
        self._base_flat = new_flat
        self.iteration += 1
        self._refresh_front_staging()
        if self.root:
            self._persist_base()
            if self.spill or os.path.exists(self._manifest_path()):
                with self._manifest_lock:
                    self._write_manifest()
        self._refresh_base_sketch()  # async publishes move the base too
        self._notify_publish()
        return rec

    # -- repository maintenance ----------------------------------------
    def fuse_pending(
        self,
        buffer: Optional[Union[StagedBuffer, jax.Array]] = None,
        *,
        wait: bool = True,
        alpha: Optional[float] = None,
        screen: Optional[bool] = None,
        op: Optional[str] = None,
    ) -> Union[FusionRecord, PendingFusion]:
        """Screen + fuse a cohort into the new base (Fig. 1, step 4).

        With no arguments: swap the front staging buffer to the back and
        fuse it (finalizing any previously in-flight fuse first).
        ``wait=False`` dispatches the screen+fuse to the device and returns
        a ``PendingFusion`` immediately — uploads of the next cohort then
        overlap the device fuse; ``flush()`` (or the next ``fuse_pending``
        / ``download``) finalizes and publishes.  On the per-leaf engine
        ``wait`` is ignored (the oracle path is synchronous).

        ``buffer=`` fuses an explicit staged operand instead — a
        ``StagedBuffer`` handle (or raw ``[K, N]`` / sharded
        ``[K, S, shard_len]`` array) prepared by the caller; the front
        staging buffer is left untouched.  ``alpha=`` overrides the
        per-op step size, ``screen=`` overrides the §9 screen, and
        ``op=`` relabels the FusionRecord — the family cross-fuse uses
        all three (member bases are not a contributor cohort); they are
        only meaningful with ``buffer=``."""
        self._finalize_inflight()
        if buffer is not None:
            return self._fuse_buffer(buffer, wait=wait, alpha=alpha,
                                     screen=screen, op=op)
        if alpha is not None or screen is not None or op is not None:
            raise ValueError("alpha=/screen=/op= overrides require buffer=")
        if not self._pending:
            raise RuntimeError("no contributions to fuse")
        t0 = time.time()
        if not self.use_flat:
            with self._manifest_lock:
                back = self._buffers.swap()
            self._mark_back_fusing()
            try:
                rec = self._fuse_pending_pytree(t0, back)
            except Exception:
                self._restore_back()
                raise
            self._retire_back()
            self._after_publish(rec)
            return rec
        with self._manifest_lock:  # workers read both sides via manifest_entries
            back = self._buffers.swap()
        try:
            pf = self._dispatch_flat(back, t0)
        except Exception:
            self._restore_back()
            raise
        self._inflight = pf
        if wait:
            return self._finalize_inflight()
        return pf

    def flush(self) -> Optional[FusionRecord]:
        """Quiesce the repository: finalize the in-flight fuse, if any,
        and drain every queued spill/publish write.  Returns the finalized
        FusionRecord (None when nothing was in flight)."""
        rec = self._finalize_inflight()
        self._drain_spill()
        return rec

    def _finalize_inflight(self) -> Optional[FusionRecord]:
        """Finalize the in-flight fuse: block on the screening statistic,
        run the weight-zeroed re-pass for rejections, publish the fused
        base, and advance the iteration.  Queued spill writes keep
        draining on the executor — only ``flush()`` waits for them."""
        pf, self._inflight = self._inflight, None
        if pf is None:
            return None
        try:
            rec = self._finalize_flat(pf)
        except Exception:
            # cohort not published: return its rows to the front buffer so
            # they are retried (diluted by new uploads) rather than lost
            self._restore_back()
            raise
        self._retire_back()
        self._after_publish(rec)
        return rec

    def _dispatch_flat(self, back: StagingSide, t0: float) -> PendingFusion:
        """Issue pass 1 (fused + sq_diff in one read of the staged buffer)
        without blocking: jax dispatch is asynchronous, so the device
        crunches while the host stages the next cohort.  The buffer is kept
        alive (no donation) only if a screening re-pass might need it."""
        self._ensure_flat_base()
        K = len(back.rows)
        stage = self._stage_cohort(back)
        w = self._cohort_weights(K, back.weights)
        alpha = self._flat_alpha(K)
        mixed = isinstance(stage, MixedStage)
        fused, sq = self._fuse_flat(stage, w, alpha,
                                    donate=not self.screen and not mixed)
        try:
            # start moving the [K] screening statistic to the host as soon
            # as the fuse produces it, so finalize's device_get is a
            # handshake rather than a transfer
            sq.copy_to_host_async()
        except (AttributeError, RuntimeError):
            pass  # sharded/older arrays may not support it; finalize copies
        # every back row's spill write (and manifest append) has completed
        # by now — _load_staged_row waited on the per-row futures — so the
        # in-flight mark covers the whole cohort
        self._mark_back_fusing()
        return PendingFusion(
            stage=stage if self.screen else None,
            fused=fused, sq=sq, weights=w, k=K, t0=t0)

    def _stage_cohort(self, back: StagingSide):
        """Build the fuse operand for the back cohort.  All-dense cohorts
        take the historical path unchanged (a stacked ``StagedBuffer``,
        donation-eligible); any delta-compressed submission among the rows
        yields a ``MixedStage`` instead."""
        if any(isinstance(p, str) and ckpt.is_flat_compressed(p)
               for p in back.rows):
            return self._stage_mixed(back)
        rows = [self._load_staged_row(p) for p in back.rows]
        return StagedBuffer(self._stack_stage(rows))

    def _stage_mixed(self, back: StagingSide):
        """Partition the back cohort into dense rows and compressed payload
        stacks.  Compressed rows ride sparse on the fast path only when
        their declared vintage matches the current iteration (the
        service's admission pin; re-checked here belt-and-braces), their
        layout matches the repository (sharded payloads on a matching
        mesh, whole-row payloads single-device), and their codec geometry
        agrees across the cohort — anything else host-decodes to a dense
        row against the correct base and joins the dense side."""
        entries = {e.get("file"): e for e in back.manifest}
        root = os.path.abspath(self.root) if self.root else None
        dense_rows: List[Any] = []
        dense_pos: List[int] = []
        payload_sets: List[list] = []
        comp_pos: List[int] = []
        geom = None
        for i, p in enumerate(back.rows):
            if not (isinstance(p, str) and ckpt.is_flat_compressed(p)):
                dense_rows.append(self._load_staged_row(p))
                dense_pos.append(i)
                continue
            fut = self._row_futures.pop(p, None)
            if fut is not None:
                fut.result()
            payloads, meta = ckpt.load_flat_delta(p)
            rel = (os.path.relpath(p, root).replace(os.sep, "/")
                   if root else None)
            entry = entries.get(rel, {})
            declared = entry.get(
                "base_iteration",
                (meta.get("extra") or {}).get("base_iteration"))
            if declared is not None and int(declared) != self.iteration:
                dense_rows.append(
                    self._decode_vs_declared(payloads, meta, int(declared)))
                dense_pos.append(i)
                continue
            sharded_payload = bool(meta["delta_spec"].get("sharded"))
            if self.mesh is not None:
                fast = (sharded_payload
                        and meta.get("shard_spec") is not None
                        and ShardedFlatSpec.from_json(meta["shard_spec"])
                        == self._sspec)
            else:
                fast = not sharded_payload
            p0 = payloads[0]
            this = (len(payloads), p0.block, p0.k_per_block, p0.n_blocks)
            if fast and geom is None:
                geom = this
            elif this != geom:
                fast = False
            if fast:
                payload_sets.append(payloads)
                comp_pos.append(i)
            else:
                dense_rows.append(self._decode_compressed_dense(payloads, meta))
                dense_pos.append(i)
        if not comp_pos:
            # every compressed row fell back dense (positions stayed in
            # cohort order, so a plain stacked buffer is exact)
            return StagedBuffer(self._stack_stage(dense_rows))
        if self.mesh is not None:
            idx = np.stack([[q.indices for q in pl] for pl in payload_sets])
            val = np.stack([[q.values for q in pl] for pl in payload_sets])
            scl = np.stack([[q.scales for q in pl] for pl in payload_sets])
        else:
            idx = np.stack([pl[0].indices for pl in payload_sets])
            val = np.stack([pl[0].values for pl in payload_sets])
            scl = np.stack([pl[0].scales for pl in payload_sets])
        dense_stage = (StagedBuffer(self._stack_stage(dense_rows))
                       if dense_rows else None)
        return MixedStage(
            dense=dense_stage,
            indices=jnp.asarray(idx), values=jnp.asarray(val),
            scales=jnp.asarray(scl), block=geom[1],
            dense_pos=np.asarray(dense_pos, np.int32),
            comp_pos=np.asarray(comp_pos, np.int32))

    def _finalize_flat(self, pf: PendingFusion) -> FusionRecord:
        """The host half of the screen+fuse: pull sq_diff (the only device
        sync), apply the §9 decision rule, re-pass with zeroed weights on
        rejections, and publish."""
        fused = pf.fused
        report: Optional[ScreenReport] = None
        n_accepted = pf.k
        use_screen = self.screen if pf.use_screen is None else pf.use_screen
        if use_screen:
            norms = norms_from_sq(jax.device_get(pf.sq))
            report = screen_norms(norms, mad_threshold=self.mad_threshold)
            n_accepted = len(report.accepted)
            if not report.accepted:
                raise RuntimeError(f"all contributions rejected: {report.reasons}")
            if report.rejected:
                w2 = np.asarray(jax.device_get(pf.weights), np.float32).copy()
                w2[report.rejected] = 0.0
                alpha = (self._flat_alpha(n_accepted) if pf.alpha is None
                         else pf.alpha)
                fused, _ = self._fuse_flat(
                    pf.stage, jnp.asarray(w2), alpha, donate=True)
        fused.block_until_ready()
        rec = FusionRecord(
            iteration=self.iteration,
            n_contributions=pf.k,
            n_accepted=n_accepted,
            op=pf.op or self.fusion_op,
            diff_norms=report.diff_norms if report else [],
            wall_time=time.time() - pf.t0,
        )
        if self.keep_history:
            self._snapshots.append(self._base)
        self._publish_flat(fused)
        pf.record = rec
        return rec

    def _fuse_buffer(self, buffer, *, wait: bool,
                     alpha: Optional[float] = None,
                     screen: Optional[bool] = None,
                     op: Optional[str] = None,
                     ) -> Union[FusionRecord, PendingFusion]:
        """Fuse an explicit staged operand (``fuse_pending(buffer=...)``)."""
        if not self.use_flat:
            raise ValueError("fuse_pending(buffer=...) requires the flat engine")
        self._ensure_flat_base()
        if not isinstance(buffer, StagedBuffer):
            buffer = StagedBuffer(jnp.asarray(buffer))
        if self.mesh is not None:
            want = (self._sspec.n_shards, self._sspec.shard_len)
            if buffer.data.shape[1:] != want:
                raise ValueError(
                    f"staged buffer shape {buffer.data.shape} does not match "
                    f"the sharded layout [K, {want[0]}, {want[1]}]")
        elif buffer.data.shape[1:] != (self._spec.size,):
            raise ValueError(
                f"staged buffer shape {buffer.data.shape} does not match "
                f"the flat layout [K, {self._spec.size}]")
        t0 = time.time()
        K = buffer.k
        w = self._cohort_weights(K, [])
        use_screen = self.screen if screen is None else bool(screen)
        a = self._flat_alpha(K) if alpha is None else float(alpha)
        # never donate here: the operand belongs to the CALLER (unlike the
        # freshly stacked buffer in _dispatch_flat) and must stay valid
        fused, sq = self._fuse_flat(buffer, w, a, donate=False)
        pf = PendingFusion(
            stage=buffer if use_screen else None,
            fused=fused, sq=sq, weights=w, k=K, t0=t0,
            alpha=None if alpha is None else float(alpha),
            use_screen=None if screen is None else use_screen, op=op)
        if not wait:
            self._inflight = pf
            return pf
        rec = self._finalize_flat(pf)
        self._after_publish(rec)
        return rec

    def _retire_back(self) -> None:
        """Drop the consumed back buffer.  Its manifest entries are NOT
        rewritten here: the manifest may only forget a cohort once the new
        base is durably on disk, so the rewrite is sequenced after the base
        persist in ``_after_publish`` (on the spill executor when one is
        configured)."""
        with self._manifest_lock:  # workers read both sides via manifest_entries
            self._buffers.retire_back()

    def _mark_back_fusing(self) -> None:
        """Stamp the back cohort's manifest entries as in-flight and
        persist the mark.  Recovery may treat an entry as consumed ONLY if
        it carries this mark AND the recorded iteration moved past its
        ``staged_at`` — unconsumed front rows can share the same staged_at
        (e.g. around a ``contribute_async`` publish) and must never be
        skipped."""
        back = self._buffers.back
        if back is None or not back.manifest:
            return
        with self._manifest_lock:
            for e in back.manifest:
                e["fusing"] = True
            if self.root and (self.spill
                              or os.path.exists(self._manifest_path())):
                self._write_manifest()

    def _restore_back(self) -> None:
        """Un-swap after a failed fuse: the back cohort returns to the head
        of the front buffer (in-flight marks dropped), so nothing staged is
        lost."""
        with self._manifest_lock:
            back = self._buffers.back
            if back is None:
                return
            for e in back.manifest:
                e.pop("fusing", None)
            front = self._buffers.front
            back.rows.extend(front.rows)
            back.fishers.extend(front.fishers)
            back.weights.extend(front.weights)
            back.manifest.extend(front.manifest)
            self._buffers.front = back
            self._buffers.back = None

    def _refresh_front_staging(self) -> None:
        """Pending (front) rows survive publishes they did not take part
        in: re-stamp their manifest entries to the next staging iteration,
        so recovery never mistakes them for a consumed cohort.  Callers
        hold no lock; the stamp is a plain dict write raced only by
        ``_write_manifest`` readers, which tolerate either value."""
        for e in self._buffers.front.manifest:
            e["staged_at"] = self._staging_iteration()

    def _after_publish(self, rec: FusionRecord) -> None:
        self.history.append(rec)
        self.iteration += 1
        self._refresh_front_staging()
        if not self.root:
            return
        if self._spill_pool is not None:
            # drain the publish write on the spill executor too: the base
            # npz + repository.json leave the fuse critical path.  State is
            # captured by value (the pytree is immutable), so later host
            # mutations cannot race the write; the manifest rewrite is
            # sequenced AFTER the base persist inside the same task.  A
            # crash before the persist recovers the cohort against the
            # previous base; a crash between persist and rewrite is caught
            # by the staged_at marker (the recorded iteration moved past
            # the entries, so recovery skips them instead of re-applying).
            it, base, meta = self.iteration, self._base, self._render_meta()
            def task():
                self._persist_base(it, base, meta)
                faults.crash_point("repo.post_publish_pre_manifest")
                with self._manifest_lock:
                    self._write_manifest()
            self._spill_futures.append(self._spill_pool.submit(task))
        else:
            self._persist_base()
            faults.crash_point("repo.post_publish_pre_manifest")
            if self.spill or os.path.exists(self._manifest_path()):
                # the second arm: a non-spill reopen that fused recovered
                # rows must still retire them from the manifest, or a later
                # spill=True reopen would re-apply the cohort
                with self._manifest_lock:
                    self._write_manifest()
        # the novelty screen's normalizer tracks the published base
        # (docs/service_loop.md); runs after the durability-critical writes
        # because the sketch is advisory — a crash here costs at most one
        # stale-scale admission decision, never a double fuse
        self._refresh_base_sketch()
        self._notify_publish()

    def _cohort_weights(self, K: int, staged_weights: Sequence[Any]) -> jnp.ndarray:
        """Per-contributor weights for the flat engine (average/damped)."""
        kw = self.fusion_kwargs
        if self.fusion_op in ("average", "damped"):
            if "weights" in kw:
                w = list(kw["weights"])
                if len(w) != K:
                    raise ValueError(f"len(fusion_kwargs['weights'])={len(w)} != K={K}")
                return jnp.asarray(w, jnp.float32)
            if staged_weights and all(w is not None for w in staged_weights):
                return jnp.asarray(list(staged_weights), jnp.float32)
        return jnp.ones((K,), jnp.float32)

    def _flat_alpha(self, n_effective: int) -> float:
        """The kernel's damping coefficient for the configured operator."""
        if self.fusion_op == "damped":
            return float(self.fusion_kwargs.get("alpha", 1.0))
        if self.fusion_op == "task_arithmetic":
            # θ + λ·Σ(θ_c − θ) == θ + (λ·K)·(mean − θ)
            return float(self.fusion_kwargs.get("lam", 1.0)) * n_effective
        return 1.0

    def _fuse_pending_pytree(self, t0: float, back: StagingSide) -> FusionRecord:
        """The seed per-leaf engine (REPRO_NO_KERNELS oracle; also serves
        the operators the kernel does not cover)."""
        models = back.rows
        report: Optional[ScreenReport] = None
        fishers = back.fishers
        weights = back.weights
        if self.screen:
            report = screen_contributions(self._base, models, mad_threshold=self.mad_threshold)
            models = [models[i] for i in report.accepted]
            fishers = [fishers[i] for i in report.accepted]
            weights = [weights[i] for i in report.accepted]
            if not models:
                raise RuntimeError(f"all contributions rejected: {report.reasons}")
        kw = dict(self.fusion_kwargs)
        if self.fusion_op == "fisher":
            if any(f is None for f in fishers):
                raise RuntimeError("fusion_op='fisher' requires upload(..., fisher=...)")
            kw["fishers"] = fishers
        elif (self.fusion_op in ("average", "damped") and "weights" not in kw
              and all(w is not None for w in weights) and weights):
            kw["weights"] = weights
        new_base = fusion.fuse(self.fusion_op, self._base, models, **kw)
        rec = FusionRecord(
            iteration=self.iteration,
            n_contributions=len(back.rows),
            n_accepted=len(models),
            op=self.fusion_op,
            diff_norms=report.diff_norms if report else [],
            wall_time=time.time() - t0,
        )
        if self.keep_history:
            self._snapshots.append(self._base)
        self._base = new_base
        self._base_flat = None
        return rec

    def rollback(self, to_iteration: int, *, keep_staged: bool = False):
        """Paper §8: "backtracking when a harmful update was done".  Any
        in-flight fuse is finalized first.

        The restore source is the in-memory ``keep_history`` snapshot when
        one exists, else the ``compact``-retained on-disk
        ``base_iterNNNN.npz`` — so a service that keeps no pytree history
        can still back out a harmful publish (the regression gate,
        docs/observability.md).  Missing both raises without touching any
        state.

        ``keep_staged=False`` (the historical behavior) drops the staged
        front cohort with the history; ``keep_staged=True`` preserves it —
        staged-but-unfused rows are re-stamped to the rolled-back staging
        iteration, so a gate-tripped publish never loses the *next*
        cohort's admitted rows.

        Crash safety (on-disk repositories): the restored base's npz
        already exists, so the single commit point is the atomic
        ``repository.json`` rewrite.  A kill -9 before it leaves the old
        (pre-rollback) state for the caller to re-detect and retry — the
        whole sequence is idempotent; a kill -9 after it reopens at the
        rolled-back base.  The ``repo.mid_rollback`` seam sits between
        that commit and the staging-manifest rewrite: entries persisted
        with a pre-rollback ``staged_at`` carry no ``fusing`` mark, so
        recovery re-stages them regardless of the stamp."""
        self.flush()  # quiesce: queued manifest/publish writes must settle
        if not (0 <= to_iteration <= self.iteration):
            raise ValueError(
                f"cannot roll back to iteration {to_iteration} from "
                f"{self.iteration}")
        if self.keep_history and to_iteration < len(self._snapshots):
            base = self._snapshots[to_iteration]
        elif self.root is not None:
            path = os.path.join(self.root, f"base_iter{to_iteration:04d}.npz")
            if not os.path.exists(path):
                raise ValueError(
                    f"no snapshot for iteration {to_iteration}: not in "
                    f"memory (keep_history={self.keep_history}) and "
                    f"{os.path.basename(path)} is not on disk — was it "
                    "compacted away? (compact keep_bases must cover the "
                    "rollback depth)")
            base = ckpt.load(path)
            if self._spec is not None:
                rspec = FlatSpec.from_tree(base)
                if rspec.dtype != self._spec.dtype or rspec.size != self._spec.size:
                    raise ValueError(
                        f"{os.path.basename(path)} loads as FlatSpec(dtype="
                        f"{rspec.dtype}, N={rspec.size}) but the repository "
                        f"base is (dtype={self._spec.dtype}, "
                        f"N={self._spec.size}) — refusing to roll back onto "
                        "a mismatched base")
        elif not self.keep_history:
            raise RuntimeError(
                "rollback requires keep_history=True or an on-disk root")
        else:
            raise ValueError(f"no snapshot for iteration {to_iteration}")
        self._base = base
        self._base_flat = None
        self._snapshots = self._snapshots[:to_iteration]
        self.history = self.history[:to_iteration]
        self.iteration = to_iteration
        # the publish guard must follow the regression or later (smaller-
        # iteration) publishes would be skipped as stale
        self._persisted_iteration = min(self._persisted_iteration, to_iteration)
        if keep_staged:
            # the front cohort survives the rollback; its manifest entries
            # follow the new staging iteration like any other publish
            self._refresh_front_staging()
        else:
            self._buffers = BufferPair()
        if self.root:
            # commit point: repository.json now names the rolled-back
            # iteration (its base npz is already durable — it is the
            # restore source, or the snapshot is re-persisted here)
            self._persist_base()
            faults.crash_point("repo.mid_rollback")
        if self.spill and self.root:
            with self._manifest_lock:
                self._write_manifest()
        self._refresh_base_sketch()  # the screen's normalizer moved too
        self._notify_publish()

    def flat_base_host(self) -> np.ndarray:
        """The current base as a host ``[N]`` float row (the form probe
        suites score).  Requires the flat engine."""
        self._ensure_flat_base()
        return np.asarray(self._spec.flatten(self._base))

    def snapshot(self, iteration: int):
        return self._snapshots[iteration]

    def compact(self, *, keep_bases: int = 2) -> Dict[str, int]:
        """Spill compaction / GC (ROADMAP item): reclaim the npz root.

        Deletes

        * superseded ``base_iterNNNN.npz`` files beyond the newest
          ``keep_bases`` (the persisted-current base is always kept — it is
          what ``open`` loads), and
        * archived contribution rows (``iterNNNN_contribMMM.npz``) not
          referenced by the staging manifest — fused cohorts' archives and
          rows orphaned by a pre-publish crash.

        Only *unreferenced* files are ever deleted, and deletion order is
        irrelevant to recovery, so a crash at ANY point mid-compact leaves
        ``open`` a fully recoverable repository: the current base, the
        manifest, and every manifest-referenced row survive by
        construction.  Queue submissions (``queue/``) belong to the service
        loop's own GC and are never touched.  Quiesces first (in-flight
        fuse finalized, spill writes drained).  Returns deletion counts."""
        if not self.root:
            raise ValueError("compact requires an on-disk root")
        if keep_bases < 1:
            raise ValueError(f"keep_bases must be >= 1, got {keep_bases}")
        self.flush()
        with self._manifest_lock:
            referenced = {os.path.normpath(e["file"])
                          for e in self._buffers.manifest_entries()}
        bases: List[tuple] = []
        rows: List[str] = []
        for name in os.listdir(self.root):
            m = _BASE_RE.match(name)
            if m:
                bases.append((int(m.group(1)), name))
            elif _ROW_RE.match(name) and os.path.normpath(name) not in referenced:
                rows.append(name)
        keep = {it for it, _ in sorted(bases)[-keep_bases:]}
        keep.add(self._persisted_iteration)  # open() loads exactly this one
        n_bases = 0
        for it, name in bases:
            if it not in keep:
                os.remove(os.path.join(self.root, name))
                n_bases += 1
        n_rows = 0
        for name in rows:
            os.remove(os.path.join(self.root, name))
            n_rows += 1
        return {"bases_removed": n_bases, "rows_removed": n_rows}

    # -- persistence -----------------------------------------------------
    def _persist_base(self, iteration: Optional[int] = None,
                      base=None, meta: Optional[Dict[str, Any]] = None):
        """Write the current (or a captured) base + repository.json.  The
        captured form is what the spill executor uses: everything it needs
        is bound at submit time, so the worker never reads mutating state.

        Serialized under the publish lock with a monotonic guard: with
        ``spill_workers>=2`` two publish tasks may run concurrently, and a
        slower, older task must neither interleave its repository.json
        write with the newer one nor land after it and regress the
        recorded iteration."""
        it = self.iteration if iteration is None else iteration
        base = self._base if base is None else base
        meta = self._render_meta() if meta is None else meta
        with self._publish_lock:
            if it < self._persisted_iteration:
                return  # a newer publish already landed
            ckpt.save(os.path.join(self.root, f"base_iter{it:04d}.npz"), base)
            if self.extra_meta:
                # re-merge LIVE extra_meta: a publish task captured before
                # a family spawn must not clobber the manifest entry the
                # spawn just recorded
                meta = {**meta, **self.extra_meta}
            # atomic like every other publish artifact: a crash mid-write
            # must not brick Repository.open with truncated repository.json
            ckpt.save_json_atomic(os.path.join(self.root, "repository.json"),
                                  meta, default=_json_default)
            self._persisted_iteration = it

    def _render_meta(self) -> Dict[str, Any]:
        spec = self._spec if self._spec is not None else FlatSpec.from_tree(self._base)
        meta = {
            "iteration": self.iteration,
            "fusion_op": self.fusion_op,
            "fusion_kwargs": self.fusion_kwargs,
            "screen": self.screen,
            "mad_threshold": self.mad_threshold,
            "spill": self.spill,
            # the flat layout the recorded fusion_kwargs / staged rows are
            # valid against; Repository.open refuses a base that disagrees
            "flat_spec": {"dtype": spec.dtype, "size": spec.size},
            "history": [
                {
                    "iteration": r.iteration,
                    "n_contributions": r.n_contributions,
                    "n_accepted": r.n_accepted,
                    "op": r.op,
                    "diff_norms": [float(n) for n in r.diff_norms],
                    "wall_time": r.wall_time,
                }
                for r in self.history
            ],
        }
        # opaque rider keys (e.g. the family manifest) survive every
        # publish of this repository verbatim
        meta.update(self.extra_meta)
        return meta

    # -- crash recovery ---------------------------------------------------
    def _recover_staged(self, manifest: Dict[str, Any], spec: FlatSpec) -> int:
        """Re-stage the staged-but-unfused rows a crash left behind
        (docs/async_repository.md).

        * entries marked in-flight (``fusing``) whose ``staged_at``
          iteration is already behind the repository's are skipped — their
          publish landed and only the manifest rewrite was lost to the
          crash; recovering them would apply the cohort twice.  Entries
          without the mark are always recovered: a publish that did not
          consume them (``contribute_async``, an explicit-buffer fuse) may
          have advanced the iteration past their ``staged_at``;
        * entries whose row file is missing or unreadable (a partial write
          never published by ``os.replace``, or a file deleted out from
          under the manifest) are skipped with a warning;
        * a row whose recorded FlatSpec disagrees with the base raises —
          fusing mismatched rows would silently corrupt the model.

        Recovered entries stay manifest-tracked on every engine, so they
        are only retired by the publish of the fuse that consumes them."""
        if self.use_flat:
            self._ensure_flat_base()
        side = self._buffers.front
        recovered = 0
        for e in manifest.get("entries", []):
            if (e.get("fusing")
                    and int(e.get("staged_at", self.iteration)) < self.iteration):
                continue  # consumed by a publish that landed pre-crash
            if (e.get("compressed") and e.get("base_iteration") is not None
                    and int(e["base_iteration"]) != self.iteration):
                # a compressed delta is only decodable against its declared
                # base; the admission pin makes this unreachable in normal
                # flows, but a repository reopened at a different vintage
                # (operator rollback, hand-edited state) must not mis-decode
                warnings.warn(
                    f"spill recovery: skipping compressed row {e['file']} — "
                    f"encoded against base iteration {e['base_iteration']} "
                    f"but the repository reopened at {self.iteration}")
                continue
            path = os.path.join(self.root, e["file"])
            try:
                meta = ckpt.flat_row_meta(path)
            except Exception as err:  # missing / truncated / not-an-npz
                warnings.warn(
                    f"spill recovery: skipping unreadable staged row "
                    f"{e['file']} ({type(err).__name__}: {err})")
                continue
            if meta["dtype"] != spec.dtype or int(meta["size"]) != spec.size:
                raise ValueError(
                    f"staged row {e['file']} was spilled with "
                    f"FlatSpec(dtype={meta['dtype']}, N={meta['size']}) but the "
                    f"repository base is (dtype={spec.dtype}, N={spec.size}) — "
                    "refusing to recover mismatched rows")
            if self.use_flat and self.spill:
                side.rows.append(path)
            elif self.use_flat:
                side.rows.append(self._load_staged_row(path))
            else:
                # per-leaf engine: rebuild the pytree from the flat row
                if meta.get("compressed"):
                    payloads, _ = ckpt.load_flat_delta(path)
                    base_row = np.asarray(spec.flatten(self._base))
                    if meta.get("sharded"):
                        ss = ShardedFlatSpec.from_json(meta["shard_spec"])
                        row = delta_decode_sharded(payloads, ss, base_row)
                    else:
                        row = delta_decode(payloads[0], base_row)
                    row, rspec = jnp.asarray(row), spec
                elif meta.get("sharded"):
                    with ckpt.FlatShardReader(path) as r:
                        row, rspec = jnp.asarray(r.full_row()), r.spec
                else:
                    row, rspec = ckpt.load_flat(path)
                side.rows.append(rspec.unflatten(row))
            fresh = {k: v for k, v in e.items() if k != "fusing"}
            fresh["staged_at"] = self._staging_iteration()
            side.manifest.append(fresh)
            side.fishers.append(None)
            side.weights.append(e.get("weight"))
            recovered += 1
        if self.root:
            with self._manifest_lock:
                self._write_manifest()
        return recovered

    @classmethod
    def open(cls, root: str, **kw) -> "Repository":
        """Re-open an on-disk repository at its latest base model, restoring
        the fusion configuration, screen settings, and history recorded in
        ``repository.json`` (explicit keyword arguments win).

        The loaded base is validated against the recorded flat layout
        (dtype/N) — a swapped or corrupted ``base_iterNNNN.npz`` raises
        instead of silently applying the recorded fusion_kwargs to the
        wrong model.  Staged-but-unfused rows recorded in the spill
        manifest are recovered into the front staging buffer (and their
        shard placement, under ``mesh=``)."""
        with open(os.path.join(root, "repository.json")) as f:
            meta = json.load(f)
        it = meta["iteration"]
        base = ckpt.load(os.path.join(root, f"base_iter{it:04d}.npz"))
        spec = FlatSpec.from_tree(base)
        recorded = meta.get("flat_spec")
        if recorded and (recorded["dtype"] != spec.dtype
                         or int(recorded["size"]) != spec.size):
            raise ValueError(
                f"repository.json records FlatSpec(dtype={recorded['dtype']}, "
                f"N={recorded['size']}) but base_iter{it:04d}.npz loads as "
                f"(dtype={spec.dtype}, N={spec.size}) — the base checkpoint "
                "does not match the recorded configuration; refusing to apply "
                "the stored fusion_kwargs/screen settings to it")
        kw.setdefault("fusion_op", meta.get("fusion_op", "average"))
        if meta.get("fusion_kwargs"):
            kw.setdefault("fusion_kwargs", meta["fusion_kwargs"])
        kw.setdefault("screen", meta.get("screen", True))
        kw.setdefault("mad_threshold", meta.get("mad_threshold", 5.0))
        # constructed with root=None so __init__ does not re-persist (and
        # clobber) base_iter0000; root/spill are restored afterwards
        # (spill is recorded in repository.json; explicit kwargs win)
        spill = bool(kw.pop("spill", meta.get("spill", False)))
        spill_workers = int(kw.pop("spill_workers", 0))
        repo = cls(base, root=None, **kw)
        repo.iteration = it
        repo.root = root
        repo._persisted_iteration = it
        if "families" in meta:
            # the family manifest rides repository.json (RepositoryFamily
            # owns its content); a plain open+publish must carry it forward
            repo.extra_meta["families"] = meta["families"]
        if spill and not repo.use_flat:
            warnings.warn(
                "spill=True requested but the repository reopened on the "
                "per-leaf engine — staged rows will NOT be spilled or "
                "crash-recoverable until reopened on the flat engine")
        repo.spill = spill and repo.use_flat
        if repo.spill and spill_workers > 0:
            repo._spill_pool = ThreadPoolExecutor(
                max_workers=spill_workers, thread_name_prefix="repo-spill")
        repo.history = [
            FusionRecord(
                iteration=r["iteration"],
                n_contributions=r["n_contributions"],
                n_accepted=r["n_accepted"],
                op=r["op"],
                diff_norms=[float(n) for n in r.get("diff_norms", [])],
                wall_time=float(r.get("wall_time", 0.0)),
            )
            for r in meta.get("history", [])
        ]
        manifest_path = os.path.join(root, MANIFEST)
        if os.path.exists(manifest_path):
            repo._recover_staged(ckpt.load_json(manifest_path), spec)
        sketch_path = os.path.join(root, SKETCH_FILE)
        if os.path.exists(sketch_path):
            # restore the novelty screen's history so a restarted daemon
            # screens against the same recent cohorts (the file is atomic,
            # but tolerate a hand-damaged one: the screen restarts empty)
            try:
                sk = CohortSketch.from_json(ckpt.load_json(sketch_path))
            except Exception as err:
                warnings.warn(f"cohort sketch unreadable "
                              f"({type(err).__name__}: {err}) — the novelty "
                              "screen history restarts empty")
            else:
                if sk.size == spec.size:
                    repo.cohort_sketch = sk
                else:
                    warnings.warn(
                        f"cohort sketch was built for N={sk.size} rows but "
                        f"the base is N={spec.size} — ignoring it")
        return repo


# ---------------------------------------------------------------------------
# RepositoryFamily — a model zoo of named bases under one root
# ---------------------------------------------------------------------------

FAMILY_DIR = "families"


def family_member_root(root: str, name: str) -> str:
    """Filesystem root of a family member.  ``main`` IS the top-level root
    — a single-base repository and a one-member family share a byte-
    identical layout — and every spawned member owns a complete repository
    layout (queue, spill manifest, sketch, gate state, bases) under
    ``<root>/families/<name>/``."""
    return root if name == "main" else os.path.join(root, FAMILY_DIR, name)


class RepositoryFamily:
    """A named family of Repository members sharing one on-disk root — the
    model-zoo layer of similarity-routed fusion (docs/service_loop.md).

    The **family manifest** is a ``"families"`` key riding the top-level
    ``repository.json`` (the main member's meta): a map of member name →
    ``{root, seeded_from, seed_iteration, created_at}``.  ``open`` on a
    pre-family single-base layout migrates it in place by writing the
    implicit ``{"main": {"root": "."}}`` manifest — no file moves, so
    every existing repository (and ``Repository.open`` caller) keeps
    working; ``Repository.open`` itself carries an existing manifest
    through publishes untouched via ``extra_meta``.

    ``spawn`` creates a new member seeded from an existing member's base
    at a declared vintage.  The member directory is persisted durably
    BEFORE the manifest entry (crash between the two leaves an orphan
    directory that the next same-named spawn adopts idempotently — the
    ``repo.post_family_spawn`` fault seam pins this in the crash matrix).

    ``cross_fuse`` is the inter-cluster merge: every member fuses the
    OTHER members' bases through the ordinary flat fuse path
    (``fuse_pending(buffer=...)``) with step size ``alpha·(M−1)/M``, so
    at ``alpha=1`` each member lands exactly on the simultaneous mean of
    all pre-cross bases (the closed form the routed demo asserts)."""

    def __init__(self, main: Repository, *, member_kw: Optional[Dict[str, Any]] = None):
        if not main.root:
            raise ValueError("RepositoryFamily requires an on-disk root")
        self.root = main.root
        self.member_kw = dict(member_kw or {})
        main.family_name = "main"
        self.members: Dict[str, Repository] = {"main": main}
        self._meta: Dict[str, Dict[str, Any]] = {"main": {"root": "."}}

    @classmethod
    def create(cls, base_params, *, root: str, **kw) -> "RepositoryFamily":
        """Initialize a NEW family: a main member at ``root`` plus the
        manifest.  ``kw`` goes to the Repository constructor and is
        remembered for spawned members."""
        main = Repository(base_params, root=root, **kw)
        fam = cls(main, member_kw=kw)
        fam._write_family_manifest()
        return fam

    @classmethod
    def open(cls, root: str, **kw) -> "RepositoryFamily":
        """Open an on-disk family (or migrate a single-base layout in
        place).  ``kw`` is applied to every member's ``Repository.open``
        and remembered for spawns."""
        main = Repository.open(root, **kw)
        fam = cls(main, member_kw=kw)
        meta = main.extra_meta.get("families")
        if meta is None:
            # single-base layout: migrate by writing the implicit manifest
            fam._write_family_manifest()
            return fam
        fam._meta = {str(n): dict(e) for n, e in meta.items()}
        fam._meta.setdefault("main", {"root": "."})
        for name in sorted(fam._meta):
            if name == "main":
                continue
            mroot = os.path.join(root, fam._meta[name]["root"])
            member = Repository.open(mroot, **kw)
            member.family_name = name
            fam.members[name] = member
        main.extra_meta["families"] = fam._meta
        return fam

    def __len__(self) -> int:
        return len(self.members)

    def member_root(self, name: str) -> str:
        return family_member_root(self.root, name)

    def _write_family_manifest(self) -> None:
        """Persist the manifest into the top-level repository.json under
        the main member's publish lock (publish tasks write the same file;
        ``_persist_base`` re-merges live ``extra_meta``, so a captured
        older publish can never clobber a newer manifest)."""
        main = self.members["main"]
        main.extra_meta["families"] = self._meta
        with main._publish_lock:
            ckpt.save_json_atomic(
                os.path.join(self.root, "repository.json"),
                main._render_meta(), default=_json_default)

    def spawn(self, *, seed_family: str = "main",
              seed_iteration: Optional[int] = None,
              name: Optional[str] = None) -> str:
        """Create (or crash-adopt) a new member seeded from
        ``seed_family``'s base at ``seed_iteration`` (its current base
        when None, or when that vintage's npz is no longer on disk).
        Names are deterministic (``f1``, ``f2``, … smallest free), so a
        spawn replayed after a crash converges on the same member."""
        src = self.members[seed_family]
        if name is None:
            k = 1
            while f"f{k}" in self._meta or f"f{k}" in self.members:
                k += 1
            name = f"f{k}"
        if name in self.members:
            raise ValueError(f"family member {name!r} already exists")
        mroot = self.member_root(name)
        it = src.iteration if seed_iteration is None else int(seed_iteration)
        if os.path.exists(os.path.join(mroot, "repository.json")):
            # a previous spawn persisted the member but crashed before the
            # manifest entry: adopt it as-is
            member = Repository.open(mroot, **self.member_kw)
        else:
            seed_path = os.path.join(src.root, f"base_iter{it:04d}.npz")
            if not os.path.exists(seed_path):
                # declared vintage compacted away (or not yet durable):
                # seed from the source's current base instead
                src.flush()
                it = src.iteration
                src._persist_base()
                seed_path = os.path.join(src.root, f"base_iter{it:04d}.npz")
            seed = ckpt.load(seed_path)
            spawn_kw: Dict[str, Any] = dict(
                fusion_op=src.fusion_op, fusion_kwargs=src.fusion_kwargs,
                screen=src.screen, mad_threshold=src.mad_threshold)
            spawn_kw.update(self.member_kw)
            member = Repository(seed, root=mroot, **spawn_kw)
        member.family_name = name
        self.members[name] = member
        faults.crash_point("repo.post_family_spawn")
        self._meta[name] = {
            "root": f"{FAMILY_DIR}/{name}",
            "seeded_from": seed_family,
            "seed_iteration": it,
            "created_at": time.time(),
        }
        self._write_family_manifest()
        return name

    def cross_fuse(self, *, alpha: float = 1.0) -> Dict[str, FusionRecord]:
        """Inter-cluster merge: fuse every member toward the mean of the
        OTHER members' bases through the ordinary flat fuse path.  All
        pre-cross bases are snapshotted first, so the update is
        simultaneous; with the default ``alpha=1.0`` every member lands
        exactly on the mean of all pre-cross bases, and smaller ``alpha``
        interpolates toward it.  Each member's publish runs the full
        pipeline (history record, iteration bump, persist, listeners) with
        the §9 screen bypassed — member bases are not a contributor
        cohort.  No-op (empty dict) for a family of one."""
        names = sorted(self.members)
        if len(names) < 2:
            return {}
        for n in names:
            m = self.members[n]
            m.flush()
            m._ensure_flat_base()
        bases = {n: self.members[n]._base_flat for n in names}
        ak = float(alpha) * (len(names) - 1) / len(names)
        recs: Dict[str, FusionRecord] = {}
        for n in names:
            m = self.members[n]
            others = [bases[o] for o in names if o != n]
            stage = StagedBuffer(m._stack_stage(others))
            recs[n] = m.fuse_pending(buffer=stage, wait=True, alpha=ak,
                                     screen=False,
                                     op=f"cross_fuse(alpha={alpha:g})")
        return recs
