"""The central Repository (paper Fig. 1): versioned base-model store that
accepts contributions, screens them (§9), fuses them (§3), and publishes the
next base model.  Performs no training — only the minimal computation the
ColD constraints allow (§2.3).

Two transports share this logic:

* **in-memory** — the simulation / single-process driver keeps pytrees.
* **on-disk**   — contributions arrive as npz checkpoints in a directory
  (the stand-in for the HF-hub exchange); useful across processes.

The fuse itself delegates to `repro.core.fusion` (host/jnp path) or to the
Pallas ``cold_fuse`` kernel via ``repro.kernels.ops`` when requested.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.checkpoint import io as ckpt
from repro.core import fusion
from repro.core.validation import ScreenReport, screen_contributions


@dataclass
class FusionRecord:
    iteration: int
    n_contributions: int
    n_accepted: int
    op: str
    diff_norms: List[float]
    wall_time: float


class Repository:
    def __init__(
        self,
        base_params,
        *,
        fusion_op: str = "average",
        fusion_kwargs: Optional[Dict[str, Any]] = None,
        screen: bool = True,
        mad_threshold: float = 5.0,
        root: Optional[str] = None,
        keep_history: bool = False,
    ):
        self._base = base_params
        self.fusion_op = fusion_op
        self.fusion_kwargs = dict(fusion_kwargs or {})
        self.screen = screen
        self.mad_threshold = mad_threshold
        self.iteration = 0
        self.root = root
        self.keep_history = keep_history
        self.history: List[FusionRecord] = []
        self._pending: List[Any] = []
        self._pending_fishers: List[Any] = []
        self._pending_weights: List[Any] = []
        self._snapshots: List[Any] = []
        if root:
            os.makedirs(root, exist_ok=True)
            self._persist_base()

    # -- contributor-facing API ----------------------------------------
    def download(self):
        """Contributor pulls the current base model (Fig. 1, step 1)."""
        return self._base

    def upload(self, params, fisher=None, weight: Optional[float] = None) -> int:
        """Contributor pushes a finetuned model (Fig. 1, step 3), optionally
        with its diagonal Fisher (for fusion_op="fisher") and a contribution
        weight (§8 "assigning individual weights to each contributor" — e.g.
        dataset size; used when fusion_op="average"/"damped").  Returns a
        contribution ticket id."""
        self._pending.append(params)
        self._pending_fishers.append(fisher)
        self._pending_weights.append(weight)
        if self.root:
            path = os.path.join(
                self.root, f"iter{self.iteration:04d}_contrib{len(self._pending) - 1:03d}.npz"
            )
            ckpt.save(path, params)
        return len(self._pending) - 1

    def contribute_async(self, params, *, alpha: Optional[float] = None) -> FusionRecord:
        """Asynchronous contribution (paper §8: "it would be beneficial if
        the repository was updated asynchronously"): immediately merge ONE
        finetuned model into the base via a damped task-arithmetic update
        θ ← θ + α·(θ_c − θ), without waiting for a cohort (Ilharco et al.
        2022).  α defaults to 1/(1 + iteration) — early contributions move
        the base more, later ones refine it (Polyak-style averaging)."""
        if self.screen:
            report = screen_contributions(
                self._base, [params], mad_threshold=self.mad_threshold)
            if not report.accepted:
                raise RuntimeError(f"async contribution rejected: {report.reasons}")
        a = alpha if alpha is not None else 1.0 / (1.0 + self.iteration)
        t0 = time.time()
        new_base = fusion.damped(self._base, [params], alpha=a)
        rec = FusionRecord(
            iteration=self.iteration, n_contributions=1, n_accepted=1,
            op=f"async-damped({a:.3f})", diff_norms=[], wall_time=time.time() - t0,
        )
        self.history.append(rec)
        if self.keep_history:
            self._snapshots.append(self._base)
        self._base = new_base
        self.iteration += 1
        if self.root:
            self._persist_base()
        return rec

    # -- repository maintenance ----------------------------------------
    def fuse_pending(self) -> FusionRecord:
        """Screen + fuse all pending contributions into the new base
        (Fig. 1, step 4) and advance the iteration."""
        if not self._pending:
            raise RuntimeError("no contributions to fuse")
        t0 = time.time()
        models = self._pending
        report: Optional[ScreenReport] = None
        fishers = self._pending_fishers
        weights = self._pending_weights
        if self.screen:
            report = screen_contributions(self._base, models, mad_threshold=self.mad_threshold)
            models = [models[i] for i in report.accepted]
            fishers = [fishers[i] for i in report.accepted]
            weights = [weights[i] for i in report.accepted]
            if not models:
                raise RuntimeError(f"all contributions rejected: {report.reasons}")
        kw = dict(self.fusion_kwargs)
        if self.fusion_op == "fisher":
            if any(f is None for f in fishers):
                raise RuntimeError("fusion_op='fisher' requires upload(..., fisher=...)")
            kw["fishers"] = fishers
        elif (self.fusion_op in ("average", "damped") and "weights" not in kw
              and all(w is not None for w in weights) and weights):
            kw["weights"] = weights
        new_base = fusion.fuse(self.fusion_op, self._base, models, **kw)
        rec = FusionRecord(
            iteration=self.iteration,
            n_contributions=len(self._pending),
            n_accepted=len(models),
            op=self.fusion_op,
            diff_norms=report.diff_norms if report else [],
            wall_time=time.time() - t0,
        )
        self.history.append(rec)
        if self.keep_history:
            self._snapshots.append(self._base)
        self._base = new_base
        self._pending = []
        self._pending_fishers = []
        self._pending_weights = []
        self.iteration += 1
        if self.root:
            self._persist_base()
        return rec

    def rollback(self, to_iteration: int):
        """Paper §8: "backtracking when a harmful update was done"."""
        if not self.keep_history:
            raise RuntimeError("rollback requires keep_history=True")
        if not (0 <= to_iteration < len(self._snapshots)):
            raise ValueError(f"no snapshot for iteration {to_iteration}")
        self._base = self._snapshots[to_iteration]
        self._snapshots = self._snapshots[:to_iteration]
        self.history = self.history[:to_iteration]
        self.iteration = to_iteration
        self._pending = []
        self._pending_fishers = []
        self._pending_weights = []

    def snapshot(self, iteration: int):
        return self._snapshots[iteration]

    # -- persistence -----------------------------------------------------
    def _persist_base(self):
        ckpt.save(os.path.join(self.root, f"base_iter{self.iteration:04d}.npz"), self._base)
        meta = {
            "iteration": self.iteration,
            "fusion_op": self.fusion_op,
            "history": [
                {
                    "iteration": r.iteration,
                    "n_contributions": r.n_contributions,
                    "n_accepted": r.n_accepted,
                    "op": r.op,
                }
                for r in self.history
            ],
        }
        with open(os.path.join(self.root, "repository.json"), "w") as f:
            json.dump(meta, f, indent=2)

    @classmethod
    def open(cls, root: str, **kw) -> "Repository":
        """Re-open an on-disk repository at its latest base model."""
        with open(os.path.join(root, "repository.json")) as f:
            meta = json.load(f)
        it = meta["iteration"]
        base = ckpt.load(os.path.join(root, f"base_iter{it:04d}.npz"))
        repo = cls(base, fusion_op=meta.get("fusion_op", "average"), root=None, **kw)
        repo.iteration = it
        repo.root = root
        return repo
