"""The central Repository (paper Fig. 1): versioned base-model store that
accepts contributions, screens them (§9), fuses them (§3), and publishes the
next base model.  Performs no training — only the minimal computation the
ColD constraints allow (§2.3).

Two transports share this logic:

* **in-memory** — the simulation / single-process driver keeps pytrees.
* **on-disk**   — contributions arrive as npz checkpoints in a directory
  (the stand-in for the HF-hub exchange); useful across processes.

Two fuse engines share the contributor-facing API:

* **streaming flat engine** (default for ``average``/``damped``/
  ``task_arithmetic`` when kernels are enabled) — ``upload`` immediately
  folds each contribution into a flat ``[N]`` staging row (the pytree is
  dropped, bounding peak memory to the staging buffer — optionally spilled
  to the npz root) and ``fuse_pending`` performs screen+fuse in a SINGLE
  streaming pass: the Pallas ``cold_fuse`` kernel emits the fused model and
  the per-contributor ``sq_diff`` screening statistic together, the §9 MAD
  screen runs on those norms, and any rejected contributors get weight 0 in
  one cheap second pass over the already-staged buffer.  No contribution is
  ever re-read as a pytree.
* **per-leaf pytree engine** — the seed path (`repro.core.fusion`), kept
  verbatim as the ``REPRO_NO_KERNELS`` oracle and for operators the kernel
  does not cover (``fisher``, ``ties``).

Passing ``mesh=`` (with optional ``mesh_axes=``) distributes the flat
engine: ``upload`` stages each row directly into its block-cyclic shard
placement (``ShardedFlatSpec``), ``fuse_pending`` runs the screen+fuse
per-shard under ``shard_map`` with exactly ONE all-reduce (the ``sq_diff``
partials), and no device ever materializes the full ``[K, N]`` staging
buffer.  Cohort capacity then scales with the mesh instead of a single
device's HBM.  See docs/sharding.md.

See docs/fusion_engine.md and docs/repository.md for the full contract.
"""
from __future__ import annotations

import functools
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.core import fusion
from repro.core.validation import (ScreenReport, norms_from_sq,
                                   screen_contributions, screen_norms)
from repro.kernels import ops
from repro.launch import sharding as SH
from repro.utils.flat import FlatSpec, ShardedFlatSpec

# operators the streaming flat engine covers; everything else (fisher, ties)
# falls back to the per-leaf pytree engine
FLAT_OPS = ("average", "damped", "task_arithmetic")


@dataclass
class FusionRecord:
    iteration: int
    n_contributions: int
    n_accepted: int
    op: str
    diff_norms: List[float]
    wall_time: float


@functools.lru_cache(maxsize=32)
def _stack_fn(k: int, sharding):
    """Jitted K-row stack with the staging out-sharding: each device
    concatenates its local shard slices, so stacking never gathers the
    cohort onto one device.  Cached per (K, sharding) to avoid re-tracing
    every fuse."""
    del k  # shapes key the jit cache; K only keys the lru entry
    return jax.jit(lambda *rows: jnp.stack(rows), out_shardings=sharding)


def _json_default(o):
    if isinstance(o, (np.ndarray, np.generic, jax.Array)):
        return np.asarray(o).tolist()
    return str(o)


class Repository:
    def __init__(
        self,
        base_params,
        *,
        fusion_op: str = "average",
        fusion_kwargs: Optional[Dict[str, Any]] = None,
        screen: bool = True,
        mad_threshold: float = 5.0,
        root: Optional[str] = None,
        keep_history: bool = False,
        use_flat: Optional[bool] = None,
        spill: bool = False,
        mesh: Optional[Any] = None,
        mesh_axes: Optional[Any] = None,
    ):
        self._base = base_params
        self.fusion_op = fusion_op
        self.fusion_kwargs = dict(fusion_kwargs or {})
        self.screen = screen
        self.mad_threshold = mad_threshold
        self.iteration = 0
        self.root = root
        self.keep_history = keep_history
        if use_flat is None:
            # the sharded engine is plain XLA under shard_map, so a mesh
            # forces the flat path regardless of the kernel toggle
            use_flat = fusion_op in FLAT_OPS and (
                mesh is not None or ops.kernels_enabled())
        elif use_flat and fusion_op not in FLAT_OPS:
            raise ValueError(f"flat engine does not cover fusion_op={fusion_op!r}")
        if mesh is not None and not use_flat:
            raise ValueError("mesh= requires the flat engine "
                             f"(fusion_op={fusion_op!r}, use_flat={use_flat})")
        self.use_flat = use_flat
        self.mesh = mesh
        if mesh is not None:
            axes = SH.norm_axes(
                mesh.axis_names if mesh_axes is None else mesh_axes)
            missing = [a for a in axes if a not in mesh.axis_names]
            if missing:
                raise ValueError(f"mesh_axes {missing} not in mesh {mesh.axis_names}")
            self.mesh_axes = axes
            self._n_shards = SH.axes_extent(mesh, axes)
        else:
            self.mesh_axes = ()
            self._n_shards = 1
        if spill and not root:
            raise ValueError("spill=True requires an on-disk root")
        self.spill = spill
        self.history: List[FusionRecord] = []
        self._pending: List[Any] = []       # pytrees, flat rows, or spill paths
        self._pending_fishers: List[Any] = []
        self._pending_weights: List[Any] = []
        self._snapshots: List[Any] = []
        self._spec: Optional[FlatSpec] = None
        self._sspec: Optional[ShardedFlatSpec] = None
        self._base_flat: Optional[jax.Array] = None
        if root:
            os.makedirs(root, exist_ok=True)
            self._persist_base()

    # -- flat staging ---------------------------------------------------
    def _ensure_flat_base(self):
        if self._spec is None:
            self._spec = FlatSpec.from_tree(self._base)
        if self.mesh is not None and self._sspec is None:
            self._sspec = ShardedFlatSpec.from_spec(self._spec, self._n_shards)
        if self._base_flat is None:
            flat = self._spec.flatten(self._base)
            self._base_flat = self._stage_row(flat) if self.mesh is not None else flat

    def _stage_row(self, row: jax.Array) -> jax.Array:
        """[N] row -> its block-cyclic [S, shard_len] placement: each device
        receives only its own slice, at upload time — the full row never
        needs to exist on a fuse device."""
        return jax.device_put(
            self._sspec.shard(row), SH.flat_row_sharding(self.mesh, self.mesh_axes))

    def _stack_stage(self, rows: List[jax.Array]) -> jax.Array:
        """Stack K staged rows into the fuse operand.  On a mesh the stack
        runs under jit with the staging out-sharding, so each device
        concatenates its local slices — the [K, N] buffer is never
        materialized on one device."""
        if self.mesh is None:
            return jnp.stack(rows)
        rows = [r if r.ndim == 2 else self._stage_row(r) for r in rows]  # spilled rows load as [N]
        stack = _stack_fn(
            len(rows), SH.flat_stage_sharding(self.mesh, self.mesh_axes))
        return stack(*rows)

    def _fuse_flat(self, stage, weights, alpha, *, donate: bool):
        if self.mesh is not None:
            return ops.fuse_flat_sharded(
                self._base_flat, stage, weights, alpha,
                mesh=self.mesh, axes=self.mesh_axes)
        return ops.fuse_flat(self._base_flat, stage, weights, alpha, donate=donate)

    def _publish_flat(self, fused: jax.Array):
        """Fused flat buffer -> the new base pytree (+ cached flat form)."""
        row = self._sspec.unshard(fused) if self.mesh is not None else fused
        self._base = self._spec.unflatten(row)
        self._base_flat = fused

    def _contrib_path(self, idx: int) -> str:
        return os.path.join(
            self.root, f"iter{self.iteration:04d}_contrib{idx:03d}.npz")

    # -- contributor-facing API ----------------------------------------
    def download(self):
        """Contributor pulls the current base model (Fig. 1, step 1)."""
        return self._base

    def upload(self, params, fisher=None, weight: Optional[float] = None) -> int:
        """Contributor pushes a finetuned model (Fig. 1, step 3), optionally
        with its diagonal Fisher (for fusion_op="fisher") and a contribution
        weight (§8 "assigning individual weights to each contributor" — e.g.
        dataset size; used when fusion_op="average"/"damped").  Returns a
        contribution ticket id.

        On the flat engine the pytree is folded into a contiguous staging
        row right here and released — the Repository never holds K live
        pytrees.  With ``spill=True`` the row goes to the npz root instead
        and only its path stays in memory."""
        idx = len(self._pending)
        if self.use_flat:
            self._ensure_flat_base()
            row = self._spec.flatten(params)
            if self.root:
                # the on-disk row stays the portable [N] form — spill files
                # are mesh-independent and re-shard on load
                ckpt.save_flat(self._contrib_path(idx), row, self._spec)
            if self.spill:
                self._pending.append(self._contrib_path(idx))
            elif self.mesh is not None:
                self._pending.append(self._stage_row(row))
            else:
                self._pending.append(row)
        else:
            self._pending.append(params)
            if self.root:
                ckpt.save(self._contrib_path(idx), params)
        self._pending_fishers.append(fisher)
        self._pending_weights.append(weight)
        return idx

    def contribute_async(self, params, *, alpha: Optional[float] = None) -> FusionRecord:
        """Asynchronous contribution (paper §8: "it would be beneficial if
        the repository was updated asynchronously"): immediately merge ONE
        finetuned model into the base via a damped task-arithmetic update
        θ ← θ + α·(θ_c − θ), without waiting for a cohort (Ilharco et al.
        2022).  α defaults to 1/(1 + iteration) — early contributions move
        the base more, later ones refine it (Polyak-style averaging).

        On the flat engine this is one streaming kernel pass: the same
        launch yields the merged model and the screening norm; if the screen
        rejects, the merged buffer is simply discarded."""
        a = alpha if alpha is not None else 1.0 / (1.0 + self.iteration)
        t0 = time.time()
        if self.use_flat:
            self._ensure_flat_base()
            row = self._spec.flatten(params)
            if self.mesh is not None:
                stage = self._stage_row(row)[None]
            else:
                stage = row[None, :]
            fused, sq = self._fuse_flat(stage, jnp.ones((1,), jnp.float32), a,
                                        donate=False)
            if self.screen:
                norm = norms_from_sq(jax.device_get(sq))[0]
                report = screen_norms([norm], mad_threshold=self.mad_threshold)
                if not report.accepted:
                    raise RuntimeError(f"async contribution rejected: {report.reasons}")
            fused.block_until_ready()
            if self.mesh is not None:
                new_base = self._spec.unflatten(self._sspec.unshard(fused))
            else:
                new_base = self._spec.unflatten(fused)
            new_flat = fused
        else:
            if self.screen:
                report = screen_contributions(
                    self._base, [params], mad_threshold=self.mad_threshold)
                if not report.accepted:
                    raise RuntimeError(f"async contribution rejected: {report.reasons}")
            new_base = fusion.damped(self._base, [params], alpha=a)
            new_flat = None
        rec = FusionRecord(
            iteration=self.iteration, n_contributions=1, n_accepted=1,
            op=f"async-damped({a:.3f})", diff_norms=[], wall_time=time.time() - t0,
        )
        self.history.append(rec)
        if self.keep_history:
            self._snapshots.append(self._base)
        self._base = new_base
        self._base_flat = new_flat
        self.iteration += 1
        if self.root:
            self._persist_base()
        return rec

    # -- repository maintenance ----------------------------------------
    def fuse_pending(self) -> FusionRecord:
        """Screen + fuse all pending contributions into the new base
        (Fig. 1, step 4) and advance the iteration."""
        if not self._pending:
            raise RuntimeError("no contributions to fuse")
        t0 = time.time()
        if self.use_flat:
            rec = self._fuse_pending_flat(t0)
        else:
            rec = self._fuse_pending_pytree(t0)
        self.history.append(rec)
        self._pending = []
        self._pending_fishers = []
        self._pending_weights = []
        self.iteration += 1
        if self.root:
            self._persist_base()
        return rec

    def _cohort_weights(self, K: int) -> jnp.ndarray:
        """Per-contributor weights for the flat engine (average/damped)."""
        kw = self.fusion_kwargs
        if self.fusion_op in ("average", "damped"):
            if "weights" in kw:
                w = list(kw["weights"])
                if len(w) != K:
                    raise ValueError(f"len(fusion_kwargs['weights'])={len(w)} != K={K}")
                return jnp.asarray(w, jnp.float32)
            if self._pending_weights and all(w is not None for w in self._pending_weights):
                return jnp.asarray(self._pending_weights, jnp.float32)
        return jnp.ones((K,), jnp.float32)

    def _flat_alpha(self, n_effective: int) -> float:
        """The kernel's damping coefficient for the configured operator."""
        if self.fusion_op == "damped":
            return float(self.fusion_kwargs.get("alpha", 1.0))
        if self.fusion_op == "task_arithmetic":
            # θ + λ·Σ(θ_c − θ) == θ + (λ·K)·(mean − θ)
            return float(self.fusion_kwargs.get("lam", 1.0)) * n_effective
        return 1.0

    def _fuse_pending_flat(self, t0: float) -> FusionRecord:
        """Single streaming pass: one kernel launch fuses the staged buffer
        AND emits the §9 screening statistic; rejections trigger one cheap
        weight-zeroed re-pass over the same staged buffer."""
        self._ensure_flat_base()
        K = len(self._pending)
        rows = [
            ckpt.load_flat(p)[0] if isinstance(p, str) else p
            for p in self._pending
        ]
        stage = self._stack_stage(rows)
        del rows
        w = self._cohort_weights(K)
        alpha = self._flat_alpha(K)
        # pass 1: fused + sq_diff in one read of the staged buffer.  Keep the
        # buffer alive only if a screening re-pass might need it.  (On a mesh
        # the sq_diff per-shard partials are completed by the fuse's single
        # all-reduce — the statistic arriving here is already global.)
        fused, sq = self._fuse_flat(stage, w, alpha, donate=not self.screen)
        report: Optional[ScreenReport] = None
        n_accepted = K
        if self.screen:
            norms = norms_from_sq(jax.device_get(sq))
            report = screen_norms(norms, mad_threshold=self.mad_threshold)
            n_accepted = len(report.accepted)
            if not report.accepted:
                raise RuntimeError(f"all contributions rejected: {report.reasons}")
            if report.rejected:
                w2 = np.asarray(jax.device_get(w), np.float32).copy()
                w2[report.rejected] = 0.0
                alpha = self._flat_alpha(n_accepted)
                fused, _ = self._fuse_flat(
                    stage, jnp.asarray(w2), alpha, donate=True)
        fused.block_until_ready()
        rec = FusionRecord(
            iteration=self.iteration,
            n_contributions=K,
            n_accepted=n_accepted,
            op=self.fusion_op,
            diff_norms=report.diff_norms if report else [],
            wall_time=time.time() - t0,
        )
        if self.keep_history:
            self._snapshots.append(self._base)
        self._publish_flat(fused)
        return rec

    def _fuse_pending_pytree(self, t0: float) -> FusionRecord:
        """The seed per-leaf engine (REPRO_NO_KERNELS oracle; also serves
        the operators the kernel does not cover)."""
        models = self._pending
        report: Optional[ScreenReport] = None
        fishers = self._pending_fishers
        weights = self._pending_weights
        if self.screen:
            report = screen_contributions(self._base, models, mad_threshold=self.mad_threshold)
            models = [models[i] for i in report.accepted]
            fishers = [fishers[i] for i in report.accepted]
            weights = [weights[i] for i in report.accepted]
            if not models:
                raise RuntimeError(f"all contributions rejected: {report.reasons}")
        kw = dict(self.fusion_kwargs)
        if self.fusion_op == "fisher":
            if any(f is None for f in fishers):
                raise RuntimeError("fusion_op='fisher' requires upload(..., fisher=...)")
            kw["fishers"] = fishers
        elif (self.fusion_op in ("average", "damped") and "weights" not in kw
              and all(w is not None for w in weights) and weights):
            kw["weights"] = weights
        new_base = fusion.fuse(self.fusion_op, self._base, models, **kw)
        rec = FusionRecord(
            iteration=self.iteration,
            n_contributions=len(self._pending),
            n_accepted=len(models),
            op=self.fusion_op,
            diff_norms=report.diff_norms if report else [],
            wall_time=time.time() - t0,
        )
        if self.keep_history:
            self._snapshots.append(self._base)
        self._base = new_base
        self._base_flat = None
        return rec

    def rollback(self, to_iteration: int):
        """Paper §8: "backtracking when a harmful update was done"."""
        if not self.keep_history:
            raise RuntimeError("rollback requires keep_history=True")
        if not (0 <= to_iteration < len(self._snapshots)):
            raise ValueError(f"no snapshot for iteration {to_iteration}")
        self._base = self._snapshots[to_iteration]
        self._base_flat = None
        self._snapshots = self._snapshots[:to_iteration]
        self.history = self.history[:to_iteration]
        self.iteration = to_iteration
        self._pending = []
        self._pending_fishers = []
        self._pending_weights = []

    def snapshot(self, iteration: int):
        return self._snapshots[iteration]

    # -- persistence -----------------------------------------------------
    def _persist_base(self):
        ckpt.save(os.path.join(self.root, f"base_iter{self.iteration:04d}.npz"), self._base)
        meta = {
            "iteration": self.iteration,
            "fusion_op": self.fusion_op,
            "fusion_kwargs": self.fusion_kwargs,
            "screen": self.screen,
            "mad_threshold": self.mad_threshold,
            "history": [
                {
                    "iteration": r.iteration,
                    "n_contributions": r.n_contributions,
                    "n_accepted": r.n_accepted,
                    "op": r.op,
                    "diff_norms": [float(n) for n in r.diff_norms],
                    "wall_time": r.wall_time,
                }
                for r in self.history
            ],
        }
        with open(os.path.join(self.root, "repository.json"), "w") as f:
            json.dump(meta, f, indent=2, default=_json_default)

    @classmethod
    def open(cls, root: str, **kw) -> "Repository":
        """Re-open an on-disk repository at its latest base model, restoring
        the fusion configuration, screen settings, and history recorded in
        ``repository.json`` (explicit keyword arguments win)."""
        with open(os.path.join(root, "repository.json")) as f:
            meta = json.load(f)
        it = meta["iteration"]
        base = ckpt.load(os.path.join(root, f"base_iter{it:04d}.npz"))
        kw.setdefault("fusion_op", meta.get("fusion_op", "average"))
        if meta.get("fusion_kwargs"):
            kw.setdefault("fusion_kwargs", meta["fusion_kwargs"])
        kw.setdefault("screen", meta.get("screen", True))
        kw.setdefault("mad_threshold", meta.get("mad_threshold", 5.0))
        # constructed with root=None so __init__ does not re-persist (and
        # clobber) base_iter0000; root/spill are restored afterwards
        spill = bool(kw.pop("spill", False))
        repo = cls(base, root=None, **kw)
        repo.iteration = it
        repo.root = root
        repo.spill = spill
        repo.history = [
            FusionRecord(
                iteration=r["iteration"],
                n_contributions=r["n_contributions"],
                n_accepted=r["n_accepted"],
                op=r["op"],
                diff_norms=[float(n) for n in r.get("diff_norms", [])],
                wall_time=float(r.get("wall_time", 0.0)),
            )
            for r in meta.get("history", [])
        ]
        return repo
