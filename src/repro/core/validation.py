"""Contribution screening — the paper's §9 mitigation for "a possible
harmful update done by a contributor": monitor diffs from the base and
reject anomalous or non-finite contributions before fusing.

Checks (all cheap, streaming):

* non-finite leaves (NaN/Inf screens),
* diff-norm too LARGE vs the cohort (runaway finetune / random weights),
* diff-norm zero (no-op "contribution"),
* optional absolute norm ceiling.

Two entry points share the decision logic:

* ``screen_contributions`` — pytree-level: reads every contribution to
  compute its diff norm (the seed path; one extra pass over the data).
* ``screen_norms`` — statistic-level: consumes *precomputed* diff norms.
  The Pallas ``cold_fuse`` kernel emits ``sq_diff[k] = ||θ_k − base||²``
  for free during fusion, so the Repository's streaming engine feeds
  ``sqrt(sq_diff)`` straight in here and never re-reads a contribution
  just to screen it.  A non-finite contribution surfaces as a NaN/Inf
  norm, which this function treats exactly like the pytree-level
  non-finite check.

On the mesh-sharded engine (docs/sharding.md) each shard contributes a
``sq_diff`` *partial* over its block-cyclic slice; the single all-reduce
that completes them happens inside the fuse, so by the time the statistic
reaches this module it is already the global norm — the decision rule is
identical across all three engines.  ``norms_from_sq`` is the shared
sq→norm bridge (f64 sqrt of the f32 kernel accumulations).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import tree_isfinite, tree_sq_norm, tree_sub


@dataclass
class ScreenReport:
    accepted: List[int] = field(default_factory=list)
    rejected: List[int] = field(default_factory=list)
    reasons: dict = field(default_factory=dict)
    diff_norms: List[float] = field(default_factory=list)


def diff_norm(base, model) -> float:
    return float(jnp.sqrt(tree_sq_norm(tree_sub(model, base))))


def norms_from_sq(sq) -> List[float]:
    """``sq_diff [K]`` (from the fuse kernel / the sharded psum) → diff
    norms for ``screen_norms``.  The sqrt runs in float64 host-side: the
    kernel accumulates in f32, and squaring back and forth in f32 would
    cost precision exactly where the MAD cutoff is decided."""
    return np.sqrt(np.asarray(sq, np.float64)).tolist()


def screen_norms(
    norms: Sequence[float],
    *,
    mad_threshold: float = 5.0,
    max_norm: Optional[float] = None,
    allow_zero: bool = False,
) -> ScreenReport:
    """Screen from precomputed diff norms (NaN/Inf norm = non-finite
    contribution).  Same decision rule as ``screen_contributions``: reject
    non-finite, zero-diff (unless ``allow_zero``), over-ceiling, and
    ``mad_threshold``-sigma MAD outliers (cohort of >= 3; the median/MAD
    statistics are robust to the outlier contaminating them)."""
    report = ScreenReport()
    norms = [float(n) for n in norms]
    finite = [bool(np.isfinite(n)) for n in norms]
    report.diff_norms = norms

    arr = np.asarray([n for n, f in zip(norms, finite) if f])
    med = float(np.median(arr)) if arr.size else 0.0
    mad = float(np.median(np.abs(arr - med))) if arr.size else 0.0
    cutoff_hi = med + mad_threshold * max(mad, 1e-12 + 0.05 * med)

    for i, (n, f) in enumerate(zip(norms, finite)):
        if not f:
            report.rejected.append(i)
            report.reasons[i] = "non-finite parameters"
        elif not allow_zero and n == 0.0:
            report.rejected.append(i)
            report.reasons[i] = "zero diff (no-op contribution)"
        elif max_norm is not None and n > max_norm:
            report.rejected.append(i)
            report.reasons[i] = f"diff norm {n:.3g} exceeds ceiling {max_norm:.3g}"
        elif len(arr) >= 3 and n > cutoff_hi:
            report.rejected.append(i)
            report.reasons[i] = f"diff norm {n:.3g} is a MAD outlier (cutoff {cutoff_hi:.3g})"
        else:
            report.accepted.append(i)
    return report


def screen_contributions(
    base,
    models: Sequence,
    *,
    mad_threshold: float = 5.0,
    max_norm: Optional[float] = None,
    allow_zero: bool = False,
) -> ScreenReport:
    """Return indices of models safe to fuse (pytree path: reads every
    contribution once to compute its diff norm)."""
    norms = []
    for m in models:
        if bool(tree_isfinite(m)):
            norms.append(diff_norm(base, m))
        else:
            norms.append(float("inf"))
    return screen_norms(
        norms, mad_threshold=mad_threshold, max_norm=max_norm, allow_zero=allow_zero)
