"""Contribution screening — the paper's §9 mitigation for "a possible
harmful update done by a contributor": monitor diffs from the base and
reject anomalous or non-finite contributions before fusing.

Checks (all cheap, streaming; the Pallas ``cold_fuse`` kernel computes the
same diff norms for free during fusion):

* non-finite leaves (NaN/Inf screens),
* diff-norm too LARGE vs the cohort (runaway finetune / random weights),
* diff-norm zero (no-op "contribution"),
* optional absolute norm ceiling.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import tree_isfinite, tree_sq_norm, tree_sub


@dataclass
class ScreenReport:
    accepted: List[int] = field(default_factory=list)
    rejected: List[int] = field(default_factory=list)
    reasons: dict = field(default_factory=dict)
    diff_norms: List[float] = field(default_factory=list)


def diff_norm(base, model) -> float:
    return float(jnp.sqrt(tree_sq_norm(tree_sub(model, base))))


def screen_contributions(
    base,
    models: Sequence,
    *,
    mad_threshold: float = 5.0,
    max_norm: Optional[float] = None,
    allow_zero: bool = False,
) -> ScreenReport:
    """Return indices of models safe to fuse.

    A contribution is rejected if it contains non-finite values, has zero
    diff (unless ``allow_zero``), exceeds ``max_norm``, or its diff norm is a
    ``mad_threshold``-sigma outlier under the median-absolute-deviation rule
    (robust to the outlier itself contaminating the statistics).
    """
    report = ScreenReport()
    norms = []
    finite = []
    for m in models:
        finite.append(bool(tree_isfinite(m)))
        norms.append(diff_norm(base, m) if finite[-1] else float("inf"))
    report.diff_norms = norms

    arr = np.asarray([n for n, f in zip(norms, finite) if f and np.isfinite(n)])
    med = float(np.median(arr)) if arr.size else 0.0
    mad = float(np.median(np.abs(arr - med))) if arr.size else 0.0
    cutoff_hi = med + mad_threshold * max(mad, 1e-12 + 0.05 * med)

    for i, (n, f) in enumerate(zip(norms, finite)):
        if not f:
            report.rejected.append(i)
            report.reasons[i] = "non-finite parameters"
        elif not allow_zero and n == 0.0:
            report.rejected.append(i)
            report.reasons[i] = "zero diff (no-op contribution)"
        elif max_norm is not None and n > max_norm:
            report.rejected.append(i)
            report.reasons[i] = f"diff norm {n:.3g} exceeds ceiling {max_norm:.3g}"
        elif len(arr) >= 3 and n > cutoff_hi:
            report.rejected.append(i)
            report.reasons[i] = f"diff norm {n:.3g} is a MAD outlier (cutoff {cutoff_hi:.3g})"
        else:
            report.accepted.append(i)
    return report
