"""Model fusion operators — the Repository's "fuse" step (paper §3).

The paper's operator is the uniform parameter average
``θ_{i+1} = 1/|C| Σ_c θ_i^c`` (Choshen et al., 2022b).  The paper's §8
discussion proposes several refinements as future work; we implement them as
first-class, composable operators (all pure pytree->pytree functions):

* ``average``           — the paper's operator (optionally weighted).
* ``damped``            — fuse then move only a fraction α from the previous
                          base ("learning rate" on the collective update).
* ``fisher_weighted``   — per-parameter precision weighting (Matena & Raffel
                          2021), with contributor-supplied diagonal Fisher.
* ``ties``              — TIES-merging (Yadav et al., 2023): trim small task
                          deltas, elect a sign per parameter, mean the
                          survivors.  Operates on deltas from the base.
* ``task_arithmetic``   — base + λ·Σ deltas (Ilharco et al., 2022).

All operators accept a list of contributor pytrees (and the previous base
where meaningful) and return the new base pytree.  They are jit-friendly.

``average``, ``damped``, and ``task_arithmetic`` route through the streaming
flat-buffer kernel (`repro.kernels.ops.fuse_pytrees` — one launch over the
whole concatenated model) whenever kernels are enabled; the per-leaf jnp
implementations below remain the ``REPRO_NO_KERNELS`` oracle and the path
for operators the kernel does not cover (``fisher``, ``ties``).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops as _ops


def _check(models: Sequence):
    if not models:
        raise ValueError("fusion requires at least one model")


def _check_weights(models: Sequence, weights: Optional[Sequence[float]]):
    if weights is None:
        return
    if len(weights) != len(models):
        raise ValueError("len(weights) != len(models)")
    if float(sum(weights)) <= 0:
        raise ValueError("weights must sum to a positive value")


def average(models: Sequence, weights: Optional[Sequence[float]] = None):
    """Uniform (paper §3) or weighted parameter average."""
    _check(models)
    _check_weights(models, weights)
    if _ops.kernels_enabled():
        # flat path: α=1 makes the fuse independent of the base operand, so
        # reuse models[0] as the base rather than materializing zeros
        fused, _ = _ops.fuse_pytrees(models[0], models, weights, 1.0)
        return fused
    if weights is None:
        w = [1.0 / len(models)] * len(models)
    else:
        w = [float(x) / float(sum(weights)) for x in weights]

    def avg(*leaves):
        acc = leaves[0].astype(jnp.float32) * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            acc = acc + leaf.astype(jnp.float32) * wi
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *models)


def damped(base, models: Sequence, alpha: float = 1.0,
           weights: Optional[Sequence[float]] = None):
    """θ' = θ + α·(average(models) − θ).  α=1 recovers the paper; α<1 is the
    §8 "restrict the effect of each iteration" lever."""
    _check(models)
    _check_weights(models, weights)
    if _ops.kernels_enabled():
        fused, _ = _ops.fuse_pytrees(base, models, weights, float(alpha))
        return fused
    fused = average(models, weights)
    return jax.tree.map(
        lambda b, f: (b.astype(jnp.float32) * (1 - alpha) + f.astype(jnp.float32) * alpha).astype(b.dtype),
        base, fused,
    )


def fisher_weighted(models: Sequence, fishers: Sequence, eps: float = 1e-8):
    """θ* = (Σ F_c ⊙ θ_c) / (Σ F_c); F_c diagonal Fisher (or any positive
    importance) pytrees matching the params structure."""
    _check(models)
    if len(fishers) != len(models):
        raise ValueError("need one fisher per model")

    def fuse(*leaves):
        n = len(leaves) // 2
        thetas, fs = leaves[:n], leaves[n:]
        num = sum(t.astype(jnp.float32) * f.astype(jnp.float32) for t, f in zip(thetas, fs))
        den = sum(f.astype(jnp.float32) for f in fs) + eps
        return (num / den).astype(thetas[0].dtype)

    return jax.tree.map(fuse, *(list(models) + list(fishers)))


def task_arithmetic(base, models: Sequence, lam: float = 1.0):
    """θ' = θ + λ · Σ_c (θ_c − θ)."""
    _check(models)
    if _ops.kernels_enabled():
        # θ + λ·Σ(θ_c − θ) == θ + (λ·K)·(mean − θ): one kernel pass
        fused, _ = _ops.fuse_pytrees(base, models, None, float(lam) * len(models))
        return fused

    def fuse(b, *ts):
        delta = sum(t.astype(jnp.float32) - b.astype(jnp.float32) for t in ts)
        return (b.astype(jnp.float32) + lam * delta).astype(b.dtype)

    return jax.tree.map(fuse, base, *models)


def ties(base, models: Sequence, density: float = 0.2, lam: float = 1.0):
    """TIES-merging: per-leaf trim each delta to its top-``density`` fraction
    by magnitude, elect the dominant sign per coordinate, average the deltas
    agreeing with it, and apply with scale λ."""
    _check(models)

    def fuse(b, *ts):
        bf = b.astype(jnp.float32)
        deltas = [t.astype(jnp.float32) - bf for t in ts]
        trimmed = []
        for d in deltas:
            flat = jnp.abs(d).reshape(-1)
            k = max(1, int(density * flat.size))
            # threshold = k-th largest magnitude
            thresh = jax.lax.top_k(flat, k)[0][-1]
            trimmed.append(jnp.where(jnp.abs(d) >= thresh, d, 0.0))
        total = sum(trimmed)
        sign = jnp.sign(total)
        keep = [jnp.where(jnp.sign(d) == sign, d, 0.0) for d in trimmed]
        cnt = sum(jnp.where(k != 0.0, 1.0, 0.0) for k in keep)
        merged = sum(keep) / jnp.maximum(cnt, 1.0)
        return (bf + lam * merged).astype(b.dtype)

    return jax.tree.map(fuse, base, *models)


FUSION_OPS = {
    "average": lambda base, models, **kw: average(models, **kw),
    "damped": damped,
    "task_arithmetic": task_arithmetic,
    "ties": ties,
}


def fuse(name: str, base, models: Sequence, **kw):
    """Dispatch by operator name (config-friendly entry point)."""
    if name == "fisher":
        return fisher_weighted(models, kw.pop("fishers"), **kw)
    try:
        op = FUSION_OPS[name]
    except KeyError:
        raise KeyError(f"unknown fusion op {name!r}; known: {sorted(FUSION_OPS)} + ['fisher']") from None
    return op(base, models, **kw)
