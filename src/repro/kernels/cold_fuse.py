"""Pallas TPU kernel: fused ColD Fusion repository update.

The Repository's fuse step is HBM-bandwidth-bound streaming arithmetic over
K contributor checkpoints.  A naive implementation reads each contribution
twice (once for the average, once for the §9 diff-norm screen) and the base
three times.  This kernel performs, in a single VMEM pass per block:

    fused = base + α·(Σ_k w_k θ_k − base)          (damped weighted average)
    sq_diff[k] += ||θ_k − base||²_block            (screening statistic)

TPU adaptation (DESIGN.md §2): parameters are flattened and tiled into
(8·128)-aligned VMEM blocks; the K contributions arrive as a stacked [K, N]
operand so the per-block working set is (K+1)·BLOCK·4B — BLOCK is chosen so
this fits comfortably in ~16 MB VMEM.  The diff-norm outputs accumulate
across the sequential grid (same output block every step), an idiomatic
Pallas reduction.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024  # f32 elems: (K+1)*256KB at K=8 -> ~2.3 MB VMEM


def _kernel(w_ref, base_ref, contribs_ref, alpha_ref, fused_ref, sq_ref):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    base = base_ref[...].astype(jnp.float32)  # [BLOCK]
    contribs = contribs_ref[...].astype(jnp.float32)  # [K, BLOCK]
    w = w_ref[...].astype(jnp.float32)  # [K]
    alpha = alpha_ref[0].astype(jnp.float32)
    wn = w / jnp.sum(w)
    avg = jnp.einsum("k,kn->n", wn, contribs)
    fused_ref[...] = (base + alpha * (avg - base)).astype(fused_ref.dtype)
    diff = contribs - base[None, :]
    sq_ref[...] += jnp.sum(diff * diff, axis=1)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cold_fuse(
    base: jax.Array,      # [N]
    contribs: jax.Array,  # [K, N]
    weights: jax.Array,   # [K]
    alpha=1.0,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (fused [N], sq_diff [K]).  N is padded to the block size
    internally (padding contributes 0 to both outputs)."""
    K, N = contribs.shape
    pad = (-N) % block
    if pad:
        base_p = jnp.concatenate([base, jnp.zeros((pad,), base.dtype)])
        contribs_p = jnp.concatenate([contribs, jnp.zeros((K, pad), contribs.dtype)], axis=1)
    else:
        base_p, contribs_p = base, contribs
    n_blocks = base_p.shape[0] // block
    alpha_arr = jnp.asarray([alpha], jnp.float32)

    fused, sq = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((K,), lambda i: (0,)),            # weights (whole)
            pl.BlockSpec((block,), lambda i: (i,)),        # base block
            pl.BlockSpec((K, block), lambda i: (0, i)),    # contrib blocks
            pl.BlockSpec((1,), lambda i: (0,)),            # alpha
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K,), lambda i: (0,)),            # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct(base_p.shape, base.dtype),
            jax.ShapeDtypeStruct((K,), jnp.float32),
        ],
        interpret=interpret,
    )(weights, base_p, contribs_p, alpha_arr)
    return fused[:N], sq
