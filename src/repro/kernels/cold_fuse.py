"""Pallas TPU kernel: fused ColD Fusion repository update.

The Repository's fuse step is HBM-bandwidth-bound streaming arithmetic over
K contributor checkpoints.  A naive implementation reads each contribution
twice (once for the average, once for the §9 diff-norm screen) and the base
three times.  This kernel performs, in a single VMEM pass per block:

    fused = base + α·(Σ_k w_k θ_k − base)          (damped weighted average)
    sq_diff[k] += ||θ_k − base||²_block            (screening statistic)

so one streaming read of the staged contributions yields BOTH the fused
model and the §9 screening statistics — the Repository's single-pass
screen+fuse contract (see docs/fusion_engine.md).

Contract details:

* **zero-weight masking** — a contributor with weight exactly 0 contributes
  nothing to ``fused`` even if its parameters are non-finite (NaN·0 would
  otherwise poison the average).  This is what lets the Repository's second
  pass simply zero the weights of screened-out contributors and re-use the
  already-staged ``[K, N]`` buffer.  ``sq_diff`` is still computed from the
  raw values, so the screening statistic always reflects the real diff.
* **bf16 streaming, f32 accumulation** — contributions may arrive in bf16
  (half the HBM traffic); all arithmetic runs in f32 inside VMEM and the
  fused output is cast back to the base dtype.
* **donation** — ``donate=True`` donates the staged ``[K, N]`` buffer to
  XLA (the Repository discards it after the fuse), letting the backend
  reuse its pages for the output instead of allocating fresh ones.

TPU adaptation (DESIGN.md §2): parameters are flattened and tiled into
(8·128)-aligned VMEM blocks; the K contributions arrive as a stacked [K, N]
operand so the per-block working set is (K+1)·BLOCK·4B — BLOCK is chosen so
this fits comfortably in ~16 MB VMEM.  The diff-norm outputs accumulate
across the sequential grid (same output block every step), an idiomatic
Pallas reduction.

**Per-shard use** (docs/sharding.md): the kernel is oblivious to whether
``[K, N]`` is the whole staging buffer or one block-cyclic shard of it —
the math is elementwise over N, so ``ops.fuse_flat_sharded`` simply runs
this launch on each shard's ``[K, shard_len]`` slice (tile-aligned by
construction: ``ShardedFlatSpec.block`` is a LANE multiple) and the
``sq_diff`` output becomes a *partial* that one ``psum`` completes.  The
weight normalization w/Σw is shard-invariant (weights are replicated), so
the fused output needs no communication at all.
"""
from __future__ import annotations

import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.flat import LANE as _LANE  # min 1-D tile (8 sublanes x 128 lanes)

DEFAULT_BLOCK = 64 * 1024  # f32 elems: (K+1)*256KB at K=8 -> ~2.3 MB VMEM


def _kernel(w_ref, base_ref, contribs_ref, alpha_ref, fused_ref, sq_ref):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    base = base_ref[...].astype(jnp.float32)  # [BLOCK]
    contribs = contribs_ref[...].astype(jnp.float32)  # [K, BLOCK]
    w = w_ref[...].astype(jnp.float32)  # [K]
    alpha = alpha_ref[0].astype(jnp.float32)
    wn = w / jnp.sum(w)
    # zero-weight rows are masked out entirely: 0 * NaN must not reach the sum
    masked = jnp.where((w == 0.0)[:, None], 0.0, contribs)
    avg = jnp.einsum("k,kn->n", wn, masked)
    fused_ref[...] = (base + alpha * (avg - base)).astype(fused_ref.dtype)
    diff = contribs - base[None, :]
    sq_ref[...] += jnp.sum(diff * diff, axis=1)


def _pad_to_blocks(base, contribs, block):
    K, N = contribs.shape
    pad = (-N) % block
    if pad:
        base = jnp.concatenate([base, jnp.zeros((pad,), base.dtype)])
        contribs = jnp.concatenate(
            [contribs, jnp.zeros((K, pad), contribs.dtype)], axis=1)
    return base, contribs


def _cold_fuse_impl(base, contribs, weights, alpha, block, interpret):
    K, N = contribs.shape
    # shrink the block for small inputs so padding stays bounded (tile-aligned)
    block = min(block, max(_LANE, ((N + _LANE - 1) // _LANE) * _LANE))
    base_p, contribs_p = _pad_to_blocks(base, contribs, block)
    n_blocks = base_p.shape[0] // block
    alpha_arr = jnp.asarray(jnp.reshape(alpha, (1,)), jnp.float32)

    fused, sq = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((K,), lambda i: (0,)),            # weights (whole)
            pl.BlockSpec((block,), lambda i: (i,)),        # base block
            pl.BlockSpec((K, block), lambda i: (0, i)),    # contrib blocks
            pl.BlockSpec((1,), lambda i: (0,)),            # alpha
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K,), lambda i: (0,)),            # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct(base_p.shape, base.dtype),
            jax.ShapeDtypeStruct((K,), jnp.float32),
        ],
        interpret=interpret,
    )(weights, base_p, contribs_p, alpha_arr)
    return fused[:N], sq


_jit_fuse = functools.partial(jax.jit, static_argnames=("block", "interpret"))
_cold_fuse = _jit_fuse(_cold_fuse_impl)
_cold_fuse_donated = _jit_fuse(_cold_fuse_impl, donate_argnums=(1,))


def call_donated(fn, *args, **kw):
    """Invoke a donated-jit function; backends that decline the donation
    (CPU) emit a warning we deliberately swallow."""
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=".*donat.*")
        return fn(*args, **kw)


def cold_fuse(
    base: jax.Array,      # [N]
    contribs: jax.Array,  # [K, N]
    weights: jax.Array,   # [K]
    alpha=1.0,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
    donate: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (fused [N], sq_diff [K]).  N is padded to the block size
    internally (padding contributes 0 to both outputs).  ``donate=True``
    hands the ``contribs`` buffer to XLA for reuse — only pass buffers you
    will not touch again."""
    if donate:
        return call_donated(
            _cold_fuse_donated, base, contribs, weights, alpha,
            block=block, interpret=interpret)
    return _cold_fuse(base, contribs, weights, alpha, block=block, interpret=interpret)


# ---------------------------------------------------------------------------
# decode_accum — weighted scatter-accumulate of compressed contribution deltas
# (docs/service_loop.md §Compressed submissions).  A compressed cohort
# arrives as [C, nb, kb] payload stacks (within-block int offsets +
# dequantized delta values); the fuse needs Σ_c w_c·Δ_c dense plus the per-
# contribution ||Δ_c||² screen statistic — and must get both WITHOUT ever
# materializing a dense [N] row per contributor.  The grid walks the nb
# codec blocks; each step decodes every contributor's kb entries for that
# block via a dense one-hot contraction ([C·kb, block] — TPU has no
# efficient scatter; same trick as the sketch kernel) and writes one
# [block] slice of the accumulator.  sq accumulates across the grid (same
# output block every step — the idiomatic Pallas reduction above).
# ---------------------------------------------------------------------------


def _decode_kernel(w_ref, idx_ref, dv_ref, acc_ref, sq_ref):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    C, _, kb = idx_ref.shape
    idx = idx_ref[...].reshape(C, kb)
    dv = dv_ref[...].astype(jnp.float32).reshape(C, kb)
    w = w_ref[...].astype(jnp.float32)
    block = acc_ref.shape[0]
    # zero-weight rows are masked out entirely: 0 * NaN must not reach the sum
    wdv = (jnp.where((w == 0.0)[:, None], 0.0, dv) * w[:, None]).reshape(C * kb)
    cols = jax.lax.broadcasted_iota(jnp.int32, (C * kb, block), 1)
    onehot = (idx.reshape(C * kb, 1) == cols).astype(jnp.float32)
    acc_ref[...] = jnp.einsum("k,kn->n", wdv, onehot)
    sq_ref[...] += jnp.sum(dv * dv, axis=1)


def _decode_accum_impl(indices, dvalues, weights, size, block, interpret):
    C, nb, kb = indices.shape
    acc, sq = pl.pallas_call(
        _decode_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),            # weights (whole)
            pl.BlockSpec((C, 1, kb), lambda i: (0, i, 0)),  # offsets, block i
            pl.BlockSpec((C, 1, kb), lambda i: (0, i, 0)),  # deltas, block i
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((C,), lambda i: (0,)),            # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * block,), jnp.float32),
            jax.ShapeDtypeStruct((C,), jnp.float32),
        ],
        interpret=interpret,
    )(weights, indices, dvalues)
    return acc[:size], sq


_decode_accum = _jit_fuse(
    _decode_accum_impl, static_argnames=("size", "block", "interpret"))


def decode_accum(
    indices: jax.Array,   # [C, nb, kb] int32 within-block offsets
    dvalues: jax.Array,   # [C, nb, kb] f32 dequantized deltas
    weights: jax.Array,   # [C]
    *,
    size: int,
    block: int,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (acc [size] = Σ_c w_c·Δ_c, sq [C] = ||Δ_c||²) — the fused
    decode+accumulate over a stacked compressed cohort.  ``block`` is the
    codec block (a LANE multiple); duplicate offsets accumulate.  Oracle:
    ``repro.kernels.ref.decode_accum``."""
    if indices.shape[0] == 0 or indices.shape[2] == 0:
        return (jnp.zeros((size,), jnp.float32),
                jnp.zeros((indices.shape[0],), jnp.float32))
    return _decode_accum(indices, dvalues, weights,
                         size=size, block=block, interpret=interpret)


# ---------------------------------------------------------------------------
# row_sketch — per-row block statistics for the novelty admission screen
# ---------------------------------------------------------------------------
#
# The service loop's content-based admission screen (docs/service_loop.md)
# needs, per submitted [N] row, a tiny fingerprint: bucketed tile sums
# (projections) and tile sq-norms — see kernels/ref.py:row_sketch for the
# exact contract.  Like cold_fuse this is HBM-bandwidth-bound streaming over
# the whole row, so the kernel reads each block exactly once and accumulates
# the [2, n_buckets] output across the sequential grid (same output block
# every step — the idiomatic Pallas reduction cold_fuse's sq_diff uses).
# Bucket membership is tile_index % n_buckets, realized as a dense one-hot
# contraction (TPU has no efficient scatter; the one-hot is [tiles, buckets]
# and trivially MXU/VPU-friendly).


def _make_sketch_kernel(n_buckets: int, tiles_per_block: int):
    def kernel(row_ref, out_ref):
        pid = pl.program_id(0)

        @pl.when(pid == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        x = row_ref[...].astype(jnp.float32).reshape(tiles_per_block, _LANE)
        ts = jnp.sum(x, axis=1)                  # [tiles]
        tq = jnp.sum(x * x, axis=1)
        # global tile index of this block's tiles; 2-D iota (TPU requires it)
        ti = (jax.lax.broadcasted_iota(jnp.int32, (tiles_per_block, n_buckets), 0)
              + pid * tiles_per_block)
        bi = jax.lax.broadcasted_iota(jnp.int32, (tiles_per_block, n_buckets), 1)
        onehot = (ti % n_buckets == bi).astype(jnp.float32)
        out_ref[...] += jnp.stack([ts @ onehot, tq @ onehot])

    return kernel


def _row_sketch_impl(row, n_buckets, block, interpret):
    (n,) = row.shape
    block = min(block, max(_LANE, ((n + _LANE - 1) // _LANE) * _LANE))
    pad = (-n) % block
    if pad:
        row = jnp.concatenate([row, jnp.zeros((pad,), row.dtype)])
    n_blocks = row.shape[0] // block
    return pl.pallas_call(
        _make_sketch_kernel(n_buckets, block // _LANE),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2, n_buckets), lambda i: (0, 0)),  # accumulated
        out_shape=jax.ShapeDtypeStruct((2, n_buckets), jnp.float32),
        interpret=interpret,
    )(row)


_row_sketch = _jit_fuse(_row_sketch_impl,
                        static_argnames=("n_buckets", "block", "interpret"))


def row_sketch(
    row: jax.Array,  # [N]
    n_buckets: int = 32,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Returns the ``[2, n_buckets]`` content sketch of one flat row in a
    single streaming read (tile-bucketed sums + sq sums; padding contributes
    0 to both).  Oracle: ``repro.kernels.ref.row_sketch``."""
    return _row_sketch(row, n_buckets=n_buckets, block=block, interpret=interpret)
