"""Pallas TPU kernel: blocked (flash) attention with causal + sliding-window
masking and GQA head sharing.

TPU adaptation (DESIGN.md §2): online-softmax accumulation in f32 VMEM
scratch; the grid is (B, Hq, Sq/bq, Sk/bk) with the KV-block axis innermost —
TPU grids execute sequentially per core, so the (acc, m, l) scratch carries
across KV blocks of one query block (the standard Mosaic flash pattern).
Block shapes default to MXU-aligned 128x128 tiles; the KV BlockSpec indexes
the shared KV head (h // rep) so grouped queries reuse the same KV tiles
straight from VMEM.

The pure-jnp oracle is `repro.kernels.ref.flash_attention`; tests sweep
shapes/dtypes/window sizes in interpret mode (this container has no TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal,
            window, q_offset, bq, bk, nk):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = q @ k.T  # [bq, bk]
    qi = pl.program_id(2)
    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, hd]
    k: jax.Array,  # [B, Sk, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "seq lens must divide block sizes"
    nq, nk = Sq // bq, Sk // bk

    kern = functools.partial(
        _kernel, scale=hd ** -0.5, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        kern,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, ki, _rep=rep: (b, ki, h // _rep, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, ki, _rep=rep: (b, ki, h // _rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),  # acc
            pltpu.VMEM((bq,), jnp.float32),     # running max m
            pltpu.VMEM((bq,), jnp.float32),     # running denom l
        ],
        interpret=interpret,
    )(q, k, v)
