"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels compile to Mosaic; on CPU (this container) they run in
``interpret=True`` mode for correctness.  ``use_kernels(False)`` (or the
REPRO_NO_KERNELS env var) routes everything to the pure-jnp oracles — the
dry-run lowering path uses the oracles because Pallas does not lower to the
CPU host platform.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ref
from repro.kernels.cold_fuse import call_donated as _call_donated
from repro.kernels.cold_fuse import cold_fuse as _cold_fuse_kernel
from repro.kernels.cold_fuse import decode_accum as _decode_accum_kernel
from repro.kernels.cold_fuse import row_sketch as _row_sketch_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv_kernel
from repro.launch.sharding import axes_entry, axes_extent, norm_axes
from repro.utils.flat import SKETCH_BUCKETS, FlatSpec, StagedBuffer

RWKV_LOGW_FLOOR = -4.0  # kernel contract (see rwkv6_scan docstring)

_STATE = {"enabled": os.environ.get("REPRO_NO_KERNELS", "0") != "1"}


def use_kernels(enabled: bool) -> None:
    _STATE["enabled"] = bool(enabled)


def kernels_enabled() -> bool:
    return _STATE["enabled"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------


def _staged(contribs):
    """Fuse operands accept either a raw array or an explicit
    ``StagedBuffer`` handle (the async double-buffered Repository hands the
    back buffer around as a handle — docs/async_repository.md)."""
    return contribs.data if isinstance(contribs, StagedBuffer) else contribs


def fuse_flat(base, contribs, weights, alpha: float = 1.0,
              *, donate: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused repository update over flattened parameter vectors.
    Returns (fused [N], sq_diff [K]).  ``contribs`` is the staged ``[K, N]``
    operand — a raw array or a ``StagedBuffer`` handle.  ``donate=True``
    hands the staged buffer to the backend for reuse (kernel path only).

    Unlike attention/rwkv, the Mosaic kernel only runs on real TPUs: the
    interpret-mode emulation is a correctness harness, several times slower
    than plain XLA, so on other backends the (jitted) flat jnp oracle serves
    the same single-pass contract (one read of the staged [K, N] buffer
    yields both the fused model and the screening statistics)."""
    contribs = _staged(contribs)
    if kernels_enabled() and not _interpret():
        return _cold_fuse_kernel(
            base, contribs, weights, alpha, interpret=False, donate=donate)
    if donate:
        return _call_donated(_ref_fuse_donated, base, contribs, weights, alpha)
    return _ref_fuse(base, contribs, weights, alpha)


_ref_fuse = jax.jit(ref.cold_fuse)
_ref_fuse_donated = jax.jit(ref.cold_fuse, donate_argnums=(1,))


def fuse_pytrees(base_tree, contrib_trees, weights=None, alpha: float = 1.0,
                 *, spec: Optional[FlatSpec] = None, donate: bool = False):
    """Repository fuse over pytrees: flatten the WHOLE model into one
    contiguous buffer per contributor, stack to [K, N], and issue ONE
    streaming kernel launch (not one padded launch per leaf).

    Returns (fused_tree, sq_diff [K] over all parameters).  Pass ``spec``
    when the caller already holds the FlatSpec (saves re-deriving it)."""
    K = len(contrib_trees)
    w = jnp.ones((K,), jnp.float32) if weights is None else jnp.asarray(weights, jnp.float32)
    if spec is None:
        spec = FlatSpec.from_tree(base_tree)
    base_flat = spec.flatten(base_tree)
    stage = jnp.stack([spec.flatten(t) for t in contrib_trees])
    fused, sq = fuse_flat(base_flat, stage, w, alpha, donate=donate)
    return spec.unflatten(fused), sq


# ---------------------------------------------------------------------------
# sharded flat fuse (docs/sharding.md) — the SAME single-pass screen+fuse
# contract as fuse_flat, run per block-cyclic shard under shard_map.  The
# fused output is elementwise over N (zero communication); the per-shard
# sq_diff partials are completed by exactly ONE psum per fuse.  The
# single-device fuse_flat / the per-leaf engine remain the oracles.
# ---------------------------------------------------------------------------

Axes = Union[str, Sequence[str]]


def _shard_cold_fuse(base, contribs, weights, alpha, *, use_kernel: bool):
    """The per-shard screen+fuse: the single-device cold_fuse contract run on
    one ``[K, shard_len]`` slice.  Returns (fused [shard_len], sq PARTIAL [K]).

    The weight normalization w/Σw uses the replicated global weights, so it
    is identical on every shard; zero-weight masking (the re-weighted second
    pass of the screen) therefore behaves exactly as on a single device."""
    if use_kernel:
        return _cold_fuse_kernel(base, contribs, weights, alpha, interpret=False)
    return ref.cold_fuse(base, contribs, weights, alpha)


@functools.lru_cache(maxsize=32)
def _sharded_fuse_fn(mesh: Mesh, axes: Tuple[str, ...], use_kernel: bool):
    """Build (once per mesh/axes) the jitted shard_map fuse over a
    ``[S, L]`` base and ``[K, S, L]`` staging buffer laid out by
    ``ShardedFlatSpec``.  Exactly one collective: the sq_diff psum."""
    row_spec = P(axes_entry(axes), None)
    stage_spec = P(None, axes_entry(axes), None)

    def local(base, contribs, weights, alpha):
        # local blocks carry a size-1 stub of the shard dim: strip/re-add it
        fused, sq = _shard_cold_fuse(
            base[0], contribs[:, 0, :], weights, alpha[0], use_kernel=use_kernel)
        return fused[None], jax.lax.psum(sq, axes)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(row_spec, stage_spec, P(), P()),
        out_specs=(row_spec, P()),
        check_rep=False,
    )
    return jax.jit(fn)


def fuse_flat_sharded(
    base: jax.Array,      # [S, shard_len] — sharded over `axes`
    contribs: jax.Array,  # [K, S, shard_len]
    weights: jax.Array,   # [K] (replicated)
    alpha=1.0,
    *,
    mesh: Mesh,
    axes: Axes,
) -> Tuple[jax.Array, jax.Array]:
    """Distributed fuse_flat over a block-cyclic staging layout.

    Returns (fused [S, shard_len] sharded like ``base``, sq_diff [K]
    replicated).  ``contribs`` is the staged operand — a raw array or a
    ``StagedBuffer`` handle.  Padding introduced by the layout is zero in
    both base and contributions, so it cancels in the diff and never biases
    ``sq_diff``.
    """
    contribs = _staged(contribs)
    ax = norm_axes(axes)
    use_kernel = kernels_enabled() and not _interpret()
    fn = _sharded_fuse_fn(mesh, ax, use_kernel)
    return fn(base, contribs,
              jnp.asarray(weights, jnp.float32),
              jnp.asarray(jnp.reshape(alpha, (1,)), jnp.float32))


@functools.lru_cache(maxsize=32)
def _cohort_fuse_fn(mesh: Mesh, contrib_axes: Tuple[str, ...],
                    shard_axes: Tuple[str, ...], alpha: float):
    """Mesh-level cohort fuse over a ``[C, S, L]`` stage: every contributor
    slab relaxes toward the α-damped cohort mean.

    Same sharded-flat structure as ``_sharded_fuse_fn`` with the roles of
    the axes swapped: here the *contributor* dim is the sharded reduction
    dim, so the per-shard partial is the local weighted sum over C_local and
    the single psum (over the contributor axes) completes the mean — no
    GSPMD ``concat -> mean`` ever lowers, which is what retires the jax
    0.4.37 miscompile workaround (see docs/sharding.md)."""
    in_spec = P(axes_entry(contrib_axes),
                axes_entry(shard_axes) if shard_axes else None, None)
    c_axes = axes_extent(mesh, contrib_axes)

    def local(x):  # [C_local, S_local(=1 when sharded), L]
        xf = x.astype(jnp.float32)
        # total cohort size: local slabs x contributor-axis extent
        part = jnp.sum(xf, axis=0, keepdims=True) / (x.shape[0] * c_axes)
        mean = jax.lax.psum(part, contrib_axes)
        if alpha != 1.0:
            fused = xf * (1.0 - alpha) + mean * alpha
        else:
            fused = jnp.broadcast_to(mean, xf.shape)
        return fused.astype(x.dtype)

    fn = shard_map(local, mesh=mesh, in_specs=(in_spec,),
                   out_specs=in_spec, check_rep=False)
    return jax.jit(fn)


def cohort_fuse_sharded(
    stage: jax.Array,  # [C, S, shard_len] — C over contrib_axes, S over shard_axes
    *,
    mesh: Mesh,
    contrib_axes: Axes,
    shard_axes: Axes = (),
    alpha: float = 1.0,
) -> jax.Array:
    """θ_c ← θ_c + α·(mean_c θ_c − θ_c), one psum over the contributor axes.

    The mesh-level counterpart of ``fuse_flat_sharded`` (the Repository
    path): both lay the flat buffer out block-cyclically and complete a
    per-shard partial with a single all-reduce; they differ only in which
    dim the psum runs over (sq_diff over the shard axes there, the
    contributor mean here).  ``stage`` accepts a raw array or a
    ``StagedBuffer`` handle."""
    stage = _staged(stage)
    fn = _cohort_fuse_fn(
        mesh, norm_axes(contrib_axes), norm_axes(shard_axes), float(alpha))
    return fn(stage)


# ---------------------------------------------------------------------------
# compressed fuse — screen+fuse directly over delta-compressed contributions
# (docs/service_loop.md §Compressed submissions).  A compressed contribution
# is θ_c = base + Δ_c with Δ_c carried as a DeltaPayload; substituting into
# the fuse gives
#
#     fused = base + α·[(Σ_d w_d θ_d + (Σ_c w_c)·base + Σ_c w_c Δ_c)/Σw − base]
#
# so the ONLY dense quantity the compressed side needs is the single
# accumulator Σ_c w_c Δ_c — one dense [N] total, never one per contributor —
# and the §9 screen statistic is ||Δ_c||² straight from the sparse payload.
# decode_accum produces both in one pass (Pallas on TPU, jnp oracle
# elsewhere); the sharded variant keeps the one-psum-per-fuse contract.
# ---------------------------------------------------------------------------


def decode_accum(indices, values, scales, weights, *,
                 size: int, block: int) -> Tuple[jax.Array, jax.Array]:
    """Decode+accumulate a stacked compressed cohort: returns
    (acc [size] = Σ_c w_c·Δ_c, sq [C] = ||Δ_c||²).  ``indices``/``values``
    are the stacked ``[C, nb, kb]`` payload arrays (any int/numeric dtype —
    cast internally), ``scales`` is ``[C, nb]``, ``block`` the codec block.
    Zero-weight contributions are masked out of ``acc``; ``sq`` always
    reflects the raw decoded delta."""
    idx = jnp.asarray(indices, jnp.int32)
    dv = (jnp.asarray(values, jnp.float32)
          * jnp.asarray(scales, jnp.float32)[..., None])
    w = jnp.asarray(weights, jnp.float32)
    if idx.shape[0] == 0 or idx.shape[2] == 0:
        return jnp.zeros((size,), jnp.float32), jnp.zeros((idx.shape[0],), jnp.float32)
    if kernels_enabled() and not _interpret():
        return _decode_accum_kernel(idx, dv, w, size=size, block=block,
                                    interpret=False)
    return _ref_decode(idx, dv, w, size=size, block=block)


_ref_decode = jax.jit(ref.decode_accum, static_argnames=("size", "block"))


@functools.partial(jax.jit, donate_argnums=())
def _compressed_combine(base, acc, comp_weights, sq_comp,
                        dense, dense_weights, alpha):
    """Finish the compressed fuse from the decoded accumulator: combined
    normalization over dense + compressed weights, zero-weight masking on
    the dense side, sq ordered (dense..., compressed...)."""
    bf = base.astype(jnp.float32)
    wd = dense_weights.astype(jnp.float32)
    wc = comp_weights.astype(jnp.float32)
    w_tot = jnp.sum(wd) + jnp.sum(wc)
    df = dense.astype(jnp.float32)
    masked = jnp.where((wd == 0.0)[:, None], 0.0, df)
    num = jnp.einsum("k,kn->n", wd, masked) + jnp.sum(wc) * bf + acc
    fused = (bf + alpha * (num / w_tot - bf)).astype(base.dtype)
    sq_dense = jnp.sum(jnp.square(df - bf[None, :]), axis=1)
    return fused, jnp.concatenate([sq_dense, sq_comp])


def fuse_flat_compressed(
    base: jax.Array,       # [N]
    indices, values, scales,  # stacked payloads: [C, nb, kb] / [C, nb]
    comp_weights,          # [C]
    alpha=1.0,
    *,
    block: int,
    dense=None,            # optional dense [K, N] side of a mixed cohort
    dense_weights=None,    # [K]
) -> Tuple[jax.Array, jax.Array]:
    """Fused repository update consuming delta-compressed contributions
    directly.  Returns (fused [N], sq_diff [K+C]) with sq ordered
    (dense contributions first, compressed after) — the same single-pass
    screen+fuse contract as ``fuse_flat``, but no dense ``[N]`` row is ever
    materialized per compressed contributor.  Oracle identity: with exact
    payloads this equals ``fuse_flat(base, stack(dense + decoded), w)``."""
    N = int(base.shape[0])
    acc, sq_comp = decode_accum(indices, values, scales, comp_weights,
                                size=N, block=block)
    if dense is None:
        dense = jnp.zeros((0, N), base.dtype)
        dense_weights = jnp.zeros((0,), jnp.float32)
    return _compressed_combine(
        base, acc, jnp.asarray(comp_weights, jnp.float32), sq_comp,
        _staged(dense), jnp.asarray(dense_weights, jnp.float32),
        jnp.asarray(alpha, jnp.float32))


@functools.lru_cache(maxsize=32)
def _compressed_sharded_fn(mesh: Mesh, axes: Tuple[str, ...], block: int,
                           use_kernel: bool, has_dense: bool):
    """Build (once per mesh/layout) the jitted shard_map compressed fuse
    over per-shard payload stacks ``[C, S, nb, kb]``.  Exactly one
    collective: the psum completing the concatenated (dense..., compressed...)
    sq partials — the fused output needs no communication at all."""
    row_spec = P(axes_entry(axes), None)
    stage_spec = P(None, axes_entry(axes), None)
    comp_spec = P(None, axes_entry(axes), None, None)
    scl_spec = P(None, axes_entry(axes), None)

    def _local_decode(idx, val, scl, wc, length):
        dv = val.astype(jnp.float32) * scl.astype(jnp.float32)[..., None]
        if idx.shape[0] == 0 or idx.shape[2] == 0:
            return (jnp.zeros((length,), jnp.float32),
                    jnp.zeros((idx.shape[0],), jnp.float32))
        if use_kernel:
            return _decode_accum_kernel(idx.astype(jnp.int32), dv, wc,
                                        size=length, block=block,
                                        interpret=False)
        return ref.decode_accum(idx.astype(jnp.int32), dv, wc,
                                size=length, block=block)

    def _local_math(base, acc, wc, sq_comp, dense, wd, alpha):
        bf = base.astype(jnp.float32)
        w_tot = jnp.sum(wd) + jnp.sum(wc)
        masked = jnp.where((wd == 0.0)[:, None], 0.0, dense.astype(jnp.float32))
        num = jnp.einsum("k,kn->n", wd, masked) + jnp.sum(wc) * bf + acc
        fused = (bf + alpha * (num / w_tot - bf)).astype(base.dtype)
        sq_dense = jnp.sum(jnp.square(dense.astype(jnp.float32) - bf[None, :]), axis=1)
        return fused, jnp.concatenate([sq_dense, sq_comp])

    if has_dense:
        def local(base, idx, val, scl, wc, dense, wd, alpha):
            # local blocks carry a size-1 stub of the shard dim: strip it
            acc, sq_comp = _local_decode(
                idx[:, 0], val[:, 0], scl[:, 0], wc, base.shape[1])
            fused, sq = _local_math(base[0], acc, wc, sq_comp,
                                    dense[:, 0, :], wd, alpha[0])
            return fused[None], jax.lax.psum(sq, axes)

        in_specs = (row_spec, comp_spec, comp_spec, scl_spec, P(),
                    stage_spec, P(), P())
    else:
        def local(base, idx, val, scl, wc, alpha):
            acc, sq_comp = _local_decode(
                idx[:, 0], val[:, 0], scl[:, 0], wc, base.shape[1])
            dense = jnp.zeros((0, base.shape[1]), base.dtype)
            wd = jnp.zeros((0,), jnp.float32)
            fused, sq = _local_math(base[0], acc, wc, sq_comp,
                                    dense, wd, alpha[0])
            return fused[None], jax.lax.psum(sq, axes)

        in_specs = (row_spec, comp_spec, comp_spec, scl_spec, P(), P())

    fn = shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=(row_spec, P()),
        check_rep=False,
    )
    return jax.jit(fn)


def fuse_flat_compressed_sharded(
    base: jax.Array,       # [S, shard_len] — sharded over `axes`
    indices, values, scales,  # [C, S, nb, kb] / [C, S, nb] per-shard stacks
    comp_weights,          # [C] (replicated)
    alpha=1.0,
    *,
    mesh: Mesh,
    axes: Axes,
    block: int,
    dense=None,            # optional dense [K, S, shard_len] side
    dense_weights=None,    # [K]
) -> Tuple[jax.Array, jax.Array]:
    """Distributed ``fuse_flat_compressed`` over a block-cyclic layout:
    each shard decodes its own payload slices (``delta_encode_sharded``
    order) and fuses locally; the concatenated sq partials are completed by
    exactly ONE psum — the same one-all-reduce contract as
    ``fuse_flat_sharded`` (docs/sharding.md).  Returns (fused [S, shard_len]
    sharded like ``base``, sq_diff [K+C] replicated, dense first)."""
    ax = norm_axes(axes)
    use_kernel = kernels_enabled() and not _interpret()
    wc = jnp.asarray(comp_weights, jnp.float32)
    alpha_arr = jnp.asarray(jnp.reshape(alpha, (1,)), jnp.float32)
    idx = jnp.asarray(indices)
    val = jnp.asarray(values)
    scl = jnp.asarray(scales)
    if dense is None:
        fn = _compressed_sharded_fn(mesh, ax, int(block), use_kernel, False)
        return fn(base, idx, val, scl, wc, alpha_arr)
    fn = _compressed_sharded_fn(mesh, ax, int(block), use_kernel, True)
    return fn(base, idx, val, scl, wc, _staged(dense),
              jnp.asarray(dense_weights, jnp.float32), alpha_arr)


# ---------------------------------------------------------------------------
# row_sketch — the novelty admission screen's per-row fingerprint
# (docs/service_loop.md).  Single-device: one streaming read of the [N] row
# (Pallas kernel on TPU, jitted jnp oracle elsewhere).  Sharded: per-shard
# partials under shard_map completed by exactly ONE psum — the same
# one-all-reduce comm contract as the sharded fuse (docs/sharding.md).
# ---------------------------------------------------------------------------


def row_sketch(row: jax.Array, n_buckets: int = SKETCH_BUCKETS) -> jax.Array:
    """Content sketch of one flat ``[N]`` row: ``[2, n_buckets]`` f32 of
    tile-bucketed sums and sq sums, in a single read of the row.  The host
    logic that screens with it lives in ``repro.utils.flat.CohortSketch``."""
    if kernels_enabled() and not _interpret():
        return _row_sketch_kernel(row, n_buckets, interpret=False)
    return _ref_sketch(row, n_buckets)


_ref_sketch = jax.jit(ref.row_sketch, static_argnums=(1,))


@functools.lru_cache(maxsize=32)
def _sharded_sketch_fn(mesh: Mesh, axes: Tuple[str, ...], n_shards: int,
                       block: int, n_buckets: int):
    """Build (once per mesh/layout) the jitted shard_map sketch over a
    block-cyclic ``[S, shard_len]`` row.  Exactly one collective: the psum
    completing the per-shard partials."""
    row_spec = P(axes_entry(axes), None)

    def local(row):  # [1, shard_len] local stub of the shard dim
        idx = jnp.int32(0)
        for a in axes:  # linear shard index, first axis most significant
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        part = ref.row_sketch_shard(row[0], idx, n_shards, block, n_buckets)
        return jax.lax.psum(part, axes)

    fn = shard_map(local, mesh=mesh, in_specs=(row_spec,), out_specs=P(),
                   check_rep=False)
    return jax.jit(fn)


def row_sketch_sharded(
    row: jax.Array,  # [S, shard_len] — sharded over `axes`
    *,
    mesh: Mesh,
    axes: Axes,
    block: int,
    n_buckets: int = SKETCH_BUCKETS,
) -> jax.Array:
    """Distributed ``row_sketch`` over a ``ShardedFlatSpec`` placement:
    each shard sketches its own slice (bucket ids derived from the
    block-cyclic layout, so membership matches the portable row) and one
    ``psum`` completes the ``[2, n_buckets]`` result, replicated.  ``block``
    is the layout's ``ShardedFlatSpec.block``."""
    ax = norm_axes(axes)
    fn = _sharded_sketch_fn(mesh, ax, int(row.shape[0]), int(block), n_buckets)
    return fn(row)


def attention(q, k, v, *, causal=True, window: Optional[int] = None, q_offset: int = 0,
              block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Blocked attention (GQA, causal, sliding window)."""
    if kernels_enabled():
        return _flash_kernel(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=block_q, block_k=block_k, interpret=_interpret(),
        )
    return ref.flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def rwkv6_mix(r, k, v, logw, u, s0, *, chunk: int = 16) -> Tuple[jax.Array, jax.Array]:
    """Chunked RWKV6 recurrence.  ``logw`` is clamped to the kernel contract
    (a per-step decay below e^-4 zeroes state within two tokens anyway)."""
    logw = jnp.clip(logw, RWKV_LOGW_FLOOR, 0.0)
    if kernels_enabled():
        return _rwkv_kernel(r, k, v, logw, u, s0, chunk=chunk, interpret=_interpret())
    return ref.rwkv6_scan(r, k, v, jnp.exp(logw), u, s0)
