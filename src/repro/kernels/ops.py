"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels compile to Mosaic; on CPU (this container) they run in
``interpret=True`` mode for correctness.  ``use_kernels(False)`` (or the
REPRO_NO_KERNELS env var) routes everything to the pure-jnp oracles — the
dry-run lowering path uses the oracles because Pallas does not lower to the
CPU host platform.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cold_fuse import cold_fuse as _cold_fuse_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv_kernel

RWKV_LOGW_FLOOR = -4.0  # kernel contract (see rwkv6_scan docstring)

_STATE = {"enabled": os.environ.get("REPRO_NO_KERNELS", "0") != "1"}


def use_kernels(enabled: bool) -> None:
    _STATE["enabled"] = bool(enabled)


def kernels_enabled() -> bool:
    return _STATE["enabled"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------


def fuse_flat(base, contribs, weights, alpha: float = 1.0) -> Tuple[jax.Array, jax.Array]:
    """Fused repository update over flattened parameter vectors.
    Returns (fused [N], sq_diff [K])."""
    if kernels_enabled():
        return _cold_fuse_kernel(base, contribs, weights, alpha, interpret=_interpret())
    return ref.cold_fuse(base, contribs, weights, alpha)


def fuse_pytrees(base_tree, contrib_trees, weights=None, alpha: float = 1.0):
    """Repository fuse over pytrees via the kernel: flatten, fuse, restore.
    Returns (fused_tree, sq_diff [K] aggregated over all leaves)."""
    K = len(contrib_trees)
    w = jnp.ones((K,), jnp.float32) if weights is None else jnp.asarray(weights, jnp.float32)
    leaves_b, treedef = jax.tree.flatten(base_tree)
    leaves_c = [jax.tree.leaves(t) for t in contrib_trees]
    fused_leaves = []
    sq_total = jnp.zeros((K,), jnp.float32)
    for i, lb in enumerate(leaves_b):
        flat_b = lb.reshape(-1)
        flat_c = jnp.stack([leaves_c[k][i].reshape(-1) for k in range(K)])
        fused, sq = fuse_flat(flat_b, flat_c, w, alpha)
        fused_leaves.append(fused.reshape(lb.shape))
        sq_total = sq_total + sq
    return jax.tree.unflatten(treedef, fused_leaves), sq_total


def attention(q, k, v, *, causal=True, window: Optional[int] = None, q_offset: int = 0,
              block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Blocked attention (GQA, causal, sliding window)."""
    if kernels_enabled():
        return _flash_kernel(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=block_q, block_k=block_k, interpret=_interpret(),
        )
    return ref.flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def rwkv6_mix(r, k, v, logw, u, s0, *, chunk: int = 16) -> Tuple[jax.Array, jax.Array]:
    """Chunked RWKV6 recurrence.  ``logw`` is clamped to the kernel contract
    (a per-step decay below e^-4 zeroes state within two tokens anyway)."""
    logw = jnp.clip(logw, RWKV_LOGW_FLOOR, 0.0)
    if kernels_enabled():
        return _rwkv_kernel(r, k, v, logw, u, s0, chunk=chunk, interpret=_interpret())
    return ref.rwkv6_scan(r, k, v, jnp.exp(logw), u, s0)
