"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels compile to Mosaic; on CPU (this container) they run in
``interpret=True`` mode for correctness.  ``use_kernels(False)`` (or the
REPRO_NO_KERNELS env var) routes everything to the pure-jnp oracles — the
dry-run lowering path uses the oracles because Pallas does not lower to the
CPU host platform.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cold_fuse import call_donated as _call_donated
from repro.kernels.cold_fuse import cold_fuse as _cold_fuse_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv_kernel
from repro.utils.flat import FlatSpec

RWKV_LOGW_FLOOR = -4.0  # kernel contract (see rwkv6_scan docstring)

_STATE = {"enabled": os.environ.get("REPRO_NO_KERNELS", "0") != "1"}


def use_kernels(enabled: bool) -> None:
    _STATE["enabled"] = bool(enabled)


def kernels_enabled() -> bool:
    return _STATE["enabled"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------


def fuse_flat(base, contribs, weights, alpha: float = 1.0,
              *, donate: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused repository update over flattened parameter vectors.
    Returns (fused [N], sq_diff [K]).  ``donate=True`` hands the staged
    ``contribs`` buffer to the backend for reuse (kernel path only).

    Unlike attention/rwkv, the Mosaic kernel only runs on real TPUs: the
    interpret-mode emulation is a correctness harness, several times slower
    than plain XLA, so on other backends the (jitted) flat jnp oracle serves
    the same single-pass contract (one read of the staged [K, N] buffer
    yields both the fused model and the screening statistics)."""
    if kernels_enabled() and not _interpret():
        return _cold_fuse_kernel(
            base, contribs, weights, alpha, interpret=False, donate=donate)
    if donate:
        return _call_donated(_ref_fuse_donated, base, contribs, weights, alpha)
    return _ref_fuse(base, contribs, weights, alpha)


_ref_fuse = jax.jit(ref.cold_fuse)
_ref_fuse_donated = jax.jit(ref.cold_fuse, donate_argnums=(1,))


def fuse_pytrees(base_tree, contrib_trees, weights=None, alpha: float = 1.0,
                 *, spec: Optional[FlatSpec] = None, donate: bool = False):
    """Repository fuse over pytrees: flatten the WHOLE model into one
    contiguous buffer per contributor, stack to [K, N], and issue ONE
    streaming kernel launch (not one padded launch per leaf).

    Returns (fused_tree, sq_diff [K] over all parameters).  Pass ``spec``
    when the caller already holds the FlatSpec (saves re-deriving it)."""
    K = len(contrib_trees)
    w = jnp.ones((K,), jnp.float32) if weights is None else jnp.asarray(weights, jnp.float32)
    if spec is None:
        spec = FlatSpec.from_tree(base_tree)
    base_flat = spec.flatten(base_tree)
    stage = jnp.stack([spec.flatten(t) for t in contrib_trees])
    fused, sq = fuse_flat(base_flat, stage, w, alpha, donate=donate)
    return spec.unflatten(fused), sq


def attention(q, k, v, *, causal=True, window: Optional[int] = None, q_offset: int = 0,
              block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Blocked attention (GQA, causal, sliding window)."""
    if kernels_enabled():
        return _flash_kernel(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=block_q, block_k=block_k, interpret=_interpret(),
        )
    return ref.flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def rwkv6_mix(r, k, v, logw, u, s0, *, chunk: int = 16) -> Tuple[jax.Array, jax.Array]:
    """Chunked RWKV6 recurrence.  ``logw`` is clamped to the kernel contract
    (a per-step decay below e^-4 zeroes state within two tokens anyway)."""
    logw = jnp.clip(logw, RWKV_LOGW_FLOOR, 0.0)
    if kernels_enabled():
        return _rwkv_kernel(r, k, v, logw, u, s0, chunk=chunk, interpret=_interpret())
    return ref.rwkv6_scan(r, k, v, jnp.exp(logw), u, s0)
