"""Pallas TPU kernel: chunked RWKV6 recurrence (data-dependent decay).

The oracle recurrence (`repro.kernels.ref.rwkv6_scan`) is O(T) sequential
with a rank-1 state update per step — hostile to the MXU.  TPU adaptation
(DESIGN.md §2): process the sequence in chunks of C tokens, turning the
recurrence into three MXU matmuls per chunk (the FLA "chunked" formulation):

  in-chunk   A[t,s] = Σ_i r_t,i k_s,i exp(cum_excl[t,i] − cum[s,i]) (s < t)
             y_in   = A @ V
  carry-in   y_st   = (R ⊙ exp(cum_excl)) @ S
  bonus      y_u    = ((R ⊙ u ⊙ K)·1) ⊙ V        (current token)
  state      S'     = diag(exp(cum_last)) S + (K ⊙ exp(cum_last − cum))ᵀ V

where cum = cumsum(log w) within the chunk.  All decay ratios that touch
data are ≤ 1 (exponents ≤ 0), so the math is f32-stable given the documented
contract ``log w ≥ -4`` per step (enforced by the ops.py wrapper; a decay
below e⁻⁴ zeroes the state within two tokens anyway).

Grid: (B, H, T/C), chunk axis innermost — the [hd, hd] f32 state lives in
VMEM scratch and carries across the sequential grid steps of one (b, h).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 16


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sT_ref, S, *, C, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        S[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # [C, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)  # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)             # [hd]

    cum = jnp.cumsum(lw, axis=0)          # inclusive
    cum_excl = cum - lw                   # exclusive
    # offset per channel keeps both exp factors finite (see module docstring)
    m = cum[C // 2][None, :]
    qf = r * jnp.exp(cum_excl - m)        # [C, hd]
    kf = k * jnp.exp(m - cum)             # [C, hd]
    A = qf @ kf.T                         # [C, C]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(t_idx > s_idx, A, 0.0)

    y = A @ v                             # in-chunk
    y += (r * jnp.exp(cum_excl)) @ S[...]  # carried state
    y += jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v  # bonus

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    cum_last = cum[-1][None, :]
    k2 = k * jnp.exp(cum_last - cum)      # [C, hd], factors <= 1
    S[...] = jnp.exp(cum_last.T) * S[...] + k2.T @ v

    @pl.when(ci == nc - 1)
    def _finish():
        sT_ref[0, 0] = S[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,   # [B, T, H, hd]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # [B, T, H, hd] log-decay, contract: in [-4, 0]
    u: jax.Array,     # [H, hd]
    s0: jax.Array,    # [B, H, hd, hd] f32
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B, T, H, hd], s_final [B, H, hd, hd])."""
    B, T, H, hd = r.shape
    C = min(chunk, T)
    assert T % C == 0, "T must divide the chunk size"
    nc = T // C

    kern = functools.partial(_kernel, C=C, nc=nc)
    y, sT = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, C, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, C, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, C, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, C, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return y, sT
