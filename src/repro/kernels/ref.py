"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# cold_fuse: K-way weighted parameter average + per-contribution diff norms
# ---------------------------------------------------------------------------


def cold_fuse(
    base: jax.Array,  # [N]
    contribs: jax.Array,  # [K, N]
    weights: jax.Array,  # [K] (need not be normalized)
    alpha: float = 1.0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (fused [N], sq_diff [K]).

    fused = base + alpha * (Σ_k w_k θ_k / Σ_k w_k − base)
    sq_diff[k] = ||θ_k − base||² (the §9 screening statistic).

    Zero-weight contributions are masked out of the average entirely (even
    non-finite ones — NaN·0 must not poison the sum), matching the Pallas
    kernel's single-pass screen+fuse contract; sq_diff always reflects the
    raw values.
    """
    w = weights.astype(jnp.float32)
    wn = w / jnp.sum(w)
    cf = contribs.astype(jnp.float32)
    bf = base.astype(jnp.float32)
    masked = jnp.where((w == 0.0)[:, None], 0.0, cf)
    avg = jnp.einsum("k,kn->n", wn, masked)
    fused = (bf + alpha * (avg - bf)).astype(base.dtype)
    sq = jnp.sum(jnp.square(cf - bf[None, :]), axis=1)
    return fused, sq


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window, GQA)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, hd]
    k: jax.Array,  # [B, Sk, Hkv, hd]
    v: jax.Array,  # [B, Sk, Hkv, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# rwkv6 recurrence (data-dependent decay)
# ---------------------------------------------------------------------------


def rwkv6_scan(
    r: jax.Array,  # [B, T, H, hd]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # [B, T, H, hd] per-step decay in (0, 1]
    u: jax.Array,  # [H, hd] current-token bonus
    s0: Optional[jax.Array] = None,  # [B, H, hd, hd] f32
) -> Tuple[jax.Array, jax.Array]:
    """Sequential oracle.  Returns (y [B, T, H, hd], s_final [B, H, hd, hd]).

        y_t = r_t · (u ⊙ k_t v_tᵀ + S_t);  S_{t+1} = w_t ⊙ S_t + k_t v_tᵀ
    """
    B, T, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None].astype(jnp.float32) * v_t[..., None, :].astype(jnp.float32)
        y = jnp.einsum("bhi,bhij->bhj", r_t.astype(jnp.float32), u[None, :, :, None] * kv + S)
        S = w_t[..., :, None].astype(jnp.float32) * S + kv
        return S, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0, inputs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), sT
