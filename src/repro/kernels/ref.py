"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.utils.flat import LANE as _LANE


# ---------------------------------------------------------------------------
# cold_fuse: K-way weighted parameter average + per-contribution diff norms
# ---------------------------------------------------------------------------


def cold_fuse(
    base: jax.Array,  # [N]
    contribs: jax.Array,  # [K, N]
    weights: jax.Array,  # [K] (need not be normalized)
    alpha: float = 1.0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (fused [N], sq_diff [K]).

    fused = base + alpha * (Σ_k w_k θ_k / Σ_k w_k − base)
    sq_diff[k] = ||θ_k − base||² (the §9 screening statistic).

    Zero-weight contributions are masked out of the average entirely (even
    non-finite ones — NaN·0 must not poison the sum), matching the Pallas
    kernel's single-pass screen+fuse contract; sq_diff always reflects the
    raw values.
    """
    w = weights.astype(jnp.float32)
    wn = w / jnp.sum(w)
    cf = contribs.astype(jnp.float32)
    bf = base.astype(jnp.float32)
    masked = jnp.where((w == 0.0)[:, None], 0.0, cf)
    avg = jnp.einsum("k,kn->n", wn, masked)
    fused = (bf + alpha * (avg - bf)).astype(base.dtype)
    sq = jnp.sum(jnp.square(cf - bf[None, :]), axis=1)
    return fused, sq


# ---------------------------------------------------------------------------
# decode_accum: weighted scatter-accumulate of compressed contribution deltas
# ---------------------------------------------------------------------------


def decode_accum(
    indices: jax.Array,   # [C, nb, kb] int — within-block offsets
    dvalues: jax.Array,   # [C, nb, kb] f32 — dequantized deltas (values·scales)
    weights: jax.Array,   # [C]
    *,
    size: int,
    block: int,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (acc [size], sq [C]) for C compressed contributions
    (``repro.utils.flat.DeltaPayload`` stacked along a leading axis):

        acc[n]  = Σ_c w_c · Δ_c[n]        (the fuse numerator's delta term)
        sq[c]   = Σ |Δ_c|²                (the §9 screening statistic)

    Entry j of block b lands at ``b·block + indices[c,b,j]``; duplicate
    offsets accumulate (scatter-add), padding-slot ``(0, 0)`` entries add
    zero, and anything past ``size`` is trimmed.  Zero-weight contributions
    are masked out of ``acc`` entirely (NaN·0 must not poison the sum —
    the same re-weighted-second-pass contract as ``cold_fuse``); ``sq``
    always reflects the raw decoded delta.
    """
    C, nb, kb = indices.shape
    w = weights.astype(jnp.float32)
    dv = dvalues.astype(jnp.float32)
    acc = jnp.zeros((nb * block,), jnp.float32)
    if C and kb:
        gi = (jnp.arange(nb, dtype=jnp.int32)[None, :, None] * block
              + indices.astype(jnp.int32))
        wdv = jnp.where((w == 0.0)[:, None, None], 0.0, dv) * w[:, None, None]
        acc = acc.at[gi.reshape(-1)].add(wdv.reshape(-1))
    sq = jnp.sum(dv * dv, axis=(1, 2))
    return acc[:size], sq


# ---------------------------------------------------------------------------
# row_sketch: per-row block statistics for the novelty admission screen
# ---------------------------------------------------------------------------


def _tile_stats(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[T*LANE] -> per-tile (sums [T], sq sums [T]) in one read."""
    tiles = x.reshape(-1, _LANE)
    return jnp.sum(tiles, axis=1), jnp.sum(tiles * tiles, axis=1)


def _bucketize(ts: jax.Array, tq: jax.Array, g: jax.Array,
               n_buckets: int) -> jax.Array:
    """Accumulate per-tile stats into their buckets (tile with global index
    ``g`` lands in bucket ``g % n_buckets``).  Dense one-hot matmul instead
    of a scatter: ``n_buckets`` is small and the same contraction lowers on
    every backend (including the Pallas TPU kernel, where scatters do not)."""
    onehot = (g[:, None] % n_buckets
              == jnp.arange(n_buckets)[None, :]).astype(jnp.float32)
    return jnp.stack([ts @ onehot, tq @ onehot])


def row_sketch(row: jax.Array, n_buckets: int = 32) -> jax.Array:
    """Content sketch of one flat ``[N]`` row in a single read.

    The row is cut into LANE-element tiles; tile ``t`` feeds bucket
    ``t % n_buckets`` of two statistics:

        sketch[0, j] = Σ_{tiles t ≡ j} Σ_i row[t·LANE + i]      (projection)
        sketch[1, j] = Σ_{tiles t ≡ j} Σ_i row[t·LANE + i]²     (sq norm)

    Returns ``[2, n_buckets]`` float32.  Both statistics give lower bounds
    on the distance between two rows (Cauchy–Schwarz over the projections,
    the reverse triangle inequality over the blockwise norms), which is
    what ``repro.utils.flat.CohortSketch`` screens with.  Zero padding
    contributes nothing, so the sketch is invariant to the block-cyclic
    layout: ``row_sketch_shard`` partials psum to exactly this value.
    """
    x = jnp.asarray(row).astype(jnp.float32)
    pad = (-x.shape[-1]) % _LANE
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), jnp.float32)])
    ts, tq = _tile_stats(x)
    return _bucketize(ts, tq, jnp.arange(ts.shape[0]), n_buckets)


def row_sketch_shard(slab: jax.Array, shard_index, n_shards: int,
                     block: int, n_buckets: int = 32) -> jax.Array:
    """One shard's sketch *partial* from its block-cyclic ``[shard_len]``
    slice (``ShardedFlatSpec``: layout block ``j`` lives on shard
    ``j % n_shards`` at slot ``j // n_shards``).

    The slice's tile at (slot ``u``, within-block tile ``v``) is global
    tile ``(u·n_shards + shard_index)·(block/LANE) + v``, so bucket
    membership matches the portable row and summing (psum-ing) the S
    partials reproduces ``row_sketch`` of the full ``[N]`` row exactly.
    ``shard_index`` may be traced (``jax.lax.axis_index`` under shard_map).
    """
    x = jnp.asarray(slab).astype(jnp.float32)
    tpb = block // _LANE
    ts, tq = _tile_stats(x)
    t = jnp.arange(ts.shape[0])
    g = ((t // tpb) * n_shards + shard_index) * tpb + t % tpb
    return _bucketize(ts, tq, g, n_buckets)


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window, GQA)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, hd]
    k: jax.Array,  # [B, Sk, Hkv, hd]
    v: jax.Array,  # [B, Sk, Hkv, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# rwkv6 recurrence (data-dependent decay)
# ---------------------------------------------------------------------------


def rwkv6_scan(
    r: jax.Array,  # [B, T, H, hd]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # [B, T, H, hd] per-step decay in (0, 1]
    u: jax.Array,  # [H, hd] current-token bonus
    s0: Optional[jax.Array] = None,  # [B, H, hd, hd] f32
) -> Tuple[jax.Array, jax.Array]:
    """Sequential oracle.  Returns (y [B, T, H, hd], s_final [B, H, hd, hd]).

        y_t = r_t · (u ⊙ k_t v_tᵀ + S_t);  S_{t+1} = w_t ⊙ S_t + k_t v_tᵀ
    """
    B, T, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None].astype(jnp.float32) * v_t[..., None, :].astype(jnp.float32)
        y = jnp.einsum("bhi,bhij->bhj", r_t.astype(jnp.float32), u[None, :, :, None] * kv + S)
        S = w_t[..., :, None].astype(jnp.float32) * S + kv
        return S, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0, inputs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), sT
