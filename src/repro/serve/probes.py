"""Post-publish task probes: the forgetting regression gate's sensor.

ColD Fusion's claim is that recycling finetunes *improves* the shared
base; the §9 MAD screen and the novelty screen reject anomalous *rows*,
but a statistically unremarkable cohort can still publish a base that
regresses earlier tasks ("Merging without Forgetting", Pan et al.; paper
§8 calls for "backtracking when a harmful update was done").  The
``ProbeSuite`` here is the cheap, fixed, per-task measurement the service
runs after every publish; ``docs/observability.md`` documents the full
probe → gate → rollback → quarantine lifecycle.

Design constraints, in order:

* **architecture-agnostic** — the service owns an arbitrary parameter
  pytree; it cannot assume a forward function.  Each probe therefore
  scores the *flat* ``[N]`` base directly: task ``k`` reads a fixed
  pseudo-random slice of the base as a linear readout ``W_k ∈ R^{M x C}``
  over the synthetic suite's motif features, and the probe score is the
  classification loss of that readout on a frozen eval batch
  (``repro.data.synthetic.SyntheticSuite`` features,
  ``repro.train.losses.cls_loss``).  Any movement of the base moves the
  scores; a *harmful* fuse (large or adversarial drift) moves them far
  beyond the per-fuse drift of a benign cohort.
* **deterministic** — batches, readout indices, and signs are all fixed
  by ``(seed, task id)``; the same base always scores identically, which
  is what lets a restarted daemon *replay* a gate verdict after a crash
  (docs/service_loop.md, crash matrix).
* **cheap** — a few tasks x a few dozen examples x one ``[n, M] @ [M, C]``
  matmul: microseconds next to a fuse, so the gate can run on every
  publish.

``compare`` applies a **per-task tolerance**: the gate trips when more
than ``max_regressed`` tasks worsened by more than ``tolerance`` loss
versus the pre-fuse baseline.  Tolerance is on the per-fuse *delta*, not
an absolute bar — the baseline is re-established at every clean publish,
so benign drift never accumulates into a false trip.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SyntheticSuite
from repro.train.losses import accuracy, cls_loss
from repro.utils.flat import FlatSpec


class MultitaskEvals:
    """REAL task evals for the regression gate: frozen
    ``train/multitask``-format batches scored by running the actual model.

    The synthetic linear-readout probes (below) are architecture-agnostic
    but only measure that the base *moved*; this suite closes the ROADMAP
    probe-quality gap — a gate trip means "task accuracy fell on held-out
    data", because each probe is the model's own classification loss:
    the flat ``[N]`` base is unflattened through the repository's
    ``FlatSpec`` into the encoder body and scored with the same
    ``classify``/``cls_loss`` the multitask trainer optimizes.

    ``datasets`` uses the ``train_multitask`` format —
    ``(task_id, x, y, n_cls)`` with ``x`` ``[n, T]`` int tokens and ``y``
    ``[n]`` int labels; pass the held-out split, not training batches.
    ``heads`` maps ``task_id -> cls head``; by default heads are
    initialized with ``train_multitask``'s per-task seeding convention
    (``seed * 997 + task_id``) so gate scores line up with a training run
    that hands its trained heads in.  Everything is frozen at
    construction: ``score`` is a pure deterministic function of the base,
    which is what lets a restarted daemon replay a gate verdict
    (docs/service_loop.md crash matrix).
    """

    def __init__(self, cfg, base_params, datasets: Sequence[Tuple[int, np.ndarray, np.ndarray, int]],
                 *, seed: int = 0, heads: Optional[Dict[int, Any]] = None):
        from repro.models import encoder as E  # heavyweight: local import
        self._E = E
        self.cfg = cfg
        self.spec = FlatSpec.from_tree(base_params)
        self.seed = int(seed)
        if not datasets:
            raise ValueError("MultitaskEvals needs at least one eval dataset")
        self.heads: Dict[int, Any] = dict(heads) if heads else {}
        self._batches: List[Tuple[str, int, np.ndarray, np.ndarray]] = []
        for tid, x, y, n_cls in datasets:
            tid = int(tid)
            if tid not in self.heads:
                self.heads[tid] = E.init_cls_head(
                    cfg, jax.random.PRNGKey(self.seed * 997 + tid), n_cls)
            self._batches.append((f"task{tid:02d}", tid,
                                  np.asarray(x), np.asarray(y)))

    @property
    def size(self) -> int:
        """Flat base length this suite scores (``FlatSpec.size``)."""
        return self.spec.size

    @property
    def task_names(self) -> List[str]:
        return [name for name, *_ in self._batches]

    def _body(self, flat: np.ndarray):
        return self.spec.unflatten(jnp.asarray(flat, self.spec.dtype))

    def score(self, flat: np.ndarray) -> Dict[str, float]:
        """Per-task eval losses of a flat ``[N]`` base."""
        body = self._body(flat)
        out: Dict[str, float] = {}
        for name, tid, x, y in self._batches:
            logits = self._E.classify(self.cfg, body, self.heads[tid], x)
            out[name] = float(cls_loss(logits, jnp.asarray(y)))
        return out

    def accuracies(self, flat: np.ndarray) -> Dict[str, float]:
        body = self._body(flat)
        out: Dict[str, float] = {}
        for name, tid, x, y in self._batches:
            logits = self._E.classify(self.cfg, body, self.heads[tid], x)
            out[name] = float(accuracy(logits, jnp.asarray(y)))
        return out


@dataclass
class ProbeReport:
    """One gate comparison: per-task (baseline, score) with the verdict."""

    ok: bool
    tolerance: float
    max_regressed: int
    regressed: List[str]                      # task names over tolerance
    deltas: Dict[str, float]                  # score - baseline, per task
    scores: Dict[str, float]
    baseline: Dict[str, float]

    @property
    def worst(self) -> float:
        """The largest per-task loss increase (negative = all improved)."""
        return max(self.deltas.values()) if self.deltas else 0.0

    def to_json(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "tolerance": self.tolerance,
            "regressed": list(self.regressed),
            "worst_delta": self.worst,
            "scores": dict(self.scores),
        }


class ProbeSuite:
    """Fixed per-task eval batches scoring a flat ``[N]`` base.

    ``size`` is the flat base length (``FlatSpec.size``); everything else
    shapes the probe pool.  All randomness is consumed at construction —
    ``score`` is a pure deterministic function of the base afterwards.
    """

    def __init__(self, size: int, *, n_tasks: int = 4, n_examples: int = 32,
                 seq_len: int = 16, seed: int = 0,
                 suite: Optional[Any] = None):
        if size <= 0:
            raise ValueError(f"flat base size must be positive, got {size}")
        if n_tasks < 1:
            raise ValueError(f"need at least one probe task, got {n_tasks}")
        self.size = int(size)
        # suite= accepts a MultitaskEvals: the gate then scores REAL task
        # evals (model forward + cls_loss) instead of the synthetic linear
        # readouts — a trip means "task accuracy fell" (docs/serving.md)
        self._evals: Optional[MultitaskEvals] = None
        if isinstance(suite, MultitaskEvals):
            if suite.size != self.size:
                raise ValueError(
                    f"MultitaskEvals scores a flat base of size "
                    f"{suite.size}, but the probe suite was asked for "
                    f"size {self.size}")
            self._evals = suite
            self.suite = suite
            self.n_tasks = len(suite.task_names)
            self.n_examples = int(n_examples)
            self.seq_len = int(seq_len)
            self.seed = suite.seed
            self._tasks = []
            return
        self.n_tasks = int(n_tasks)
        self.n_examples = int(n_examples)
        self.seq_len = int(seq_len)
        self.seed = int(seed)
        self.suite = suite or SyntheticSuite(
            num_tasks=max(self.n_tasks, 1), seed=seed)
        if self.n_tasks > self.suite.num_tasks:
            raise ValueError(f"probe pool wants {self.n_tasks} tasks but the "
                             f"suite has {self.suite.num_tasks}")
        self._tasks: List[Tuple[str, np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray]] = []
        for t in range(self.n_tasks):
            spec = self.suite.tasks[t]
            ds = self.suite.dataset(t, 1, self.n_examples, self.seq_len,
                                    split_seed=self.seed)
            toks, labels = ds["x_test"], ds["y_test"]
            # motif features are model-independent: the probe's "encoder"
            # is the suite's ground-truth Φ, so the score isolates what the
            # READOUT — a fixed slice of the base — does to the task
            feats = self.suite.phi[toks].mean(axis=1).astype(np.float32)
            rng = np.random.default_rng((self.seed, spec.seed, 11))
            m, c = self.suite.num_motifs, spec.num_classes
            idx = rng.integers(0, self.size, size=m * c)
            sign = rng.choice(np.asarray([-1.0, 1.0], np.float32), size=m * c)
            self._tasks.append((spec.name, feats, labels, idx, sign))

    # -- scoring --------------------------------------------------------
    def _flat(self, base) -> np.ndarray:
        """Accept a flat ``[N]`` row or a parameter pytree."""
        arr = base if isinstance(base, (np.ndarray, jnp.ndarray)) else None
        if arr is None or getattr(arr, "ndim", None) != 1:
            arr = FlatSpec.from_tree(base).flatten(base)
        arr = np.asarray(arr, np.float32)
        if arr.shape != (self.size,):
            raise ValueError(f"probe suite was built for flat size "
                             f"{self.size}, got base of shape {arr.shape}")
        return arr

    def score(self, base) -> Dict[str, float]:
        """Per-task probe losses of a base (flat ``[N]`` row or pytree).
        Deterministic: the same base always produces the same scores."""
        flat = self._flat(base)
        if self._evals is not None:
            return self._evals.score(flat)
        out: Dict[str, float] = {}
        for name, feats, labels, idx, sign in self._tasks:
            m = feats.shape[1]
            w = (flat[idx] * sign).reshape(m, -1)
            logits = feats @ w
            out[name] = float(cls_loss(jnp.asarray(logits),
                                       jnp.asarray(labels)))
        return out

    def accuracies(self, base) -> Dict[str, float]:
        """Per-task probe accuracies (observability only — the gate
        compares losses, which move smoothly under small drift)."""
        flat = self._flat(base)
        if self._evals is not None:
            return self._evals.accuracies(flat)
        out: Dict[str, float] = {}
        for name, feats, labels, idx, sign in self._tasks:
            m = feats.shape[1]
            w = (flat[idx] * sign).reshape(m, -1)
            out[name] = float(accuracy(jnp.asarray(feats @ w),
                                       jnp.asarray(labels)))
        return out

    # -- gate decision --------------------------------------------------
    def compare(self, baseline: Dict[str, float], scores: Dict[str, float],
                *, tolerance: float = 0.5,
                max_regressed: int = 0) -> ProbeReport:
        """Per-task tolerance comparison: a task *regressed* when its loss
        rose more than ``tolerance`` over ``baseline``; the gate is ``ok``
        while at most ``max_regressed`` tasks regressed.  Tasks absent
        from ``baseline`` (a probe-pool reconfiguration mid-run) are
        skipped rather than treated as regressions."""
        deltas = {name: scores[name] - baseline[name]
                  for name in scores if name in baseline}
        regressed = [name for name, d in deltas.items() if d > tolerance]
        return ProbeReport(
            ok=len(regressed) <= max_regressed,
            tolerance=float(tolerance),
            max_regressed=int(max_regressed),
            regressed=sorted(regressed),
            deltas=deltas,
            scores=dict(scores),
            baseline=dict(baseline),
        )


@dataclass
class RegressionGate:
    """The service's gate configuration: a probe pool plus the trip rule.
    Built by ``repro.launch.serve_repository`` from the ``--gate`` flags
    and handed to ``ColdService(gate=...)``."""

    probes: ProbeSuite
    tolerance: float = 0.5
    max_regressed: int = 0

    def check(self, baseline: Dict[str, float], base) -> ProbeReport:
        """Score ``base`` and compare against ``baseline`` under this
        gate's trip rule."""
        return self.probes.compare(baseline, self.probes.score(base),
                                   tolerance=self.tolerance,
                                   max_regressed=self.max_regressed)
