"""Fuse-to-serve hot path: zero-downtime base hot-swap for the engine.

The paper's synergistic loop (§1) only pays off when the continually
improving base is actually *served*: contributors recycle finetunes into
the repository and downstream users immediately generate against each
newly published iteration.  ``ServingWorker`` is that wiring, built as a
thin composition of the serve layer's parts (docs/serving.md):

* a ``BaseFollower`` (``serve/base_follower.py``) watches the published
  iteration and performs the double-buffered residency + atomic-flip
  swap — forward publishes and gate rollbacks alike;
* an optional ``BatchScheduler`` (``serve/scheduler.py``) coalesces
  compatible single-row requests into shared ``[B, T]`` batches in
  front of the engine (``batch_requests=True``);
* the worker itself owns the ``Engine``, executes requests against
  version-pinned ``BaseVersion`` handles, and publishes its serving
  state.

**Version-pinned requests**: ``generate`` captures the follower's
current ``BaseVersion`` once at entry and decodes every step against
it, so a request in flight across a swap completes on the base it
started on.  The same holds across a gate ``rollback``, where the
pointer moves *backwards* (the follower's target test is
``iteration != current``, not ``>``).

Observability: the worker persists its state file atomically —
``serving_state.json`` for the default solo worker, or the namespaced
``serving_state-<id>.json`` when constructed with ``worker_id=`` (one
file per pool member; the daemon owns ``service_status.json`` and
aggregates the whole namespace as the ``"serving"`` block) — and
appends ``event="swap"`` records to the shared append-only
``metrics.jsonl``.  While ``start()``ed it also heartbeats the state
file (throttled) so the router's health checks see a fresh
``updated_at`` even between swaps.

Crash discipline (docs/serving.md crash matrix): the follower's swap
path carries the three ``repro.utils.faults`` seams —
``worker.pre_transfer``, ``worker.post_transfer_pre_flip``,
``worker.post_flip``.  The worker holds no durable state the repository
does not already own; a restarted worker re-reads ``repository.json``
(written atomically, and the base npz is durable *before* the json
names it) so it can only ever load a published, uncorrupted base —
never a half-swapped one.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import io as ckpt
from repro.serve.base_follower import BaseFollower, BaseVersion
from repro.serve.cold_service import METRICS_FILE, serving_state_filename
from repro.serve.engine import Engine

__all__ = ["BaseVersion", "ServedGeneration", "ServingWorker"]


@dataclass
class ServedGeneration:
    """An Engine ``GenerationResult`` stamped with the base version that
    served it (the pinned version — not necessarily the newest) and the
    executed batch size (>1 when the scheduler coalesced the request
    with others)."""

    tokens: np.ndarray
    prompt_len: int
    steps: int
    iteration: int
    latency_s: float
    batch_size: int = 1


def _default_engine_factory(cfg, params, max_len: int) -> Engine:
    return Engine(cfg, params, max_len=max_len)


class ServingWorker:
    """Serve the repository's latest published base, hot-swapping on
    every publish/rollback with version-pinned in-flight requests.

    The two watch modes (in-process ``repo=`` listener vs cross-process
    ``root`` polling, with ``family=`` member resolution) live in
    ``BaseFollower`` — see its docstring for the snapshot-consistency
    and durability arguments.

    ``engine_factory(cfg, params, max_len)`` is pluggable so tests and
    the interleaving property suite can swap in a fake engine; the real
    ``Engine`` is built once (jit caches are keyed by shapes, so serving
    a same-shaped new tree via ``generate(params=...)`` never retraces).
    The engine is built inside the follower's ``on_resident`` hook —
    after the residency barrier, before the pointer flip — so no reader
    can observe a version the engine cannot serve.

    ``worker_id=`` namespaces the state file for pool membership
    (``serving_state-<id>.json``); the default ``None`` keeps the solo
    ``serving_state.json``.  ``batch_requests=True`` routes single-row
    ``generate`` calls through a ``BatchScheduler`` (bounded queue of
    ``queue_depth``, batches up to ``max_batch`` coalesced within
    ``batch_wait_s``) — multi-row calls and the unbatched default hit
    the engine directly.
    """

    def __init__(self, cfg, root: Optional[str], *, repo=None,
                 family: Optional[str] = None,
                 max_len: int = 256, name: str = "worker",
                 engine_factory: Optional[Callable[..., Any]] = None,
                 worker_id: Optional[str] = None,
                 batch_requests: bool = False, queue_depth: int = 64,
                 max_batch: int = 8, batch_wait_s: float = 0.002):
        self.cfg = cfg
        self.max_len = int(max_len)
        self.name = str(name)
        self.worker_id = None if worker_id is None else str(worker_id)
        self._engine_factory = engine_factory or _default_engine_factory
        self._engine: Optional[Any] = None
        self._stats_lock = threading.Lock()
        self.requests_total = 0
        self.requests_pinned_across_swaps = 0
        self.requests_batched = 0      # served as part of a coalesced batch
        self._inflight = 0             # the router's load signal
        self._follower = BaseFollower(
            root, repo=repo, family=family, name=self.name,
            on_swap_begin=self._on_swap_begin,
            on_resident=self._on_resident,
            on_swap=self._on_swap)
        self.root = self._follower.root
        self.family = self._follower.family
        self._scheduler = None
        if batch_requests:
            from repro.serve.scheduler import BatchScheduler
            self._scheduler = BatchScheduler(
                self._execute_batch, queue_depth=queue_depth,
                max_batch=max_batch, max_wait_s=batch_wait_s,
                name=self.name)
            self._scheduler.start()
        self._last_persist = 0.0
        # merged into serve_state() last: a host process (e.g. the pool
        # child) advertises transport details — port, endpoint id — to
        # state-file readers like the router's health checks
        self.extra_state: Dict[str, Any] = {}

    # -- follower hooks --------------------------------------------------
    def _on_swap_begin(self, iteration: int) -> None:
        # entering a live swap: persist the `swapping` flag so a router
        # polling the state file can drain this worker mid-swap
        self._persist_state()

    def _on_resident(self, version: BaseVersion) -> None:
        if self._engine is None:
            self._engine = self._engine_factory(
                self.cfg, version.params, self.max_len)

    def _on_swap(self, record: Dict[str, Any], version: BaseVersion,
                 prev: Optional[BaseVersion]) -> None:
        self._persist_state()
        with self._stats_lock:
            requests_total = self.requests_total
            pinned = self.requests_pinned_across_swaps
        # plain append — rotation is the daemon's job (single rotator;
        # concurrent pool workers only ever O_APPEND here)
        ckpt.append_jsonl(os.path.join(self.root, METRICS_FILE), {
            "t": time.time(), "event": "swap", "worker": self.name,
            **record,
            "versions_served": len(self._follower.versions_served),
            "requests_total": requests_total,
            "requests_pinned_across_swaps": pinned,
        })

    # -- follower delegation ---------------------------------------------
    def attach(self, repo) -> None:
        self._follower.attach(repo)

    def poll_once(self) -> bool:
        return self._follower.poll_once()

    def current(self) -> Optional[BaseVersion]:
        return self._follower.current()

    @property
    def current_iteration(self) -> Optional[int]:
        return self._follower.current_iteration

    @property
    def swapping(self) -> bool:
        return self._follower.swapping

    @property
    def swaps_total(self) -> int:
        return self._follower.swaps_total

    @property
    def live_swaps(self) -> int:
        return self._follower.live_swaps

    @property
    def versions_served(self):
        return self._follower.versions_served

    @property
    def last_swap(self) -> Optional[Dict[str, Any]]:
        return self._follower.last_swap

    @property
    def last_swap_latency_s(self) -> Optional[float]:
        return self._follower.last_swap_latency_s

    @property
    def watch_error(self) -> Optional[str]:
        return self._follower.watch_error

    @property
    def _swap_log(self) -> List[int]:
        return self._follower._swap_log

    # -- serving --------------------------------------------------------
    def _execute_batch(self, prompts: np.ndarray, max_new_tokens: int,
                       version: BaseVersion):
        """The scheduler's executor: one batched engine call against the
        batch's pinned version."""
        return self._engine.generate(prompts, max_new_tokens=max_new_tokens,
                                     params=version.params)

    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 16,
                 deadline_s: Optional[float] = None) -> ServedGeneration:
        """Version-pinned generation: the base version is captured ONCE
        here, and every decode step runs against it — a swap (forward or
        rollback) mid-request cannot tear the output across versions.

        With batching enabled, single-row prompts are handed to the
        scheduler (which may coalesce them with other requests pinned to
        the SAME version); the pinned-version contract is identical.
        ``deadline_s`` (wall seconds from now) only applies on the
        scheduler path; a request that cannot start executing in time
        fails with ``RequestRejected("deadline")``."""
        version = self._follower.current()
        if version is None:
            raise RuntimeError(
                "ServingWorker has no base resident yet — call poll_once() "
                "(or start()) after the repository published")
        t0 = time.perf_counter()
        with self._stats_lock:
            self._inflight += 1
        try:
            batched = (self._scheduler is not None
                       and prompts.ndim == 2 and prompts.shape[0] == 1)
            if batched:
                ticket = self._scheduler.submit(
                    prompts[0], max_new_tokens=max_new_tokens,
                    version=version, deadline_s=deadline_s)
                out = ticket.result()
                tokens = out.tokens[None, :]
                steps, prompt_len = out.steps, int(prompts.shape[1])
                batch_size = out.batch_size
            else:
                res = self._engine.generate(
                    prompts, max_new_tokens=max_new_tokens,
                    params=version.params)
                tokens, steps = res.tokens, res.steps
                prompt_len, batch_size = res.prompt_len, 1
        finally:
            with self._stats_lock:
                self._inflight -= 1
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.requests_total += 1
            if batched and batch_size > 1:
                self.requests_batched += 1
            if self._follower.current() is not version:
                self.requests_pinned_across_swaps += 1
        return ServedGeneration(tokens=tokens, prompt_len=prompt_len,
                                steps=steps, iteration=version.iteration,
                                latency_s=dt, batch_size=batch_size)

    # -- observability --------------------------------------------------
    def serve_state(self) -> Dict[str, Any]:
        """The serving-state payload (``serving_state.json`` or the
        pool-namespaced ``serving_state-<id>.json``; aggregated by the
        daemon's status endpoint as the ``"serving"`` block)."""
        st = self._follower.swap_stats()
        with self._stats_lock:
            st.update({
                "worker": self.name,
                "worker_id": self.worker_id,
                "family": self.family,
                "requests_total": self.requests_total,
                "requests_pinned_across_swaps":
                    self.requests_pinned_across_swaps,
                "requests_batched": self.requests_batched,
                "inflight": self._inflight,
                "pid": os.getpid(),
                "updated_at": time.time(),
            })
        if self._scheduler is not None:
            st["scheduler"] = self._scheduler.stats()
        st.update(self.extra_state)
        return st

    @property
    def state_path(self) -> str:
        return os.path.join(self.root,
                            serving_state_filename(self.worker_id))

    def _persist_state(self) -> None:
        ckpt.save_json_atomic(self.state_path, self.serve_state())
        self._last_persist = time.monotonic()

    def _tick(self) -> None:
        # heartbeat between swaps (throttled): routers health-check the
        # state file's updated_at to tell a live-but-idle worker from a
        # dead one
        if time.monotonic() - self._last_persist >= 0.25:
            self._persist_state()

    # -- watch thread ---------------------------------------------------
    def start(self, *, interval: float = 0.05) -> None:
        """Run the follower's watch loop on a daemon thread: poll/receive
        publishes and hot-swap until ``stop``.  Swap errors are recorded
        (and the current version keeps serving) rather than killing the
        loop."""
        self._follower.start(interval=interval, on_tick=self._tick)

    def stop(self) -> Dict[str, Any]:
        """Stop the watch thread (and scheduler) and persist a final
        serving state."""
        self._follower.stop()
        if self._scheduler is not None:
            self._scheduler.stop()
        self._persist_state()
        return self.serve_state()
