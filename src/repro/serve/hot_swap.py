"""Fuse-to-serve hot path: zero-downtime base hot-swap for the engine.

The paper's synergistic loop (§1) only pays off when the continually
improving base is actually *served*: contributors recycle finetunes into
the repository and downstream users immediately generate against each
newly published iteration.  ``ServingWorker`` is that wiring — it watches
the repository's published iteration and swaps the engine onto every new
base with zero downtime:

* **double-buffered weights on device** — the next base is materialized
  (in-process: adopted as the repository's own ``FlatSpec.unflatten``
  device views; cross-process: per-leaf npz load) and made resident with
  ``jax.block_until_ready`` while in-flight requests keep decoding
  against the current tree.  No host-side dense ``[N]`` copy happens on
  the swap path: the flat base was already unflattened straight into the
  param tree by jitted slicing (``repro.utils.flat``), and the worker
  adopts that tree by reference.
* **atomic iteration pointer** — ``_current`` is a single Python
  reference, flipped only AFTER the new tree is resident; readers either
  see the old complete version or the new complete version, never a mix.
* **version-pinned requests** — ``generate`` captures the current
  ``BaseVersion`` once at entry and decodes every step against it, so a
  request in flight across a swap completes on the base it started on.
  The same holds across a gate ``rollback``, where the pointer moves
  *backwards* (the target test is ``iteration != current``, not ``>``).

Observability: the worker persists ``serving_state.json`` atomically
(its own file — the daemon owns ``service_status.json`` and embeds this
one as the ``"serving"`` block) and appends ``event="swap"`` records to
the shared append-only ``metrics.jsonl``.

Crash discipline (docs/serving.md crash matrix): the swap path carries
three ``repro.utils.faults`` seams — ``worker.pre_transfer``,
``worker.post_transfer_pre_flip``, ``worker.post_flip``.  The worker
holds no durable state the repository does not already own; a restarted
worker re-reads ``repository.json`` (written atomically, and the base
npz is durable *before* the json names it) so it can only ever load a
published, uncorrupted base — never a half-swapped one.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.core.repository import family_member_root
from repro.serve.cold_service import METRICS_FILE, SERVING_STATE_FILE
from repro.serve.engine import Engine
from repro.utils import faults

# module-level so the atomicity tests can spy on the residency barrier
# (asserting it runs BEFORE the pointer flip)
_block_until_ready = jax.block_until_ready


class BaseVersion:
    """One published base resident on device: the unit the pointer flips
    between and the object a request pins at ``generate`` entry."""

    __slots__ = ("iteration", "params")

    def __init__(self, iteration: int, params: Any):
        self.iteration = int(iteration)
        self.params = params


@dataclass
class ServedGeneration:
    """An Engine ``GenerationResult`` stamped with the base version that
    served it (the pinned version — not necessarily the newest)."""

    tokens: np.ndarray
    prompt_len: int
    steps: int
    iteration: int
    latency_s: float


def _default_engine_factory(cfg, params, max_len: int) -> Engine:
    return Engine(cfg, params, max_len=max_len)


class ServingWorker:
    """Serve the repository's latest published base, hot-swapping on
    every publish/rollback with version-pinned in-flight requests.

    Two watch modes share one swap path:

    * **in-process** (``repo=``): subscribes via
      ``Repository.add_publish_listener`` — the listener stores a
      consistent ``(iteration, base, flat)`` snapshot taken *after* the
      iteration bump, and the worker's own thread performs the swap.
      (Raw polling of ``repo.iteration``/``repo._base`` from another
      thread can pair iteration ``k`` with ``k+1``'s weights, because the
      repository installs the base before bumping the counter.)
    * **cross-process** (``root`` only): polls ``repository.json`` (an
      atomic write) and loads ``base_iterNNNN.npz`` per leaf — durable
      before the json names it, so the worker can never race into a
      missing or torn base.  Pass ``family="f1"`` to follow a named
      member of a multi-base family: the worker resolves that member's
      root (a full repository layout of its own) and everything else —
      polling, swap, rollback handling — is identical.

    ``engine_factory(cfg, params, max_len)`` is pluggable so tests and
    the interleaving property suite can swap in a fake engine; the real
    ``Engine`` is built once (jit caches are keyed by shapes, so serving
    a same-shaped new tree via ``generate(params=...)`` never retraces).
    """

    def __init__(self, cfg, root: Optional[str], *, repo=None,
                 family: Optional[str] = None,
                 max_len: int = 256, name: str = "worker",
                 engine_factory: Optional[Callable[..., Any]] = None):
        if root is None and repo is None:
            raise ValueError("ServingWorker needs a repository root, an "
                             "attached Repository, or both")
        if family is not None and repo is not None:
            raise ValueError(
                "family= selects a member under a family root in "
                "cross-process watch mode; when attaching in-process, pass "
                "that member's Repository directly as repo=")
        self.family = None if family is None else str(family)
        if self.family is not None:
            # a member root is a full repository layout, so the whole
            # watch/swap path below works against it unchanged
            root = family_member_root(root, self.family)
        self.cfg = cfg
        self.root = root if root is not None else repo.root
        self.max_len = int(max_len)
        self.name = str(name)
        self._engine_factory = engine_factory or _default_engine_factory
        self._engine: Optional[Any] = None
        self._current: Optional[BaseVersion] = None
        self._announce: Optional[Tuple[int, Any, Any]] = None
        self._repo = None
        self._swap_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.swaps_total = 0           # pointer flips, incl. initial adoption
        self.live_swaps = 0            # flips while already serving a base
        self.requests_total = 0
        self.requests_pinned_across_swaps = 0
        self.versions_served: Set[int] = set()
        self.last_swap_latency_s: Optional[float] = None
        self.last_swap: Optional[Dict[str, Any]] = None
        self._swap_log: List[int] = []  # flip order, for the property suite
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self.watch_error: Optional[str] = None
        if repo is not None:
            self.attach(repo)

    # -- watch sources --------------------------------------------------
    def attach(self, repo) -> None:
        """Subscribe to an in-process Repository's publishes (and take an
        initial snapshot of whatever it currently serves)."""
        self._repo = repo
        repo.add_publish_listener(self._on_publish)
        self._announce = (repo.iteration, repo._base, repo._base_flat)

    def _on_publish(self, iteration: int, base, flat) -> None:
        # publisher's thread: store-only (one tuple assignment is atomic
        # under the GIL); the worker thread does the transfer + flip
        self._announce = (iteration, base, flat)

    def _target(self) -> Optional[Tuple[int, Any]]:
        """The published version to swap to, or None when current."""
        cur = self._current
        if self._repo is not None:
            ann = self._announce
            if ann is None:
                return None
            it, base, _flat = ann
            if cur is not None and cur.iteration == int(it):
                return None
            return int(it), base
        try:
            meta = ckpt.load_json(os.path.join(self.root, "repository.json"))
        except FileNotFoundError:
            return None
        it = int(meta["iteration"])
        if cur is not None and cur.iteration == it:
            return None
        return it, None

    # -- the swap -------------------------------------------------------
    def poll_once(self) -> bool:
        """Check for a newer (or rolled-back: *different*) published base
        and hot-swap onto it.  Returns True when a swap happened."""
        with self._swap_lock:
            target = self._target()
            if target is None:
                return False
            self._swap_to(*target)
            return True

    def _swap_to(self, iteration: int, base) -> None:
        t0 = time.perf_counter()
        faults.crash_point("worker.pre_transfer")
        if base is None:
            path = os.path.join(self.root, f"base_iter{iteration:04d}.npz")
            base = ckpt.load(path)
        # residency barrier: the new tree (lazy unflatten views in-process,
        # fresh transfers cross-process) must be fully materialized on
        # device BEFORE the flip — in-flight requests keep decoding against
        # the current version the whole time (double-buffered weights)
        _block_until_ready(base)
        if self._engine is None:
            self._engine = self._engine_factory(self.cfg, base, self.max_len)
        faults.crash_point("worker.post_transfer_pre_flip")
        prev = self._current
        self._current = BaseVersion(iteration, base)   # the atomic flip
        faults.crash_point("worker.post_flip")
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.swaps_total += 1
            if prev is not None:
                self.live_swaps += 1
            self.versions_served.add(iteration)
            self.last_swap_latency_s = dt
            self.last_swap = {
                "from_iteration": None if prev is None else prev.iteration,
                "to_iteration": iteration,
                "swap_latency_s": dt,
            }
            self._swap_log.append(iteration)
        self._persist_state()
        ckpt.append_jsonl(os.path.join(self.root, METRICS_FILE), {
            "t": time.time(), "event": "swap", "worker": self.name,
            **self.last_swap,
            "versions_served": len(self.versions_served),
            "requests_total": self.requests_total,
            "requests_pinned_across_swaps": self.requests_pinned_across_swaps,
        })

    # -- serving --------------------------------------------------------
    @property
    def current_iteration(self) -> Optional[int]:
        cur = self._current
        return None if cur is None else cur.iteration

    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 16
                 ) -> ServedGeneration:
        """Version-pinned generation: the base version is captured ONCE
        here, and every decode step runs against it — a swap (forward or
        rollback) mid-request cannot tear the output across versions."""
        version = self._current
        if version is None:
            raise RuntimeError(
                "ServingWorker has no base resident yet — call poll_once() "
                "(or start()) after the repository published")
        t0 = time.perf_counter()
        res = self._engine.generate(prompts, max_new_tokens=max_new_tokens,
                                    params=version.params)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.requests_total += 1
            if self._current is not version:
                self.requests_pinned_across_swaps += 1
        return ServedGeneration(tokens=res.tokens, prompt_len=res.prompt_len,
                                steps=res.steps, iteration=version.iteration,
                                latency_s=dt)

    # -- observability --------------------------------------------------
    def serve_state(self) -> Dict[str, Any]:
        """The ``serving_state.json`` payload (also embedded by the
        daemon's status endpoint as the ``"serving"`` block)."""
        with self._stats_lock:
            return {
                "worker": self.name,
                "family": self.family,
                "iteration": self.current_iteration,
                "swaps_total": self.swaps_total,
                "live_swaps": self.live_swaps,
                "versions_served": sorted(self.versions_served),
                "last_swap": (None if self.last_swap is None
                              else dict(self.last_swap)),
                "last_swap_latency_s": self.last_swap_latency_s,
                "requests_total": self.requests_total,
                "requests_pinned_across_swaps":
                    self.requests_pinned_across_swaps,
                "watch_error": self.watch_error,
                "pid": os.getpid(),
                "updated_at": time.time(),
            }

    def _persist_state(self) -> None:
        ckpt.save_json_atomic(
            os.path.join(self.root, SERVING_STATE_FILE), self.serve_state())

    # -- watch thread ---------------------------------------------------
    def start(self, *, interval: float = 0.05) -> None:
        """Run the watch loop on a daemon thread: poll/receive publishes
        and hot-swap until ``stop``.  Swap errors are recorded (and the
        current version keeps serving) rather than killing the loop."""
        if self._thread is not None:
            raise RuntimeError("worker already started")
        self._stop_evt.clear()

        def loop():
            while not self._stop_evt.is_set():
                try:
                    self.poll_once()
                except Exception as err:  # noqa: BLE001 - keep serving
                    self.watch_error = f"{type(err).__name__}: {err}"
                self._stop_evt.wait(interval)

        self._thread = threading.Thread(
            target=loop, name=f"serving-{self.name}", daemon=True)
        self._thread.start()

    def stop(self) -> Dict[str, Any]:
        """Stop the watch thread and persist a final serving state."""
        if self._thread is not None:
            self._stop_evt.set()
            self._thread.join(timeout=30.0)
            self._thread = None
        self._persist_state()
        return self.serve_state()
