"""BaseFollower: reusable publish-watching with double-buffered residency.

The PR 8 ``ServingWorker`` fused four responsibilities into one class:
following the repository's published iteration, owning the engine,
executing requests, and publishing serving state.  The first of those —
the *swap machinery* — is the piece everything in the serving stack
needs (workers, probers, benches, health checkers), so it lives here on
its own:

* **two watch modes, one swap path** — in-process (``repo=``) the
  follower subscribes via ``Repository.add_publish_listener`` and
  receives a consistent ``(iteration, base, flat)`` snapshot taken
  *after* the iteration bump (raw cross-thread polling can pair
  iteration ``k`` with ``k+1``'s weights); cross-process (``root``) it
  polls ``repository.json`` — an atomic write, and the base npz is
  durable *before* the json names it, so a reader can never load a
  missing or torn base.  ``family=`` resolves a named family member's
  root (a full repository layout) and everything else is identical.
* **double-buffered residency** — the next base is materialized and made
  resident (``jax.block_until_ready``) while readers keep using the
  current version; only then does the pointer flip.  The flip is a
  single Python reference assignment: a reader sees the old complete
  version or the new complete version, never a mix.
* **version-pinned handles** — ``current()`` returns the ``BaseVersion``
  the pointer names *now*; a consumer that captures it once works
  against those exact weights for as long as it holds the handle,
  across any number of forward or backward (rollback) swaps.

Crash discipline: the swap path carries the three ``repro.utils.faults``
seams the docs/serving.md crash matrix kills at —
``worker.pre_transfer``, ``worker.post_transfer_pre_flip``,
``worker.post_flip``.  The follower holds no durable state the
repository does not already own, so a crashed follower can only ever
re-adopt a published, uncorrupted base.

Hooks (all optional) let a composer attach behavior at the exact seams
the old monolith hard-coded:

* ``on_swap_begin(target_iteration)`` — entering a *live* swap (a
  current version is already being served); the hot-swap worker uses it
  to mark itself ``swapping`` so a router can drain it;
* ``on_resident(version)`` — the new tree is resident but the pointer
  has NOT flipped; the worker builds/validates its engine here so no
  reader can observe a version the engine cannot serve;
* ``on_swap(record, version, prev)`` — the pointer flipped; the worker
  persists serving state and appends the metrics swap record.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax

from repro.checkpoint import io as ckpt
from repro.core.repository import family_member_root
from repro.utils import faults

# module-level so the atomicity tests can spy on the residency barrier
# (asserting it runs BEFORE the pointer flip)
_block_until_ready = jax.block_until_ready


class BaseVersion:
    """One published base resident on device: the unit the pointer flips
    between and the object a request pins at ``generate`` entry."""

    __slots__ = ("iteration", "params")

    def __init__(self, iteration: int, params: Any):
        self.iteration = int(iteration)
        self.params = params


def _default_loader(root: str, iteration: int):
    """Cross-process materialization: per-leaf load of the published npz
    (durable before ``repository.json`` named it)."""
    return ckpt.load(os.path.join(root, f"base_iter{iteration:04d}.npz"))


class BaseFollower:
    """Follow a repository's published base with atomic hot-swaps.

    ``poll_once()`` checks for a *different* published iteration (a gate
    rollback moves the pointer backwards — the target test is ``!=``,
    never ``>``) and swaps onto it: materialize, residency barrier,
    flip.  ``current()`` hands out the version-pinned handle.

    ``loader(root, iteration)`` overrides cross-process materialization
    (tests substitute cheap fakes); in-process the announced snapshot's
    own device views are adopted by reference — no host round trip.
    """

    def __init__(self, root: Optional[str] = None, *, repo=None,
                 family: Optional[str] = None,
                 loader: Optional[Callable[[str, int], Any]] = None,
                 on_swap_begin: Optional[Callable[[int], None]] = None,
                 on_resident: Optional[Callable[[BaseVersion], None]] = None,
                 on_swap: Optional[Callable[..., None]] = None,
                 name: str = "follower"):
        if root is None and repo is None:
            raise ValueError("BaseFollower needs a repository root, an "
                             "attached Repository, or both")
        if family is not None and repo is not None:
            raise ValueError(
                "family= selects a member under a family root in "
                "cross-process watch mode; when attaching in-process, pass "
                "that member's Repository directly as repo=")
        self.family = None if family is None else str(family)
        if self.family is not None:
            # a member root is a full repository layout, so the whole
            # watch/swap path below works against it unchanged
            root = family_member_root(root, self.family)
        self.root = root if root is not None else repo.root
        self.name = str(name)
        self._loader = loader or _default_loader
        self._on_swap_begin = on_swap_begin
        self._on_resident = on_resident
        self._on_swap = on_swap
        self._current: Optional[BaseVersion] = None
        self._announce: Optional[Tuple[int, Any, Any]] = None
        self._repo = None
        self._swap_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.swapping = False          # inside a live swap (drain signal)
        self.swaps_total = 0           # pointer flips, incl. initial adoption
        self.live_swaps = 0            # flips while already serving a base
        self.versions_served: Set[int] = set()
        self.last_swap_latency_s: Optional[float] = None
        self.last_swap: Optional[Dict[str, Any]] = None
        self._swap_log: List[int] = []  # flip order, for the property suite
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self.watch_error: Optional[str] = None
        if repo is not None:
            self.attach(repo)

    # -- watch sources --------------------------------------------------
    def attach(self, repo) -> None:
        """Subscribe to an in-process Repository's publishes (and take an
        initial snapshot of whatever it currently serves)."""
        self._repo = repo
        repo.add_publish_listener(self._on_publish)
        self._announce = (repo.iteration, repo._base, repo._base_flat)

    def _on_publish(self, iteration: int, base, flat) -> None:
        # publisher's thread: store-only (one tuple assignment is atomic
        # under the GIL); the follower's thread does the transfer + flip
        self._announce = (iteration, base, flat)

    def _target(self) -> Optional[Tuple[int, Any]]:
        """The published version to swap to, or None when current."""
        cur = self._current
        if self._repo is not None:
            ann = self._announce
            if ann is None:
                return None
            it, base, _flat = ann
            if cur is not None and cur.iteration == int(it):
                return None
            return int(it), base
        try:
            meta = ckpt.load_json(os.path.join(self.root, "repository.json"))
        except FileNotFoundError:
            return None
        it = int(meta["iteration"])
        if cur is not None and cur.iteration == it:
            return None
        return it, None

    # -- the swap -------------------------------------------------------
    def poll_once(self) -> bool:
        """Check for a newer (or rolled-back: *different*) published base
        and hot-swap onto it.  Returns True when a swap happened."""
        with self._swap_lock:
            target = self._target()
            if target is None:
                return False
            self._swap_to(*target)
            return True

    def _swap_to(self, iteration: int, base) -> None:
        t0 = time.perf_counter()
        live = self._current is not None
        try:
            if live:
                # a live swap is drainable: routers deprioritize a worker
                # whose begin-hook marked it swapping.  Initial adoption
                # skips the hook — there is nothing to drain yet, and a
                # begin-persist would overwrite a pre-crash worker's state
                # with an empty one (the crash matrix pins this).
                self.swapping = True
                if self._on_swap_begin is not None:
                    self._on_swap_begin(iteration)
            faults.crash_point("worker.pre_transfer")
            if base is None:
                base = self._loader(self.root, iteration)
            # residency barrier: the new tree (lazy unflatten views
            # in-process, fresh transfers cross-process) must be fully
            # materialized on device BEFORE the flip — in-flight readers
            # keep decoding against the current version the whole time
            # (double-buffered weights)
            _block_until_ready(base)
            version = BaseVersion(iteration, base)
            if self._on_resident is not None:
                self._on_resident(version)
            faults.crash_point("worker.post_transfer_pre_flip")
            prev = self._current
            self._current = version   # the atomic flip
        finally:
            self.swapping = False
        faults.crash_point("worker.post_flip")
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.swaps_total += 1
            if prev is not None:
                self.live_swaps += 1
            self.versions_served.add(iteration)
            self.last_swap_latency_s = dt
            self.last_swap = {
                "from_iteration": None if prev is None else prev.iteration,
                "to_iteration": iteration,
                "swap_latency_s": dt,
            }
            self._swap_log.append(iteration)
        if self._on_swap is not None:
            self._on_swap(dict(self.last_swap), version, prev)

    # -- handles --------------------------------------------------------
    def current(self) -> Optional[BaseVersion]:
        """The version-pinned handle: capture once, decode every step
        against it — a swap mid-request cannot tear the output."""
        return self._current

    @property
    def current_iteration(self) -> Optional[int]:
        cur = self._current
        return None if cur is None else cur.iteration

    def swap_stats(self) -> Dict[str, Any]:
        """The follower's slice of serving state (merged by composers)."""
        with self._stats_lock:
            return {
                "iteration": self.current_iteration,
                "swapping": self.swapping,
                "swaps_total": self.swaps_total,
                "live_swaps": self.live_swaps,
                "versions_served": sorted(self.versions_served),
                "last_swap": (None if self.last_swap is None
                              else dict(self.last_swap)),
                "last_swap_latency_s": self.last_swap_latency_s,
                "watch_error": self.watch_error,
            }

    # -- watch thread ---------------------------------------------------
    def start(self, *, interval: float = 0.05,
              on_tick: Optional[Callable[[], None]] = None) -> None:
        """Run the watch loop on a daemon thread: poll/receive publishes
        and hot-swap until ``stop``.  Swap errors are recorded (and the
        current version keeps serving) rather than killing the loop.
        ``on_tick`` runs once per loop iteration after the poll — the
        worker hangs its state heartbeat there."""
        if self._thread is not None:
            raise RuntimeError("follower already started")
        self._stop_evt.clear()

        def loop():
            while not self._stop_evt.is_set():
                try:
                    self.poll_once()
                except Exception as err:  # noqa: BLE001 - keep serving
                    self.watch_error = f"{type(err).__name__}: {err}"
                if on_tick is not None:
                    try:
                        on_tick()
                    except Exception as err:  # noqa: BLE001
                        self.watch_error = f"{type(err).__name__}: {err}"
                self._stop_evt.wait(interval)

        self._thread = threading.Thread(
            target=loop, name=f"follow-{self.name}", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop_evt.set()
            self._thread.join(timeout=30.0)
            self._thread = None
