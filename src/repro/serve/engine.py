"""Minimal batched serving engine: prefill the prompt into a KV/state cache,
then greedy-decode one token per step via ``serve_step``.

This is the host-side driver behind the decode input shapes; the examples
use it end-to-end on reduced configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import forward_lm, init_cache
from repro.train.step import make_serve_step


@dataclass
class GenerationResult:
    tokens: np.ndarray        # [B, prompt + generated]
    prompt_len: int
    steps: int


class Engine:
    """Greedy batched generation for the decoder-LM families."""

    def __init__(self, cfg: ArchConfig, params, *, max_len: int = 256):
        if cfg.is_encoder_decoder:
            raise ValueError("Engine drives decoder-only archs; use whisper_decode directly")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._serve = jax.jit(make_serve_step(cfg))

        def prefill(params, tokens, cache):
            logits, _, cache = forward_lm(cfg, params, tokens, cache=cache,
                                          cache_index=jnp.asarray(0, jnp.int32))
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)

    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 16,
                 params=None) -> GenerationResult:
        """prompts: [B, P] int32 (fixed-length, packed by the caller).

        ``params=`` serves this one request against a different (same-
        shaped) parameter tree without retracing — the jitted prefill and
        serve_step close over ``cfg`` only, so the hot-swap worker can pin
        an in-flight request to the base version it started on while the
        engine's default tree moves (docs/serving.md)."""
        params = self.params if params is None else params
        B, P = prompts.shape
        if P + max_new_tokens > self.max_len:
            # a real error, not an assert: asserts vanish under -O and a
            # cache overrun would silently wrap the decode index instead
            raise ValueError(
                f"prompt_len={P} + max_new_tokens={max_new_tokens} exceeds "
                f"max_len={self.max_len}; re-build the Engine with a larger "
                "max_len or shorten the request")
        cache = init_cache(self.cfg, B, self.max_len)
        logits, cache = self._prefill(params, jnp.asarray(prompts), cache)
        out = [jnp.argmax(logits, axis=-1)]
        for t in range(1, max_new_tokens):
            tok = out[-1][:, None]
            logits, cache = self._serve(params, cache, tok,
                                        jnp.asarray(P + t - 1, jnp.int32))
            out.append(jnp.argmax(logits, axis=-1))
        gen = np.stack([np.asarray(o) for o in out], axis=1)
        return GenerationResult(
            tokens=np.concatenate([prompts, gen], axis=1), prompt_len=P,
            steps=max_new_tokens,
        )
