"""BatchScheduler: a bounded request queue coalescing compatible requests.

One serving engine call has a large fixed cost (python dispatch, jit
cache lookup, device launch) that is nearly independent of the batch
dimension at serving scales — so under concurrent load, N single-row
``generate`` calls leave most of the throughput on the table.  The
scheduler sits in front of a batched ``Engine.generate`` and coalesces
compatible waiting requests into one ``[B, T]`` call:

* **compatibility** — two requests may share a batch only when they have
  the same prompt length ``T`` (a causal LM's prompt cannot be padded:
  pad tokens change the logits of every later position), the same
  ``max_new_tokens`` (one decode loop per call), and the same pinned
  ``BaseVersion`` (version pinning is per request; coalescing across a
  swap boundary would tear the batch).  Batch shapes are quantized to a
  small **bucket set** (pad ``B`` up by repeating rows, slice outputs
  back out) so the jit cache holds a handful of entries and stays warm.
* **bounded queue, explicit shedding** — at ``queue_depth`` waiting
  requests, ``submit`` fails fast with ``RequestRejected("queue_full")``
  instead of letting latency collapse; a request whose ``deadline_s``
  passes before execution starts fails with
  ``RequestRejected("deadline")``.
* **fairness** — strict FIFO head discipline: every batch is built
  around the OLDEST waiting request, and only requests compatible with
  that head may join it (up to ``max_wait_s`` of extra coalescing
  delay).  A stream of popular-shaped requests can never starve an
  odd-shaped head; mixed request sizes interleave in arrival order.

The executor callback runs on the scheduler's own thread:
``execute(prompts[B, T], max_new_tokens, version)`` returning an object
with ``.tokens [B, T+new]`` and ``.steps`` (the ``Engine`` result shape)
— the ``ServingWorker`` binds its engine here with the batch's pinned
``version.params``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["BatchScheduler", "RequestRejected", "SchedResult",
           "batch_bucket"]

BATCH_BUCKETS = (1, 2, 4, 8)


class RequestRejected(RuntimeError):
    """A request the scheduler refused to execute — ``reason`` is
    machine-readable: ``queue_full`` (bounded-queue shedding),
    ``deadline`` (expired before execution started), ``stopped``."""

    def __init__(self, reason: str, detail: str = ""):
        self.reason = str(reason)
        super().__init__(f"request rejected: {reason}"
                         + (f" ({detail})" if detail else ""))


def batch_bucket(n: int, buckets: Tuple[int, ...] = BATCH_BUCKETS) -> int:
    """The executed batch size for ``n`` coalesced requests: the smallest
    bucket >= n (``n`` itself when it exceeds every bucket — a cold jit
    entry is better than refusing the batch)."""
    for b in buckets:
        if b >= n:
            return b
    return n


@dataclass
class SchedResult:
    """One request's slice of a batched engine call."""

    tokens: np.ndarray          # [T + steps] — this request's row
    steps: int
    batch_size: int             # executed [B] (bucketed), not the raw count
    coalesced: int              # real requests that shared the call
    queued_s: float             # submit -> execution start


@dataclass
class _Request:
    prompt: np.ndarray
    max_new_tokens: int
    version: Any
    deadline: Optional[float]   # absolute monotonic, None = never
    submitted: float
    done: threading.Event = field(default_factory=threading.Event)
    result: Optional[SchedResult] = None
    error: Optional[BaseException] = None

    def key(self) -> Tuple[int, int, int]:
        # id(version): same PIN means same BaseVersion object — iteration
        # equality is not enough (a re-adopted iteration after rollback is
        # a different resident tree)
        return (int(self.prompt.shape[0]), self.max_new_tokens,
                id(self.version))


class Ticket:
    """The caller's handle on a submitted request."""

    def __init__(self, req: _Request):
        self._req = req

    def result(self, timeout: Optional[float] = None) -> SchedResult:
        """Block until the request executed; raises the executor's error
        or ``RequestRejected`` verbatim."""
        if not self._req.done.wait(timeout):
            raise TimeoutError("scheduler request still pending")
        if self._req.error is not None:
            raise self._req.error
        return self._req.result


class BatchScheduler:
    """Bounded FIFO queue + coalescing loop in front of a batched engine.

    ``submit`` is thread-safe and non-blocking (reject-fast); the single
    scheduler thread forms and executes batches.  ``stats()`` is the
    observability slice the worker embeds in its serving state.
    """

    def __init__(self, execute: Callable[[np.ndarray, int, Any], Any], *,
                 queue_depth: int = 64, max_batch: int = 8,
                 buckets: Tuple[int, ...] = BATCH_BUCKETS,
                 max_wait_s: float = 0.002, name: str = "sched"):
        if queue_depth < 1 or max_batch < 1:
            raise ValueError("queue_depth and max_batch must be >= 1")
        self._execute = execute
        self.queue_depth = int(queue_depth)
        self.max_batch = int(max_batch)
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.max_wait_s = float(max_wait_s)
        self.name = str(name)
        self._queue: deque[_Request] = deque()
        self._cond = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        # counters (under _cond): exposed via stats()
        self._submitted = 0
        self._completed = 0
        self._rejected_queue_full = 0
        self._rejected_deadline = 0
        self._batches = 0
        self._coalesced_requests = 0   # requests served in a batch of >1
        self._max_queue_seen = 0

    # -- caller side -----------------------------------------------------
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int,
               version: Any, deadline_s: Optional[float] = None) -> Ticket:
        """Enqueue one single-row request (``prompt`` is ``[T]``).
        Rejects fast with ``queue_full`` at the depth bound — explicit
        shedding beats queueing into latency collapse."""
        prompt = np.asarray(prompt)
        if prompt.ndim != 1:
            raise ValueError(f"submit takes one [T] prompt row, got shape "
                             f"{prompt.shape}")
        now = time.monotonic()
        req = _Request(prompt=prompt, max_new_tokens=int(max_new_tokens),
                       version=version,
                       deadline=None if deadline_s is None
                       else now + float(deadline_s),
                       submitted=now)
        with self._cond:
            if self._stopping:
                raise RequestRejected("stopped", self.name)
            if len(self._queue) >= self.queue_depth:
                self._rejected_queue_full += 1
                raise RequestRejected(
                    "queue_full", f"{len(self._queue)}/{self.queue_depth}")
            self._submitted += 1
            self._queue.append(req)
            self._max_queue_seen = max(self._max_queue_seen,
                                       len(self._queue))
            self._cond.notify()
        return Ticket(req)

    # -- scheduler side --------------------------------------------------
    def _reject(self, req: _Request, reason: str) -> None:
        req.error = RequestRejected(reason)
        req.done.set()

    def _take_batch(self) -> Optional[List[_Request]]:
        """Pop the FIFO head and coalesce compatible followers (waiting up
        to ``max_wait_s`` for more), dropping expired requests."""
        with self._cond:
            while True:
                now = time.monotonic()
                # shed expired requests wherever they sit — an expired
                # head must not anchor (and delay) a batch
                alive = deque()
                for r in self._queue:
                    if r.deadline is not None and r.deadline <= now:
                        self._rejected_deadline += 1
                        self._reject(r, "deadline")
                    else:
                        alive.append(r)
                self._queue = alive
                if self._queue:
                    break
                if self._stopping:
                    return None
                self._cond.wait(0.05)
            head = self._queue.popleft()
            batch = [head]
            key = head.key()
            # one pass now, then bounded waits for late compatible
            # arrivals; FIFO order among the compatible is preserved
            coalesce_until = time.monotonic() + self.max_wait_s
            while len(batch) < self.max_batch:
                rest = deque()
                for r in self._queue:
                    if len(batch) < self.max_batch and r.key() == key:
                        batch.append(r)
                    else:
                        rest.append(r)
                self._queue = rest
                remaining = coalesce_until - time.monotonic()
                if len(batch) >= self.max_batch or remaining <= 0:
                    break
                self._cond.wait(remaining)
            return batch

    def _run_batch(self, batch: List[_Request]) -> None:
        n = len(batch)
        bucket = batch_bucket(n, self.buckets)
        prompts = np.stack([r.prompt for r in batch])
        if bucket > n:
            # pad B up to the bucket by repeating the last row — the
            # padding rows' outputs are discarded, and the jit cache only
            # ever sees bucket-shaped batches
            prompts = np.concatenate(
                [prompts, np.repeat(prompts[-1:], bucket - n, axis=0)])
        started = time.monotonic()
        try:
            res = self._execute(prompts, batch[0].max_new_tokens,
                                batch[0].version)
        except BaseException as err:  # noqa: BLE001 - fail the batch, not the loop
            for r in batch:
                r.error = err
                r.done.set()
            return
        tokens = np.asarray(res.tokens)
        for i, r in enumerate(batch):
            r.result = SchedResult(
                tokens=tokens[i], steps=int(res.steps), batch_size=bucket,
                coalesced=n, queued_s=started - r.submitted)
            r.done.set()
        with self._cond:
            self._batches += 1
            self._completed += n
            if n > 1:
                self._coalesced_requests += n

    def _loop(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            self._run_batch(batch)

    # -- lifecycle / observability --------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("scheduler already started")
        self._stopping = False
        self._thread = threading.Thread(
            target=self._loop, name=f"sched-{self.name}", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Drain: queued requests still execute, new submits are shed."""
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None

    def stats(self) -> Dict[str, Any]:
        with self._cond:
            return {
                "queue": len(self._queue),
                "queue_depth": self.queue_depth,
                "max_batch": self.max_batch,
                "buckets": list(self.buckets),
                "submitted": self._submitted,
                "completed": self._completed,
                "rejected_queue_full": self._rejected_queue_full,
                "rejected_deadline": self._rejected_deadline,
                "batches": self._batches,
                "coalesced_requests": self._coalesced_requests,
                "max_queue_seen": self._max_queue_seen,
            }
