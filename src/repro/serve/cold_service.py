"""The contributor service loop: a queue-driven fusion daemon.

ColD Fusion's core claim (paper Fig. 1, §2.3) is a *synergistic loop*:
many independent contributors continually recycle finetuned models into a
shared base, with only limited communication — no gradients, no lockstep.
This module turns the async double-buffered ``Repository`` into that
always-on service:

* **ContributorClient** — submits finetuned models as atomically-written
  flat rows (whole ``[N]`` or per-shard slices) into a durable on-disk
  **contribution queue** (``<root>/queue/``), and polls the published base
  iteration through a status file.  Contributors never touch the
  Repository object; the queue directory is the only shared surface.
* **ColdService** — a polling daemon that owns the Repository: it batches
  queue arrivals into cohorts under an **admission policy** (size /
  timeout / staleness screening at the queue boundary), drives
  ``fuse_pending(wait=False)`` so device fuses overlap queue drain, and
  publishes a status endpoint (iteration, queue depth, fuse latency).

Exactly-once fusion across crashes
----------------------------------

The hand-off rides the PR 3 spill/manifest machinery instead of inventing
a second durability story.  Admission calls
``Repository.ingest_spilled(path)``: the queue npz *becomes* the spill row
(no copy) and is recorded in the crash-recoverable staging manifest.  The
orderings that make every window safe:

1. a submission exists only once its npz lands via atomic
   ``os.replace`` — a contributor killed mid-enqueue leaves at most an
   ignorable ``.tmp-*`` file, and a retry of the same ``(name, seq)``
   replaces the same file idempotently;
2. **ingest before admit-mark**: the row enters the staging manifest
   (durable) before the queue manifest records it as admitted.  A crash
   between the two is healed on restart: the file is found in
   ``Repository.staged_spill_files()`` and simply re-marked, never
   re-ingested;
3. from staged to published, the Repository's own ``staged_at`` /
   ``fusing`` markers guarantee a killed daemon re-fuses a dispatched
   cohort iff its publish did not land (docs/async_repository.md);
4. **delete file before dropping its queue entry**: a consumed submission
   (admitted, yet absent from the staging manifest) is GC'd file-first, so
   a crash mid-GC leaves an orphan *entry* (harmless, dropped next pass)
   rather than an orphan *file* (which would look like a fresh submission
   and double-fuse).

Every ``faults.crash_point`` below names one of these windows; the
kill-at-checkpoint harness in ``tests/_faults.py`` arms them one at a time
and asserts the restarted daemon converges to the uninterrupted run's
base.  See docs/service_loop.md for the full crash matrix.

The forgetting regression gate
------------------------------

With ``gate=`` (a ``repro.serve.probes.RegressionGate``) armed, every
publish is *probed* before the service builds on it: fuses run
synchronously, the new base is scored by the fixed per-task probe suite,
and the scores are compared against the pre-fuse baseline.  A clean
publish refreshes the durable baseline (``gate_state.json``); a tripped
gate **quarantines** the offending cohort's queue files into
``<root>/quarantine/`` (never deleted, never re-fused) and **rolls the
repository back** to the baseline base on disk.  The gate verdict is
deterministic and the baseline durable, so a kill -9 anywhere in
probe → rollback → quarantine is replayed on restart — the bad publish
can never outlive the daemon that let it through.  Every cycle that
changes state appends one record to the append-only ``metrics.jsonl``
time series (torn tail repaired on restart).  See docs/observability.md.
"""
from __future__ import annotations

import math
import os
import random
import time
import warnings
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import io as ckpt
from repro.core.repository import (Repository, RepositoryFamily,
                                   family_member_root)
from repro.serve.probes import RegressionGate
from repro.utils import faults
from repro.utils.flat import (LANE, FamilyRouter, FlatSpec, ShardedFlatSpec,
                              delta_checksum, delta_encode,
                              delta_encode_sharded, row_checksum,
                              row_sketch_host)

QUEUE_DIR = "queue"
QUEUE_MANIFEST = "queue_manifest.json"
STATUS_FILE = "service_status.json"
QUARANTINE_DIR = "quarantine"
GATE_STATE_FILE = "gate_state.json"
METRICS_FILE = "metrics.jsonl"
# rotation cap for the active metrics file (docs/observability.md): at or
# past this size the daemon renames it to metrics.jsonl.1 before its next
# append.  The daemon is the SINGLE rotator — pool workers only O_APPEND.
METRICS_ROTATE_BYTES = 4 * 1024 * 1024
# owned by the ServingWorker (repro.serve.hot_swap), NOT the daemon: two
# processes atomically rewriting one status file would clobber each other,
# so the worker persists its own file and status() embeds it read-only
SERVING_STATE_FILE = "serving_state.json"


def serving_state_filename(worker_id: Optional[str] = None) -> str:
    """The serving-state file for one worker: the solo ``ServingWorker``
    keeps the historical ``serving_state.json``; pool members namespace
    theirs as ``serving_state-<id>.json`` so N workers under one root
    never clobber each other (``status()`` aggregates the namespace)."""
    if worker_id is None:
        return SERVING_STATE_FILE
    wid = str(worker_id)
    if not wid or any(c in wid for c in "/\\."):
        raise ValueError(f"invalid worker_id for state file: {worker_id!r}")
    return f"serving_state-{wid}.json"
ERROR_RING = 16  # recent_errors entries kept (and persisted) per service
ROUTE_RING = 64  # recent routing decisions surfaced in the status endpoint


def _queue_dir(root: str) -> str:
    return os.path.join(root, QUEUE_DIR)


# ---------------------------------------------------------------------------
# contributor side
# ---------------------------------------------------------------------------


class ContributorClient:
    """A contributor's handle on the service: submit rows, poll the base.

    ``name`` must be unique among concurrently-running contributors — the
    submission file is ``<name>-<seq>.npz``, and that determinism is what
    makes retries idempotent (re-submitting the same ``seq`` atomically
    replaces the same file; it can never enqueue twice).  The default name
    embeds the pid."""

    def __init__(self, root: str, name: Optional[str] = None):
        self.root = root
        self.name = name if name is not None else f"c{os.getpid()}"
        self._seq = 0
        self._spec: Optional[FlatSpec] = None

    # -- submit ---------------------------------------------------------
    def submit(self, params=None, *, row=None, spec: Optional[FlatSpec] = None,
               sspec: Optional[ShardedFlatSpec] = None,
               weight: Optional[float] = None,
               base_iteration: Optional[int] = None,
               seq: Optional[int] = None,
               checksum: bool = False,
               sketch: Optional[bool] = None,
               compress: bool = False,
               base=None,
               family: Optional[str] = None,
               k_per_block: int = 64,
               codec_block: int = LANE) -> str:
        """Enqueue one contribution; returns the submission id once (and
        only once) it is durably in the queue.

        Pass a ``params`` pytree (flattened here), or a pre-flattened
        ``row`` with its ``spec``.  With ``sspec`` the row is written as
        per-shard block-cyclic slices (``ShardedFlatSpec.shard_slices``) —
        the layout a mesh repository stages without host reassembly.
        ``base_iteration`` is the iteration of the base this contribution
        was finetuned from; the service's admission policy screens
        staleness on it.  ``seq`` replays a specific submission (retry);
        by default it auto-increments.

        ``checksum=True`` additionally stamps a CRC of the portable row
        for end-to-end verification under ``verify_checksums`` admission —
        covering the shard/unshard rearrangement, not just the file.
        Torn-file detection needs no checksum: the atomic write hides
        partial files, and the npz zip entry's own CRC is verified on
        read.

        The rider can also carry the row's content **sketch**
        (``repro.kernels.ops.row_sketch`` of the portable row) so the
        service's novelty screen needs no extra row read at admission.
        ``sketch=None`` (default) stamps it iff the service's published
        status says the screen is armed (or no status exists yet);
        True/False force it.  It sits in the same trust class as
        ``weight``/``base_iteration`` (a rider that mis-states it only
        distorts the advisory screen for its own row — no different from
        perturbing the row itself); under ``verify_checksums`` the service
        recomputes it from the file.

        ``compress=True`` enqueues the contribution **delta-compressed**
        (docs/service_loop.md): the difference against ``base`` (the
        pulled base pytree, or its pre-flattened row) is encoded as
        per-block top-``k_per_block`` sparse int8 values with per-block
        float scales (``repro.utils.flat.delta_encode``; per-shard under
        ``sspec``) — typically 5-10x fewer queue bytes than a dense row.
        Requires ``base_iteration``: the service admits a compressed
        delta only against its exact declared base vintage (a delta means
        nothing against any other base).  ``checksum=True`` then stamps a
        CRC of the *encoded payload bytes*, which is what the service
        recomputes under ``verify_checksums``.

        ``family=`` declares which family member's base this contribution
        was finetuned from (docs/service_loop.md routing; default the
        main base).  Under a routing service the declaration anchors the
        rider's delta — the actual fuse target is the router's decision,
        surfaced in the status ``routes`` ring — except for compressed
        submissions, which are *pinned*: routed anywhere but their
        declared member they are rejected, never decoded against the
        wrong base."""
        if row is None:
            if params is None:
                raise ValueError("submit needs params= or row=")
            spec = spec or self._spec or FlatSpec.from_tree(params)
            row = spec.flatten(params)
        elif spec is None:
            raise ValueError("row= requires spec=")
        self._spec = spec
        if seq is None:
            seq = self._seq
        self._seq = max(self._seq, seq) + 1
        sub_id = f"{self.name}-{seq:06d}"
        path = os.path.join(_queue_dir(self.root), sub_id + ".npz")
        os.makedirs(_queue_dir(self.root), exist_ok=True)
        host_row = np.asarray(row)
        payloads = None
        if compress:
            if base is None:
                raise ValueError("compress=True needs base= — the pulled "
                                 "base this contribution was finetuned from")
            if base_iteration is None:
                raise ValueError(
                    "compress=True needs base_iteration= — the service "
                    "admits a compressed delta only against its declared "
                    "base vintage")
            base_row = np.asarray(base if getattr(base, "ndim", None) == 1
                                  else spec.flatten(base))
            if sspec is not None:
                payloads = delta_encode_sharded(
                    host_row, base_row, sspec,
                    k_per_block=k_per_block, block=codec_block)
            else:
                payloads = delta_encode(host_row, base_row,
                                        k_per_block=k_per_block,
                                        block=codec_block)
        extra = {
            "id": sub_id,
            "contributor": self.name,
            "weight": None if weight is None else float(weight),
            "base_iteration": base_iteration,
            "submitted_at": time.time(),
        }
        if family is not None:
            extra["family"] = str(family)
        if compress:
            extra["codec"] = {"k_per_block": int(k_per_block),
                              "block": int(codec_block)}
        if sketch is None:
            st = self.status()
            sketch = (st is None or bool(st.get("novelty_screen"))
                      or bool(st.get("routing")))
        if sketch:
            # the row is already in hand: sketching it here is one cheap
            # host pass over memory, vs a full row re-read at admission
            extra["sketch"] = row_sketch_host(host_row).tolist()
        if checksum:
            # compressed submissions CRC the encoded payload bytes — the
            # artifact actually in the queue — so a rider cannot vouch for
            # a decode it never shipped (the liar-rider seam)
            extra["checksum"] = (delta_checksum(payloads) if compress
                                 else row_checksum(host_row))
        # the armed window: nothing durable has happened yet — a death here
        # (or anywhere inside the atomic write) enqueues nothing, and the
        # caller never receives the id
        faults.crash_point("client.mid_submit")
        if compress:
            ckpt.save_flat_delta(path, payloads, spec, sspec=sspec,
                                 extra=extra)
        elif sspec is not None:
            ckpt.save_flat_shards(path, sspec.shard_slices(host_row), spec,
                                  sspec, extra=extra)
        else:
            ckpt.save_flat(path, host_row, spec, extra=extra)
        return sub_id

    # -- poll -----------------------------------------------------------
    def status(self) -> Optional[Dict[str, Any]]:
        """The service's last published status, or None before the first
        cycle.  Never torn: the file is written atomically."""
        try:
            return ckpt.load_json(os.path.join(self.root, STATUS_FILE))
        except FileNotFoundError:
            return None

    def iteration(self) -> int:
        """The latest published base iteration (0 before any fuse)."""
        st = self.status()
        if st is not None:
            return int(st["iteration"])
        try:
            meta = ckpt.load_json(os.path.join(self.root, "repository.json"))
            return int(meta["iteration"])
        except FileNotFoundError:
            return 0

    def wait_for_iteration(self, target: int, *, timeout: float = 60.0,
                           interval: float = 0.02,
                           max_interval: float = 1.0) -> Dict[str, Any]:
        """Bounded poll until the published iteration reaches ``target``.
        Returns the status observed; raises TimeoutError at the deadline
        (never an unbounded sleep).

        Polling backs off exponentially from ``interval`` with full
        jitter, capped at ``max_interval`` — a fleet of contributors
        waiting on the same status file neither busy-spins the filesystem
        nor thunders in lockstep.  Every sleep is additionally clamped to
        the time remaining, so the total wait stays bounded by
        ``timeout`` regardless of the interval parameters."""
        deadline = time.monotonic() + timeout
        delay = interval
        while True:
            st = self.status()
            if st is not None and int(st["iteration"]) >= target:
                return st
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"iteration {target} not published within {timeout}s "
                    f"(last status: {st})")
            time.sleep(min(remaining, random.uniform(delay / 2, delay)))
            delay = min(delay * 2, max_interval)

    def download_base(self, family: Optional[str] = None):
        """Pull the latest published base pytree (Fig. 1, step 1) — of the
        named family member under a routing service, or the main base by
        default.  The base npz is durable before repository.json names it,
        so the load can never race a publish into a missing file."""
        root = (self.root if family is None
                else family_member_root(self.root, family))
        meta = ckpt.load_json(os.path.join(root, "repository.json"))
        it = int(meta["iteration"])
        return ckpt.load(os.path.join(root, f"base_iter{it:04d}.npz"))

    def family_iteration(self, family: str) -> int:
        """The named family member's published iteration (0 before any
        fuse; also 0 when the member does not exist yet — a member is
        born at iteration 0, so waiters need no existence special-case)."""
        st = self.status()
        fams = (st or {}).get("families") or {}
        if family in fams:
            return int(fams[family]["iteration"])
        try:
            meta = ckpt.load_json(os.path.join(
                family_member_root(self.root, family), "repository.json"))
            return int(meta["iteration"])
        except FileNotFoundError:
            return 0

    def wait_for_family(self, family: str, target: int, *,
                        timeout: float = 60.0, interval: float = 0.02,
                        max_interval: float = 1.0) -> Dict[str, Any]:
        """Bounded poll until the named member's published iteration
        reaches ``target`` — the routed-mode counterpart of
        ``wait_for_iteration``, with the same jittered backoff."""
        deadline = time.monotonic() + timeout
        delay = interval
        while True:
            st = self.status()
            if self.family_iteration(family) >= target:
                return st or {}
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"family {family!r} iteration {target} not published "
                    f"within {timeout}s (last status: {st})")
            time.sleep(min(remaining, random.uniform(delay / 2, delay)))
            delay = min(delay * 2, max_interval)

    def route_of(self, sub_id: str) -> Optional[Dict[str, Any]]:
        """The routing record for one of this contributor's submissions,
        from the status endpoint's recent-routes ring (None when the
        submission has not been routed yet, or has aged out of the
        ring)."""
        st = self.status()
        for rec in (st or {}).get("routes") or []:
            if rec.get("id") == sub_id:
                return rec
        return None


# ---------------------------------------------------------------------------
# service side
# ---------------------------------------------------------------------------


@dataclass
class AdmissionPolicy:
    """Cohort formation + screening at the queue boundary (the "Collaborative
    and Efficient Fine-tuning" framing: cheap per-row admission decisions
    here, the §9 statistical screen inside the fuse).

    * ``min_cohort`` — dispatch a fuse only once this many rows are staged
      (1 = fuse every arrival immediately);
    * ``max_wait_s`` — ...unless the oldest staged row has waited this long
      (0 = size-only batching);
    * ``max_cohort`` — admission stops staging past this many rows per
      cohort; the excess stays queued for the next round;
    * ``max_staleness`` — reject a submission whose recorded
      ``base_iteration`` lags the current base by more than this many
      iterations (None = accept any vintage).  Delta-compressed
      submissions ignore this knob: they are pinned to the *exact*
      current vintage (and deferred while a fuse is in flight), since a
      delta is only decodable against the base it was computed from;
    * ``verify_checksums`` — re-read each row at admission and verify the
      contributor's CRC (costs a full row read; off by default);
    * ``novelty_threshold`` — content-based novelty screen (ROADMAP
      "Similarity/novelty admission"): reject a submission whose row
      sketch sits within this relative distance of any of the last
      ``sketch_window`` admitted rows (``repro.utils.flat.CohortSketch``;
      costs one row read per admission).  0 still rejects exact replays;
      None (default) disables the screen;
    * ``sketch_window`` — how many recent admissions the novelty screen
      remembers (persisted in ``cohort_sketch.json``, so a restarted
      daemon screens against the same history);
    * ``compact_keep_bases`` — run ``Repository.compact`` after each
      publish, keeping this many bases (None = never compact);
    * ``max_bases`` / ``split_threshold`` / ``cross_fuse_every`` — the
      similarity router's knobs, live only when the service wraps a
      ``RepositoryFamily`` (docs/service_loop.md routing): submissions
      whose sketch delta sits further than ``split_threshold`` from every
      member spawn a new base (up to ``max_bases`` members; at the cap
      they route to the nearest anyway), and every ``cross_fuse_every``
      member publishes the whole family cross-fuses toward its mean
      (0 = never cross-fuse).
    """

    min_cohort: int = 1
    max_wait_s: float = 0.0
    max_cohort: int = 64
    max_staleness: Optional[int] = None
    verify_checksums: bool = False
    novelty_threshold: Optional[float] = None
    sketch_window: int = 32
    compact_keep_bases: Optional[int] = None
    max_bases: int = 1
    split_threshold: float = 0.8
    cross_fuse_every: int = 0


@dataclass
class _Lane:
    """Per-family-member service state: the member Repository plus the
    cohort clock and gate baseline that were service-global before
    routing.  A single-base service is exactly one ``main`` lane, so the
    lane machinery IS the old single-repo path, not a parallel one."""

    name: str
    repo: Repository
    queue_dir: str
    gate_path: str
    cohort_since: Optional[float] = None
    failed_cohort_size: Optional[int] = None
    gate_baseline: Optional[Dict[str, float]] = None
    gate_iteration: Optional[int] = None
    last_gate: Optional[Dict[str, Any]] = None


class ColdService:
    """The polling fusion daemon: wraps a spill-enabled Repository behind
    the durable contribution queue.  Single-owner: exactly one service per
    repository root (contributors scale horizontally instead).

    Pass ``family=`` (a ``RepositoryFamily``) instead of ``repo`` to arm
    **similarity routing** (docs/service_loop.md): every fresh submission
    is scored against each member's base sketch and windowed delta
    evidence (``repro.utils.flat.FamilyRouter``), moved into its nearest
    member's queue namespace, and fused there — with new members spawned
    when nothing is near (up to ``policy.max_bases``) and the family
    periodically cross-fused toward its mean."""

    def __init__(self, repo: Optional[Repository] = None, *,
                 family: Optional[RepositoryFamily] = None,
                 policy: Optional[AdmissionPolicy] = None,
                 gate: Optional[RegressionGate] = None):
        if (repo is None) == (family is None):
            raise ValueError(
                "ColdService takes exactly one of repo= (single base) or "
                "family= (similarity-routed RepositoryFamily)")
        if family is not None:
            # spawned members must inherit the queue-ingest spill contract
            family.member_kw.setdefault("spill", True)
            repo = family.members["main"]
        if not repo.root:
            raise ValueError("ColdService requires an on-disk repository")
        self.repo = repo
        self.family = family
        self._routing = family is not None
        self.policy = policy or AdmissionPolicy()
        self.gate = gate
        self.queue_dir = _queue_dir(repo.root)
        self.quarantine_dir = os.path.join(repo.root, QUARANTINE_DIR)
        self._router = FamilyRouter(
            split_threshold=self.policy.split_threshold,
            max_bases=self.policy.max_bases) if self._routing else None
        members = family.members if family is not None else {"main": repo}
        self._lanes: Dict[str, _Lane] = {
            name: self._make_lane(name, member)
            for name, member in members.items()}
        self._main = self._lanes["main"]
        self._qman_path = os.path.join(self.queue_dir, QUEUE_MANIFEST)
        self._status_path = os.path.join(repo.root, STATUS_FILE)
        self._metrics_path = os.path.join(repo.root, METRICS_FILE)
        self._entries: Dict[str, Dict[str, Any]] = {}
        self._rejects: List[Dict[str, str]] = []
        self._fused_ids = 0          # queue submissions retired as fused
        self._rejected = 0
        self._novelty_rejected = 0   # subset of _rejected: near-duplicates
        self._quarantined = 0        # queue submissions banished by the gate
        self._rollbacks = 0          # gate trips that backed out a publish
        self._spawned = 0            # family members minted by the router
        self._cross_fuses = 0        # inter-member merges performed
        self._cross_counter = 0      # member publishes since the last one
        self._routes: List[Dict[str, Any]] = []
        self._last_pub = "main"      # lane of the most recent publish
        self._recent_errors: List[Dict[str, Any]] = []
        self._last_error: Optional[str] = None
        self._last_gate: Optional[Dict[str, Any]] = None
        self._cycle = 0
        self._metrics_mark: Optional[tuple] = None
        self._stop = False
        # a previous daemon killed mid-append leaves a torn final line;
        # truncate it BEFORE the first append or the next record would be
        # welded onto the fragment (mid-file corruption, which readers
        # rightly treat as fatal rather than as a crash artifact)
        torn = ckpt.repair_jsonl_tail(self._metrics_path)
        if torn:
            warnings.warn(f"metrics.jsonl: truncated a torn {torn}-byte "
                          "tail left by a crashed daemon")
        if gate is not None and self.policy.compact_keep_bases is not None \
                and self.policy.compact_keep_bases < 2:
            warnings.warn("regression gate needs the baseline base retained "
                          "on disk — raising compact_keep_bases to 2")
            self.policy.compact_keep_bases = 2
        self._load_queue_manifest()
        if gate is not None:
            # before _recover(): a publish whose gate verdict was lost to a
            # crash must be replayed first, or _recover would GC (as fused)
            # the very cohort the replayed verdict needs to quarantine
            self._init_gate()
        self._recover()
        if self.policy.novelty_threshold is not None or self._routing:
            # adopt (or create) the persisted sketch window before the
            # first admission, so the screen sees pre-crash history; the
            # router needs every member's sketch even with the novelty
            # screen off (base sketches + delta windows ARE its evidence)
            for lane in self._lanes.values():
                lane.repo.enable_cohort_sketch(
                    window=self.policy.sketch_window)
        # publish an initial status so contributors can see the policy
        # (e.g. whether to stamp rider sketches) before the first cycle
        ckpt.save_json_atomic(self._status_path, self.status())
        for lane in self._lanes.values():
            if lane.repo.n_staged:
                # rows recovered from the staging manifest start the cohort
                # clock too — max_wait_s must cover an undersized recovered
                # cohort, not just fresh arrivals
                lane.cohort_since = time.time()

    def _make_lane(self, name: str, member: Repository) -> _Lane:
        if not member.root:
            raise ValueError("ColdService requires an on-disk repository")
        if not member.spill:
            raise ValueError(
                "ColdService requires Repository(spill=True) — queue ingest "
                "rides the crash-recoverable staging manifest "
                f"(family member {name!r})")
        lane = _Lane(name=name, repo=member,
                     queue_dir=os.path.join(member.root, QUEUE_DIR),
                     gate_path=os.path.join(member.root, GATE_STATE_FILE))
        os.makedirs(lane.queue_dir, exist_ok=True)
        return lane

    # -- queue manifest -------------------------------------------------
    def _load_queue_manifest(self) -> None:
        try:
            data = ckpt.load_json(self._qman_path)
        except FileNotFoundError:
            return
        self._entries = {e["id"]: e for e in data.get("entries", [])}
        self._fused_ids = int(data.get("fused_total", 0))
        self._rejected = int(data.get("rejected_total", 0))
        self._novelty_rejected = int(data.get("novelty_rejected_total", 0))
        self._quarantined = int(data.get("quarantined_total", 0))
        self._rollbacks = int(data.get("rollbacks_total", 0))
        self._spawned = int(data.get("families_spawned_total", 0))
        self._cross_fuses = int(data.get("cross_fuses_total", 0))
        self._cross_counter = int(data.get("cross_counter", 0))
        self._recent_errors = list(data.get("recent_errors", []))[-ERROR_RING:]

    def _write_queue_manifest(self) -> None:
        ckpt.save_json_atomic(self._qman_path, {
            "version": 1,
            "fused_total": self._fused_ids,
            "rejected_total": self._rejected,
            "novelty_rejected_total": self._novelty_rejected,
            "quarantined_total": self._quarantined,
            "rollbacks_total": self._rollbacks,
            "families_spawned_total": self._spawned,
            "cross_fuses_total": self._cross_fuses,
            "cross_counter": self._cross_counter,
            "recent_errors": list(self._recent_errors),
            "entries": list(self._entries.values()),
        })

    def _entry_lane(self, e: Dict[str, Any]) -> _Lane:
        """The lane an entry's queue file lives in — ``main`` for entries
        written before routing existed (no ``family`` key)."""
        return self._lanes.get(e.get("family") or "main") or self._main

    def _recover(self) -> None:
        """Reconcile the queue manifest against the reopened repository.
        An *admitted* entry was, by the ingest-before-admit-mark ordering,
        in the staging manifest when it was marked — so if it is absent
        now, its cohort's publish landed (or recovery skipped it as
        consumed): GC it.  Entries still staged will fuse normally."""
        staged = {n: l.repo.staged_spill_files()
                  for n, l in self._lanes.items()}
        changed = False
        for sub_id, e in list(self._entries.items()):
            lane = self._entry_lane(e)
            if f"{QUEUE_DIR}/{e['file']}" in staged[lane.name]:
                continue
            path = os.path.join(lane.queue_dir, e["file"])
            if os.path.exists(path):
                os.remove(path)          # file first; see ordering (4)
            del self._entries[sub_id]
            self._fused_ids += 1
            changed = True
        if changed:
            self._write_queue_manifest()

    # -- the forgetting regression gate ---------------------------------
    def _init_gate(self) -> None:
        """Adopt (or establish) each lane's durable gate baseline,
        replaying any publish whose verdict a crash swallowed.

        Per member, ``gate_state.json`` records the probe scores of its
        last known-good base and iteration.  On start:

        * state matches the member's iteration — adopt it;
        * state lags the member — a publish landed post-baseline whose
          gate never ran (kill -9 between publish and verdict): re-score
          the current base and apply the verdict NOW, exactly as the dead
          daemon would have (probes are deterministic, so the replayed
          verdict is the one that was lost);
        * no state (or implausible state) — baseline = the current base.

        Gate state is strictly per member: a trip on one family member
        quarantines and rolls back that member alone."""
        for lane in list(self._lanes.values()):
            self._init_gate_lane(lane)

    def _init_gate_lane(self, lane: _Lane) -> None:
        state = None
        try:
            state = ckpt.load_json(lane.gate_path)
        except FileNotFoundError:
            pass
        if state is not None:
            try:
                it = int(state["iteration"])
                scores = {k: float(v) for k, v in state["scores"].items()}
            except (KeyError, TypeError, ValueError):
                warnings.warn("gate_state.json unreadable — re-baselining "
                              "on the current base")
                state = None
        if state is not None and it == lane.repo.iteration:
            lane.gate_baseline, lane.gate_iteration = scores, it
            return
        if state is not None and it < lane.repo.iteration:
            lane.gate_baseline, lane.gate_iteration = scores, it
            self._apply_gate_verdict(
                self.gate.check(scores, lane.repo.flat_base_host()), lane)
            return
        if state is not None:
            warnings.warn(
                f"gate_state.json names iteration {it} but the repository "
                f"is at {lane.repo.iteration} — re-baselining")
        self._rebaseline_gate(lane)

    def _rebaseline_gate(self, lane: _Lane) -> None:
        """Score the lane's current base as its new known-good baseline
        and persist it atomically."""
        lane.gate_baseline = self.gate.probes.score(
            lane.repo.flat_base_host())
        lane.gate_iteration = lane.repo.iteration
        ckpt.save_json_atomic(lane.gate_path, {
            "version": 1,
            "iteration": lane.gate_iteration,
            "scores": lane.gate_baseline,
        })

    def _apply_gate_verdict(self, report, lane: _Lane) -> Dict[str, Any]:
        """Act on a probe comparison of the lane's just-published base.

        Clean: the baseline advances to the new base (durably) and the
        service proceeds.  Tripped: the consumed cohort's queue files are
        **quarantined** (moved, counted, never re-fused), then the lane's
        repository **rolls back on disk** to its baseline iteration with
        the staged next cohort preserved — other family members' bases,
        baselines, and in-flight cohorts are untouched.  Quarantine
        strictly precedes rollback: while the bad base is still current,
        the member iteration sits ahead of its ``gate_state.json``, which
        is exactly the signal that makes a restarted daemon replay this
        verdict — roll back first and a crash before quarantine would
        leave the cohort looking ordinarily fused.  Returns the gate
        event for metrics."""
        faults.crash_point("service.post_probe")
        lane.last_gate = self._last_gate = report.to_json()
        if report.ok:
            self._rebaseline_gate(lane)
            return {"event": "probe", "ok": True, "family": lane.name,
                    "iteration": lane.repo.iteration,
                    "probe": self._last_gate}
        bad_iteration = lane.repo.iteration
        moved = self._quarantine_consumed(lane)
        self._emit_metrics({
            "event": "quarantine", "iteration": bad_iteration,
            "family": lane.name,
            "quarantined": moved, "quarantined_total": self._quarantined,
            "regressed": report.regressed, "worst_delta": report.worst,
        })
        faults.crash_point("service.post_quarantine")
        lane.repo.rollback(lane.gate_iteration, keep_staged=True)
        lane.failed_cohort_size = None  # the staged cohort is unrelated
        self._emit_metrics({
            "event": "rollback", "from_iteration": bad_iteration,
            "family": lane.name,
            "to_iteration": lane.gate_iteration,
            "rollbacks_total": self._rollbacks, "probe": self._last_gate,
        })
        return {"event": "rollback", "ok": False, "family": lane.name,
                "from_iteration": bad_iteration,
                "to_iteration": lane.gate_iteration,
                "quarantined": moved, "probe": self._last_gate}

    def _quarantine_consumed(self, lane: _Lane) -> int:
        """Move the lane's consumed cohort's queue files into the shared
        ``<root>/quarantine/`` — file moved (atomic ``os.replace``) before
        its entry is dropped, mirroring GC ordering (4): a crash
        mid-quarantine leaves an orphan *entry* whose file already sits in
        quarantine, finished by the replayed verdict; never an orphan
        queue file that could re-fuse.  Only entries routed to THIS lane
        are candidates — a gate trip never banishes another member's
        cohort.  Counters ride the same queue-manifest write as the entry
        drops, so ``quarantined_total`` (and the rollback count,
        incremented here because a trip quarantines exactly one cohort)
        stay exact across any crash."""
        staged = lane.repo.staged_spill_files()
        moved = 0
        for sub_id, e in list(self._entries.items()):
            if (e.get("family") or "main") != lane.name:
                continue  # another member's cohort: not this verdict's
            if f"{QUEUE_DIR}/{e['file']}" in staged:
                continue  # next cohort, still staged: not this publish's
            src = os.path.join(lane.queue_dir, e["file"])
            if os.path.exists(src):
                os.makedirs(self.quarantine_dir, exist_ok=True)
                os.replace(src, os.path.join(self.quarantine_dir, e["file"]))
            del self._entries[sub_id]
            self._quarantined += 1
            moved += 1
        if moved:
            self._rollbacks += 1
            self._write_queue_manifest()
        return moved

    # -- admission ------------------------------------------------------
    def _scan_new(self) -> List[Tuple[str, Optional[_Lane]]]:
        """Queue files not yet tracked, oldest submission order, as
        ``(filename, lane)`` pairs.  In-flight atomic writes (``*.tmp-*``)
        are invisible by construction.

        Fresh submissions land in the top-level queue and scan with
        ``lane=None`` — unrouted.  Files already sitting in a non-main
        member's queue namespace but absent from the queue manifest are a
        crash artifact of the post-route window (moved, then killed
        before ingest/admit-mark): they scan *forced* to that lane, so
        the restart finishes their admission without re-routing — the
        atomic move IS the durable routing decision."""
        known = {((e.get("family") or "main"), e["file"])
                 for e in self._entries.values()}
        out: List[Tuple[str, Optional[_Lane]]] = [
            (fn, None) for fn in sorted(os.listdir(self.queue_dir))
            if fn.endswith(".npz") and ".tmp-" not in fn
            and ("main", fn) not in known]
        for name, lane in self._lanes.items():
            if name == "main":
                continue
            out.extend(
                (fn, lane) for fn in sorted(os.listdir(lane.queue_dir))
                if fn.endswith(".npz") and ".tmp-" not in fn
                and (name, fn) not in known)
        return out

    def _reject(self, fn: str, reason: str, *, novelty: bool = False,
                lane: Optional[_Lane] = None) -> None:
        self._rejected += 1
        if novelty:
            self._novelty_rejected += 1
        self._rejects = (self._rejects + [{"file": fn, "reason": reason}])[-8:]
        path = os.path.join((lane or self._main).queue_dir, fn)
        if os.path.exists(path):
            os.remove(path)

    @staticmethod
    def _rider_error(extra: Dict[str, Any]) -> Optional[str]:
        """Screen queue-supplied rider metadata before anything consumes
        it: a garbage ``base_iteration``/``weight``/``id`` must be a
        per-file rejection reason, never an exception that aborts the admit
        pass (and stalls every other submission behind it)."""
        sub_id = extra.get("id")
        if sub_id is not None and not isinstance(sub_id, str):
            return f"malformed rider: id={sub_id!r} is not a string"
        base_it = extra.get("base_iteration")
        if base_it is not None:
            try:
                int(base_it)
            except (TypeError, ValueError):
                return (f"malformed rider: base_iteration={base_it!r} "
                        "is not an integer")
        weight = extra.get("weight")
        if weight is not None:
            try:
                w = float(weight)
            except (TypeError, ValueError):
                return f"malformed rider: weight={weight!r} is not a number"
            if not math.isfinite(w):
                # a NaN/inf weight would poison the weight normalization
                # w/Σw and publish a non-finite base — permanently
                return f"malformed rider: weight={weight!r} is not finite"
        return None

    def _checksum_ok(self, path: str, meta: Dict[str, Any],
                     want: str) -> Tuple[bool, Optional[np.ndarray]]:
        """Returns (crc matches, the portable [N] row it read) — callers
        that need the row again (the novelty screen's rider-distrust
        recompute) reuse it instead of paying a second full read.

        Compressed submissions verify against the **encoded payload
        bytes** (``repro.utils.flat.delta_checksum``) — the artifact
        actually enqueued — never against a decode: a liar rider stamping
        the CRC of the row it *claims* to decode to is a per-file
        checksum rejection, not an accepted forgery.  The returned row is
        None (the novelty screen sketches compressed rows from the delta
        instead)."""
        if meta.get("compressed"):
            payloads, _ = ckpt.load_flat_delta(path)
            return delta_checksum(payloads) == want, None
        if meta["sharded"]:
            with ckpt.FlatShardReader(path) as r:
                row = r.full_row()
        else:
            row, _ = ckpt.load_flat(path, as_jax=False)
        return row_checksum(row) == want, row

    def _compressed_screen(self, extra: Dict[str, Any], path: str,
                           lane: Optional[_Lane] = None) -> Optional[str]:
        """Admission screen for a delta-compressed submission.  Returns
        None (admit), ``"defer"`` (leave queued for the next cycle), or a
        per-file rejection reason.

        A delta is only decodable against the exact base it was computed
        from, so the vintage pin is equality — ``base_iteration`` must
        match the current iteration — not the dense rows' lag-tolerant
        ``max_staleness``.  While a fuse is in flight the next publish is
        already moving the base, so a current-vintage delta is *deferred*
        (kept in the queue, neither staged nor rejected) rather than
        admitted into a cohort that would decode it against tomorrow's
        base.  The payload arrays are validated here too: non-finite
        quantization scales would decode to a non-finite delta and poison
        the fuse, so they are malformed-rider rejections at the boundary,
        with the same per-file (never admit-pass-aborting) discipline as
        every other screen."""
        repo = (lane or self._main).repo
        bi = extra.get("base_iteration")
        if bi is None:
            return ("malformed rider: compressed submission without "
                    "base_iteration — a delta is only decodable against "
                    "its declared base")
        bi = int(bi)  # _rider_error already screened non-integers
        if repo.inflight:
            return "defer"
        if bi != repo.iteration:
            return (f"stale: delta encoded against base iteration {bi}, "
                    f"current {repo.iteration} — a compressed "
                    "submission must match the current vintage exactly")
        try:
            payloads, _ = ckpt.load_flat_delta(path)
        except Exception as err:  # torn/garbage payload entries
            return f"unreadable ({type(err).__name__}: {err})"
        for p in payloads:
            if not np.isfinite(p.scales).all():
                return ("malformed rider: non-finite quantization scale "
                        "in delta payload")
        return None

    def _admit(self) -> Dict[str, int]:
        """Stage new queue arrivals into their repository, up to the
        per-member cohort budget.  Unreadable / malformed / mismatched /
        stale / near-duplicate rows are rejected here at the queue
        boundary — they never reach the fuse.  Returns
        ``{"admitted": n, "queue_depth": files left unadmitted}``.

        Under routing, each fresh submission is first scored and moved
        into its member's queue namespace (``_route_admit``); every
        screen after that point — compressed vintage pin, staleness,
        novelty window, ingest — runs against the ROUTED member.  Files
        already sitting in a member namespace (the post-route crash
        window) skip re-scoring entirely.

        Already-staged files (ingested by a pre-crash admit whose
        queue-manifest write was lost) are re-marked UNCONDITIONALLY —
        outside the budget, before anything else.  A budget-starved
        re-mark would let the file fuse and leave the staging manifest
        while still looking brand-new to a later scan, which would
        re-ingest (double-fuse) it.  Re-marks are keyed by *(member,
        file)*: a rider ``id`` that differs from the filename stem must
        reuse the entry already tracking the file, never mint a second
        one."""
        new = self._scan_new()
        if not new:
            return {"admitted": 0, "queue_depth": 0}
        staged = {n: l.repo.staged_spill_files()
                  for n, l in self._lanes.items()}
        threshold = self.policy.novelty_threshold
        admitted = leftover = 0
        rejected0 = self._rejected
        for fn, forced in new:
            lane = forced if forced is not None else self._main
            path = os.path.join(lane.queue_dir, fn)
            sub_id = fn[:-len(".npz")]
            if f"{QUEUE_DIR}/{fn}" in staged[lane.name]:
                # re-mark only; bookkeeping fields best-effort, taken from
                # the entry already tracking this file if there is one
                prev = next(
                    (s for s, e in self._entries.items()
                     if e["file"] == fn
                     and (e.get("family") or "main") == lane.name), None)
                if prev is not None:
                    sub_id = prev
                    extra = {k: self._entries[prev].get(k)
                             for k in ("weight", "contributor")}
                else:
                    extra = {}
                weight = extra.get("weight")
            else:
                if ((forced is not None or not self._routing)
                        and self.policy.max_cohort - lane.repo.n_staged <= 0):
                    # routed-lane budgets are enforced inside _route_admit
                    # (before the move), so only already-placed files are
                    # budget-checked here
                    leftover += 1
                    continue
                try:
                    meta = ckpt.flat_row_meta(path)
                except Exception as err:  # torn/garbage enqueue: quarantine
                    self._reject(fn, f"unreadable ({type(err).__name__}: "
                                     f"{err})", lane=lane)
                    continue
                extra = meta.get("extra") or {}
                rider_err = self._rider_error(extra)
                if rider_err is not None:
                    self._reject(fn, rider_err, lane=lane)
                    continue
                sub_id = extra.get("id") or sub_id
                sketch = delta = None
                if self._routing:
                    if forced is None:
                        routed = self._route_admit(fn, path, meta, extra,
                                                   sub_id)
                        if routed is None:
                            continue
                        if routed == "defer":
                            leftover += 1
                            continue
                        lane, path, sketch, delta = routed
                    else:
                        sketch, bad = self._obtain_sketch(fn, path, meta,
                                                          lane=lane)
                        if bad:
                            continue
                        delta = self._delta_of(sketch, extra)
                if meta.get("compressed"):
                    verdict = self._compressed_screen(extra, path, lane)
                    if verdict == "defer":
                        # current-vintage delta arriving mid-fuse: neither
                        # staged (the in-flight publish is about to move
                        # the base it decodes against) nor rejected — it
                        # stays queued and admits next cycle
                        leftover += 1
                        continue
                    if verdict is not None:
                        self._reject(fn, verdict, lane=lane)
                        continue
                else:
                    stale = self._staleness(extra, lane)
                    if stale is not None:
                        self._reject(fn, stale, lane=lane)
                        continue
                row = None
                if self.policy.verify_checksums and extra.get("checksum"):
                    try:
                        ok, row = self._checksum_ok(path, meta,
                                                    extra["checksum"])
                    except Exception as err:
                        # torn or vanished between the meta peek and the
                        # full-row read: same quarantine as unreadable
                        # metadata, never an aborted admit pass
                        self._reject(fn, f"unreadable ({type(err).__name__}: "
                                         f"{err})", lane=lane)
                        continue
                    if not ok:
                        self._reject(fn, "checksum mismatch", lane=lane)
                        continue
                if threshold is not None or self._routing:
                    # with routing and the novelty screen off the sketch is
                    # still recorded: window deltas are routing evidence
                    dup = self._novelty_check(fn, path, meta, sub_id,
                                              threshold, lane=lane, row=row,
                                              sketch=sketch, delta=delta)
                    if dup:
                        continue
                w = extra.get("weight")
                weight = None if w is None else float(w)
                try:
                    lane.repo.ingest_spilled(path, weight=weight, meta=meta)
                except ValueError as err:  # FlatSpec mismatch etc.
                    if threshold is not None or self._routing:
                        # the pre-ingest sketch of a row that never staged
                        # must not pollute the novelty window
                        lane.repo.cohort_sketch.discard(sub_id)
                        lane.repo.save_cohort_sketch()
                    self._reject(fn, str(err), lane=lane)
                    continue
                # the row is durably staged; the admit-mark below is the
                # recoverable half of the hand-off (ordering (2))
                faults.crash_point("service.post_ingest")
            # dedupe by (member, file): this (re)admission supersedes any
            # entry that tracks the same file under a different id
            for other in [s for s, e in self._entries.items()
                          if e["file"] == fn and s != sub_id
                          and (e.get("family") or "main") == lane.name]:
                del self._entries[other]
            entry = {
                "id": sub_id, "file": fn, "state": "admitted",
                "weight": weight,
                "contributor": extra.get("contributor"),
                "admitted_at": time.time(),
                "staged_iteration": lane.repo.iteration,
            }
            if self._routing:
                entry["family"] = lane.name
            self._entries[sub_id] = entry
            admitted += 1
            lane.failed_cohort_size = None  # new blood: retry a stuck cohort
            if lane.cohort_since is None:
                lane.cohort_since = time.time()
        if admitted or self._rejected != rejected0:
            # rejections persist their counters too: a restarted daemon's
            # totals must agree with what the status endpoint reported
            self._write_queue_manifest()
        return {"admitted": admitted, "queue_depth": leftover}

    # -- routing --------------------------------------------------------
    def _route_admit(self, fn: str, path: str, meta: Dict[str, Any],
                     extra: Dict[str, Any], sub_id: str):
        """Route one fresh top-queue submission against the family
        (docs/service_loop.md).  Returns ``None`` (rejected, counted),
        ``"defer"`` (left queued for the next cycle), or
        ``(lane, path, sketch, delta)`` with ``path`` pointing at the
        file's post-move location in the routed member's queue namespace.

        The atomic ``move_atomic`` into the member namespace IS the
        durable routing decision: a crash anywhere after it (the
        ``service.post_route`` seam) is healed by ``_scan_new``'s
        forced-lane pass, which finishes admission in the routed member
        without re-scoring."""
        declared = str(extra.get("family") or "main")
        dl = self._lanes.get(declared)
        if dl is None:
            self._reject(fn, f"malformed rider: unknown family {declared!r}")
            return None
        bi = extra.get("base_iteration")
        bi = None if bi is None else int(bi)
        sketch, bad = self._obtain_sketch(fn, path, meta, lane=dl,
                                          at=self._main)
        if bad:
            return None
        decision = self._router.route(
            sketch, {n: l.repo.cohort_sketch for n, l in self._lanes.items()},
            declared=declared, base_iteration=bi)
        spawned = False
        if decision.spawn:
            if meta.get("compressed"):
                # the vintage pin below would reject it anyway — never
                # mint a member for a submission that cannot fuse there
                self._reject(fn, self._family_pin_reason(declared, None))
                return None
            lane = self._unclaimed_lane()
            if lane is None:
                lane = self._spawn_lane(declared, bi)
                spawned = True
        else:
            lane = self._lanes[decision.family]
            if meta.get("compressed") and lane.name != declared:
                self._reject(fn, self._family_pin_reason(declared, lane.name))
                return None
        if self.policy.max_cohort - lane.repo.n_staged <= 0:
            return "defer"
        if lane.name != "main":
            dst = os.path.join(lane.queue_dir, fn)
            ckpt.move_atomic(path, dst)
            path = dst
        faults.crash_point("service.post_route")
        self._routes = (self._routes + [{
            "id": sub_id, "family": lane.name,
            "distance": decision.distance, "spawned": spawned,
            "reason": decision.reason}])[-ROUTE_RING:]
        return lane, path, sketch, decision.delta

    @staticmethod
    def _family_pin_reason(declared: str, routed: Optional[str]) -> str:
        dst = ("a new family member" if routed is None
               else f"member {routed!r}")
        return (f"stale: delta encoded against family {declared!r} but "
                f"routed to {dst} — a compressed submission is pinned to "
                "its declared member's base")

    def _unclaimed_lane(self) -> Optional[_Lane]:
        """A spawned-but-evidence-free member: its spawning submission
        crashed away (or failed ingest) before leaving any trace, so the
        next spawn-worthy submission claims it instead of minting another
        — a durable spawn whose rider was lost must not grow the family
        twice."""
        for name in sorted(self._lanes):
            lane = self._lanes[name]
            if (name != "main" and not lane.repo.history
                    and not lane.repo.n_staged and not lane.repo.inflight
                    and lane.repo.cohort_sketch is not None
                    and not lane.repo.cohort_sketch.entries):
                return lane
        return None

    def _spawn_lane(self, declared: str,
                    seed_iteration: Optional[int]) -> _Lane:
        """Mint a new family member seeded from the declared member's base
        vintage, wire up its lane (sketch window, gate baseline), and
        persist the spawn counters."""
        name = self.family.spawn(seed_family=declared,
                                 seed_iteration=seed_iteration)
        member = self.family.members[name]
        lane = self._make_lane(name, member)
        self._lanes[name] = lane
        member.enable_cohort_sketch(window=self.policy.sketch_window)
        if self.gate is not None:
            self._rebaseline_gate(lane)
        self._spawned += 1
        self._write_queue_manifest()
        self._emit_metrics({
            "event": "family_spawn", "family": name,
            "seeded_from": declared, "families": len(self._lanes),
            "families_spawned_total": self._spawned,
        })
        return lane

    def _delta_of(self, sketch, extra: Dict[str, Any]
                  ) -> Optional[np.ndarray]:
        """Recompute a forced-lane file's routing delta (its projection
        sketch minus its declared base vintage's) for the routed member's
        evidence window — the post-route crash path skips the router,
        which would otherwise have supplied it."""
        declared = str(extra.get("family") or "main")
        dl = self._lanes.get(declared)
        if dl is None or dl.repo.cohort_sketch is None:
            return None
        bi = extra.get("base_iteration")
        b0 = dl.repo.cohort_sketch.base_at(None if bi is None else int(bi))
        if b0 is None:
            return None
        return (np.asarray(sketch, np.float64)[0]
                - np.asarray(b0, np.float64)[0])

    def _obtain_sketch(self, fn: str, path: str, meta: Dict[str, Any], *,
                       lane: _Lane, at: Optional[_Lane] = None,
                       row: Optional[np.ndarray] = None
                       ) -> Tuple[Optional[np.ndarray], bool]:
        """The submission's content sketch, as ``(sketch, rejected)``.

        The rider's pre-computed sketch is used when present (no row read
        at all); rows without one — or any rider sketch when
        ``verify_checksums`` distrusts riders — are sketched from ``row``
        (the checksum pass already read it) or from the file in one read
        (``Repository.sketch_row_file``, against ``lane``'s base for
        compressed deltas).  An unreadable file is rejected here (from
        ``at``'s queue namespace — the lane whose directory currently
        holds it) and reported as ``(None, True)``."""
        sk = lane.repo.cohort_sketch
        sketch = None
        rider = (meta.get("extra") or {}).get("sketch")
        if rider is not None and not self.policy.verify_checksums:
            try:
                arr = np.asarray(rider, np.float64)
                if arr.shape == (2, sk.n_buckets) and np.isfinite(arr).all():
                    sketch = arr
            except (TypeError, ValueError):
                sketch = None  # malformed rider sketch: compute from file
        if sketch is None and row is not None:
            sketch = row_sketch_host(row, sk.n_buckets)
        if sketch is None:
            try:
                sketch = lane.repo.sketch_row_file(path, meta=meta)
            except Exception as err:  # torn/vanished since the meta peek
                self._reject(fn, f"unreadable ({type(err).__name__}: {err})",
                             lane=at or lane)
                return None, True
        return sketch, False

    def _novelty_check(self, fn: str, path: str, meta: Dict[str, Any],
                       sub_id: str, threshold: Optional[float], *,
                       lane: Optional[_Lane] = None,
                       row: Optional[np.ndarray] = None,
                       sketch: Optional[np.ndarray] = None,
                       delta: Optional[np.ndarray] = None) -> bool:
        """The content-based novelty screen (docs/service_loop.md): obtain
        the row's sketch, reject the file if it sits within ``threshold``
        of any of the lane's windowed recent admissions, otherwise make
        the sketch (and its routing ``delta`` evidence) durable *before*
        the row stages.  Returns True when the file was rejected (caller
        skips it).  ``threshold=None`` (routing with the novelty screen
        off) skips the match but still records the evidence."""
        lane = lane or self._main
        sk = lane.repo.cohort_sketch
        if sketch is None:
            sketch, rejected = self._obtain_sketch(fn, path, meta, lane=lane,
                                                   row=row)
            if rejected:
                return True
        if threshold is not None:
            # the self-match exemption is keyed by id AND file: only the
            # same queue file's own pre-crash entry is skipped — a replay
            # forging a previously admitted rider id under a new file is
            # still screened
            hit = sk.match(sketch, threshold, skip_id=sub_id, skip_file=fn)
            if hit is not None:
                self._reject(
                    fn, f"near-duplicate of {hit[0]} (sketch distance "
                        f"{hit[1]:.4f} <= novelty_threshold {threshold:g})",
                    novelty=True, lane=lane)
                return True
        sk.add(sub_id, sketch, file=fn, delta=delta)
        lane.repo.save_cohort_sketch()
        # the sketch history is durable before the row stages: a crash in
        # this window re-screens the row against its own entry on restart,
        # which the id+file skip turns into a no-op, not a self-rejection
        faults.crash_point("service.post_sketch")
        return False

    def _staleness(self, extra: Dict[str, Any],
                   lane: Optional[_Lane] = None) -> Optional[str]:
        repo = (lane or self._main).repo
        lim = self.policy.max_staleness
        base_it = extra.get("base_iteration")
        if lim is None or base_it is None:
            return None
        try:
            base_it = int(base_it)
        except (TypeError, ValueError):  # _rider_error screens this first;
            # stay a per-file reason even if a caller skips that screen
            return (f"malformed rider: base_iteration={base_it!r} "
                    "is not an integer")
        lag = repo.iteration - base_it
        if lag > lim:
            return (f"stale: finetuned from iteration {base_it}, "
                    f"current {repo.iteration} (max_staleness={lim})")
        return None

    # -- fuse policy ----------------------------------------------------
    def _should_fuse(self, lane: _Lane) -> bool:
        n = lane.repo.n_staged
        if n == 0:
            return False
        if lane.failed_cohort_size == n:
            return False  # same cohort just failed; wait for arrivals
        if n >= self.policy.min_cohort:
            return True
        return (self.policy.max_wait_s > 0
                and lane.cohort_since is not None
                and time.time() - lane.cohort_since >= self.policy.max_wait_s)

    def _gc_consumed(self) -> None:
        """Drop queue entries whose rows left their member's staging
        manifest — i.e. whose cohort's publish is durable.  File deleted
        before the entry (ordering (4))."""
        staged = {n: l.repo.staged_spill_files()
                  for n, l in self._lanes.items()}
        changed = False
        for sub_id, e in list(self._entries.items()):
            lane = self._entry_lane(e)
            if f"{QUEUE_DIR}/{e['file']}" in staged[lane.name]:
                continue
            path = os.path.join(lane.queue_dir, e["file"])
            if os.path.exists(path):
                os.remove(path)
            faults.crash_point("service.mid_gc")
            del self._entries[sub_id]
            self._fused_ids += 1
            changed = True
        if changed:
            self._write_queue_manifest()

    def _note_error(self, err: Exception, lane: Optional[_Lane] = None
                    ) -> None:
        lane = lane or self._main
        self._last_error = f"{type(err).__name__}: {err}"
        lane.failed_cohort_size = lane.repo.n_staged
        # the ring (unlike last_error) survives the next clean cycle AND a
        # restart: an error observed once is an error an operator can still
        # see.  Persisted via the queue manifest — errors are rare, so the
        # extra atomic write is off every hot path.
        self._recent_errors = (self._recent_errors + [
            {"t": time.time(), "error": self._last_error}])[-ERROR_RING:]
        self._write_queue_manifest()

    # -- the poll cycle -------------------------------------------------
    def run_once(self) -> Dict[str, Any]:
        """One cycle of the service loop: admit (and route) arrivals,
        dispatch (or finalize) per the cohort policy in every lane, gate
        each publish when armed, GC consumed submissions, cross-fuse the
        family on schedule, publish status, append metrics.  Returns the
        status dict it published."""
        self._cycle += 1
        adm = self._admit()
        gate_event = None
        published = []
        for lane in list(self._lanes.values()):
            it_before = lane.repo.iteration
            if self._should_fuse(lane):
                try:
                    if self.gate is not None:
                        # gated: fuse synchronously.  The wait=False
                        # overlap would let a second cohort dispatch
                        # against a base the gate is about to roll back —
                        # its rows would be consumed by a publish that
                        # never survives.  The gate trades that overlap
                        # for the probe (the service_loop/regression_gate
                        # bench bounds the cost).
                        lane.repo.fuse_pending(wait=True)
                    else:
                        # finalizes any in-flight fuse, then dispatches
                        # the staged cohort with wait=False: the device
                        # crunches while the next cycles keep draining
                        # the queue
                        lane.repo.fuse_pending(wait=False)
                    lane.cohort_since = None
                    self._last_error = None
                    faults.crash_point("service.post_dispatch")
                except RuntimeError as err:  # e.g. all rows rejected
                    self._note_error(err, lane)
            elif lane.repo.inflight:
                # queue drained: publish the in-flight fuse instead of
                # sitting on it until the next arrival
                try:
                    lane.repo.flush()
                    self._last_error = None
                except RuntimeError as err:
                    self._note_error(err, lane)
            if lane.repo.iteration != it_before:
                published.append(lane)
                self._last_pub = lane.name
                self._cross_counter += 1
                faults.crash_point("service.post_publish")
                if self.gate is not None:
                    gate_event = self._apply_gate_verdict(self.gate.check(
                        lane.gate_baseline, lane.repo.flat_base_host()),
                        lane)
        if published:
            self._gc_consumed()
            for lane in published:
                if (self.policy.compact_keep_bases is not None
                        and not lane.repo.inflight):
                    # compact only while quiescent: its flush() would
                    # otherwise synchronously finalize the fuse dispatched
                    # above and kill the wait=False overlap.  Deferred
                    # compaction runs on the drain cycle that publishes
                    # without redispatching.
                    lane.repo.compact(
                        keep_bases=self.policy.compact_keep_bases)
        if (self._routing and self.policy.cross_fuse_every > 0
                and self._cross_counter >= self.policy.cross_fuse_every
                and len(self._lanes) >= 2
                and not any(l.repo.inflight or l.repo.n_staged
                            for l in self._lanes.values())):
            # quiescent on schedule: inter-cluster merge (the counter is
            # persisted, so a crashed daemon neither skips nor repeats
            # the round it already took credit for)
            self._cross_fuse()
        st = self.status(admitted=adm["admitted"],
                         queue_depth=adm["queue_depth"])
        ckpt.save_json_atomic(self._status_path, st)
        self._emit_cycle_metrics(st, gate_event)
        return st

    def _cross_fuse(self) -> None:
        """One inter-cluster merge round (``RepositoryFamily.cross_fuse``)
        plus its service bookkeeping: counters persist, every lane's gate
        re-baselines on its moved base (the merge is an operator-level
        blend of gated bases, not a contributor cohort to gate), and the
        event lands in the metrics series."""
        self.family.cross_fuse()
        self._cross_fuses += 1
        self._cross_counter = 0
        self._write_queue_manifest()
        if self.gate is not None:
            for lane in self._lanes.values():
                self._rebaseline_gate(lane)
        self._emit_metrics({
            "event": "cross_fuse",
            "families": {n: l.repo.iteration
                         for n, l in self._lanes.items()},
            "cross_fuses_total": self._cross_fuses,
        })

    # -- metrics time series --------------------------------------------
    def _emit_metrics(self, record: Dict[str, Any]) -> None:
        """One record onto the append-only ``metrics.jsonl`` time series
        (docs/observability.md).  Advisory state: appends happen after the
        durability-critical writes of their cycle, so a crash can lose a
        record but the series never disagrees with the repository.  The
        daemon is the series' single rotator: once the active file
        reaches ``METRICS_ROTATE_BYTES`` it rolls to ``metrics.jsonl.1``
        (concurrent worker appends are rename-safe; see
        ``repro.checkpoint.io.rotate_jsonl``)."""
        ckpt.append_jsonl(self._metrics_path,
                          {"t": time.time(), **record},
                          rotate_bytes=METRICS_ROTATE_BYTES)

    def _emit_cycle_metrics(self, st: Dict[str, Any],
                            gate_event: Optional[Dict[str, Any]]) -> None:
        """Append the per-cycle record — for every cycle that *changed*
        anything (publish, admission, rejection, error, gate event) plus
        the first cycle.  Idle polls repeat the previous mark and are
        skipped, so a long-lived daemon's series grows with events, not
        wall time — and under sustained serve load the daemon (as the
        single rotator) caps the active file via ``METRICS_ROTATE_BYTES``
        in ``_emit_metrics``."""
        mark = (st["iteration"], st["staged"], st["admitted"],
                st["fused_queue_submissions"], st["rejected_total"],
                st["quarantined_total"], st["rollbacks_total"],
                st["last_error"])
        if mark == self._metrics_mark and gate_event is None:
            return
        self._metrics_mark = mark
        last = st["last_fuse"]
        self._emit_metrics({
            "event": "cycle",
            "cycle": self._cycle,
            "iteration": st["iteration"],
            "queue_depth": st["queue_depth"],
            "staged": st["staged"],
            "inflight": st["inflight"],
            "admitted_this_cycle": st["admitted_this_cycle"],
            "cohort": None if last is None else last["n_contributions"],
            "fuse_latency_s": st["fuse_latency_s"],
            "fused_queue_submissions": st["fused_queue_submissions"],
            "rejected_total": st["rejected_total"],
            "novelty_rejected_total": st["novelty_rejected_total"],
            "quarantined_total": st["quarantined_total"],
            "rollbacks_total": st["rollbacks_total"],
            "probe": None if gate_event is None else gate_event.get("probe"),
            "last_error": st["last_error"],
        })

    def serve_forever(self, *, poll_interval: float = 0.02,
                      max_iterations: Optional[int] = None,
                      idle_timeout: Optional[float] = None,
                      max_poll_interval: Optional[float] = None
                      ) -> Dict[str, Any]:
        """Run poll cycles until stopped: by ``request_stop()`` (signal
        handlers), by the published iteration reaching ``max_iterations``
        (once quiescent), or by ``idle_timeout`` seconds without progress
        — no admission and no publish, queue empty.  An undersized cohort
        held below ``min_cohort`` counts as idle time (its rows are
        durable in the staging manifest and survive the exit).  Returns
        the final status.

        No-progress sleeps back off exponentially (with jitter) from
        ``poll_interval`` up to ``max_poll_interval`` (default: the larger
        of ``poll_interval`` and 0.25s) — the same cap discipline as
        ``ContributorClient.wait_for_iteration`` — and reset on any
        progress.  An in-flight fuse pins the sleep at ``poll_interval``
        so its finalize is never backed off."""
        cap = (max(poll_interval, 0.25) if max_poll_interval is None
               else max(poll_interval, max_poll_interval))
        delay = poll_interval
        last_progress = time.monotonic()
        last_its = {n: l.repo.iteration for n, l in self._lanes.items()}
        while not self._stop:
            st = self.run_once()
            its = {n: l.repo.iteration for n, l in self._lanes.items()}
            progress = st["admitted_this_cycle"] or its != last_its
            last_its = its
            if progress:
                last_progress = time.monotonic()
                delay = poll_interval
            idle = (st["queue_depth"] == 0 and st["staged"] == 0
                    and not st["inflight"])
            if (max_iterations is not None and idle
                    and min(its.values()) >= max_iterations):
                # under routing EVERY member must reach the target — main
                # hitting it first must not strand another member's queue
                break
            if (idle_timeout is not None and st["queue_depth"] == 0
                    and not st["inflight"]
                    and time.monotonic() - last_progress >= idle_timeout):
                break
            if not progress:
                # nothing moved this cycle (empty queue, undersized or
                # screen-stuck cohort): sleep instead of busy-spinning the
                # scan/status write. An in-flight fuse finalizes next cycle.
                if st["inflight"]:
                    time.sleep(poll_interval)
                else:
                    time.sleep(random.uniform(delay / 2, delay))
                    delay = min(delay * 2, cap)
        return self.close()

    def request_stop(self) -> None:
        self._stop = True

    def close(self) -> Dict[str, Any]:
        """Quiesce: finalize any in-flight fuse, GC, publish a final
        status with ``running=False``.  Staged-but-unfused rows stay in
        the (durable) manifest for the next service instance."""
        self._stop = True
        for lane in list(self._lanes.values()):
            try:
                lane.repo.flush()
            except RuntimeError as err:
                self._note_error(err, lane)
        self._gc_consumed()
        st = self.status()
        st["running"] = False
        ckpt.save_json_atomic(self._status_path, st)
        return st

    # -- status endpoint ------------------------------------------------
    def status(self, *, admitted: int = 0,
               queue_depth: Optional[int] = None) -> Dict[str, Any]:
        """The fields contributors (and operators) poll; persisted
        atomically to ``<root>/service_status.json`` every cycle.  See
        docs/service_loop.md for the field reference.  ``queue_depth=``
        reuses the admit pass's scan (one directory listing per cycle, not
        two); standalone calls re-scan.

        Aggregate fields (``staged``, ``inflight``, ``fuses``,
        ``fused_contributions``) sum/any over the whole family;
        ``iteration`` stays the main base's.  Under routing a
        ``families`` map carries each member's own iteration/staging/gate
        view, plus the recent ``routes`` ring and the spawn/cross-fuse
        totals."""
        lanes = self._lanes.values()
        lh = (self._lanes.get(self._last_pub) or self._main).repo.history
        last = lh[-1] if lh else None
        st = {
            "iteration": self.repo.iteration,
            "queue_depth": (len(self._scan_new()) if queue_depth is None
                            else queue_depth),
            "staged": sum(l.repo.n_staged for l in lanes),
            "inflight": any(l.repo.inflight for l in lanes),
            "admitted": len(self._entries),
            "admitted_this_cycle": admitted,
            "fuses": sum(len(l.repo.history) for l in lanes),
            "fused_contributions": sum(r.n_contributions for l in lanes
                                       for r in l.repo.history),
            "fused_queue_submissions": self._fused_ids,
            "rejected_total": self._rejected,
            "novelty_rejected_total": self._novelty_rejected,
            "novelty_screen": self.policy.novelty_threshold is not None,
            "sketch_entries": (None if self.repo.cohort_sketch is None
                               else len(self.repo.cohort_sketch)),
            "recent_rejects": list(self._rejects),
            "gate": self.gate is not None,
            "quarantined_total": self._quarantined,
            "rollbacks_total": self._rollbacks,
            "last_gate": self._last_gate,
            "routing": self._routing,
            "fuse_latency_s": last.wall_time if last else None,
            "last_fuse": None if last is None else {
                "iteration": last.iteration,
                "n_contributions": last.n_contributions,
                "n_accepted": last.n_accepted,
                "op": last.op,
                "wall_time": last.wall_time,
            },
            "last_error": self._last_error,
            "recent_errors": list(self._recent_errors),
            "serving": self._serving_state(),
            "pid": os.getpid(),
            "running": not self._stop,
            "updated_at": time.time(),
        }
        if self._routing:
            st["families"] = {
                name: {
                    "iteration": lane.repo.iteration,
                    "staged": lane.repo.n_staged,
                    "inflight": lane.repo.inflight,
                    "fuses": len(lane.repo.history),
                    "fused_contributions": sum(
                        r.n_contributions for r in lane.repo.history),
                    "gate_iteration": lane.gate_iteration,
                    "last_gate": lane.last_gate,
                } for name, lane in self._lanes.items()}
            st["routes"] = list(self._routes)
            st["families_spawned_total"] = self._spawned
            st["cross_fuses_total"] = self._cross_fuses
        return st

    def _serving_state(self) -> Optional[Dict[str, Any]]:
        """The hot-swap worker-state namespace, embedded read-only (None
        when no worker ever served this root).

        A solo worker's ``serving_state.json`` passes through unchanged
        (the historical status shape).  When namespaced pool files
        (``serving_state-<id>.json``) exist, the block becomes an
        aggregate: the per-worker map plus rollups — summed request/swap
        counters, summed inflight, and ``iteration`` set only when every
        worker agrees (mid-swap divergence surfaces as ``None`` rather
        than a misleading single number)."""
        root = self.repo.root
        workers: Dict[str, Dict[str, Any]] = {}
        try:
            names = sorted(os.listdir(root))
        except FileNotFoundError:
            names = []
        for fn in names:
            if (fn.startswith("serving_state-") and fn.endswith(".json")):
                try:
                    workers[fn[len("serving_state-"):-len(".json")]] = \
                        ckpt.load_json(os.path.join(root, fn))
                except (FileNotFoundError, ValueError):
                    continue  # mid-replace or torn: skip, not fatal
        solo = None
        try:
            solo = ckpt.load_json(os.path.join(root, SERVING_STATE_FILE))
        except (FileNotFoundError, ValueError):
            pass
        if not workers:
            return solo   # legacy single-worker shape (or None)
        if solo is not None:
            workers.setdefault(solo.get("worker", "solo"), solo)
        iters = {w.get("iteration") for w in workers.values()}
        agg: Dict[str, Any] = {
            "workers": workers,
            "n_workers": len(workers),
            "iteration": iters.pop() if len(iters) == 1 else None,
            "swapping": any(w.get("swapping") for w in workers.values()),
        }
        for key in ("swaps_total", "live_swaps", "requests_total",
                    "requests_pinned_across_swaps", "requests_batched",
                    "inflight"):
            agg[key] = sum(int(w.get(key) or 0) for w in workers.values())
        agg["versions_served"] = sorted(
            {v for w in workers.values()
             for v in (w.get("versions_served") or [])})
        return agg
