"""Least-loaded router over a pool of serving workers.

The router is the single entry point in front of N ``ServingWorker``s
(docs/serving.md).  It speaks to workers through the small
``WorkerEndpoint`` interface — ``LocalEndpoint`` wraps an in-process
worker (tests, single-process benches), ``repro.serve.worker_pool``
provides the cross-process socket endpoint — and applies three policies:

* **least-loaded dispatch** — the router tracks its OWN per-endpoint
  in-flight count (it is the single dispatcher, so its view is exact and
  never stale, unlike the state-file heartbeat) and picks the live
  endpoint with the fewest outstanding requests.
* **drain on swap** — an endpoint whose health snapshot says
  ``swapping`` is deprioritized (a large load penalty, not exclusion:
  if every worker is mid-swap, requests still go somewhere) so new work
  flows around a worker busy transferring the next base.
* **exactly-once re-route** — a request that fails in flight because its
  worker died (kill -9 included: the connection drops or resets) is
  re-dispatched to a different live endpoint AT MOST once
  (``max_reroutes``), and the dead endpoint is marked down until its
  health probe recovers (a restarted worker re-registers by heartbeating
  its state file).  A second transport failure surfaces to the caller —
  unbounded retries could duplicate arbitrarily much work.  A
  ``queue_full`` shed from an overloaded worker fails over under the
  same single-retry budget; a second shed means the POOL is saturated
  and the caller must see it.

Version pinning is per worker and unchanged by routing: each response
carries the iteration its worker pinned at execution start.  The router
never mixes workers within one request, so the one-base-per-response
guarantee proven for a single worker holds across the pool.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.scheduler import RequestRejected

__all__ = ["EndpointDied", "LocalEndpoint", "RoutedResult", "Router"]

# a swapping worker counts as this many extra in-flight requests when
# the router compares loads (drain, don't exclude)
SWAP_DRAIN_PENALTY = 1_000
# a health snapshot older than this is stale: the worker is presumed
# dead until it heartbeats again (the worker heartbeats every ~0.25s)
HEALTH_STALE_S = 5.0


class EndpointDied(RuntimeError):
    """Transport-level failure: the worker behind the endpoint is gone
    (connection refused/reset mid-request).  Distinct from an explicit
    ``RequestRejected``, which is the worker *alive and shedding*."""


class RoutedResult:
    """One routed generation: the worker's response plus routing info."""

    __slots__ = ("tokens", "iteration", "steps", "batch_size", "latency_s",
                 "worker_id", "rerouted")

    def __init__(self, *, tokens: np.ndarray, iteration: int, steps: int,
                 batch_size: int, latency_s: float, worker_id: str,
                 rerouted: bool):
        self.tokens = tokens
        self.iteration = int(iteration)
        self.steps = int(steps)
        self.batch_size = int(batch_size)
        self.latency_s = float(latency_s)
        self.worker_id = str(worker_id)
        self.rerouted = bool(rerouted)


class LocalEndpoint:
    """An in-process ``ServingWorker`` as a routable endpoint (tests and
    single-process benches; the socket endpoint lives in worker_pool)."""

    def __init__(self, worker, endpoint_id: Optional[str] = None):
        self.worker = worker
        self.id = str(endpoint_id or worker.worker_id or worker.name)

    def health(self) -> Optional[Dict[str, Any]]:
        return self.worker.serve_state()

    def generate(self, prompt: np.ndarray, *, max_new_tokens: int,
                 deadline_s: Optional[float] = None) -> Dict[str, Any]:
        res = self.worker.generate(np.asarray(prompt)[None, :],
                                   max_new_tokens=max_new_tokens,
                                   deadline_s=deadline_s)
        return {"tokens": np.asarray(res.tokens)[0],
                "iteration": res.iteration, "steps": res.steps,
                "batch_size": res.batch_size, "latency_s": res.latency_s}


class Router:
    """Dispatch requests across endpoints; survive worker death.

    ``route`` is thread-safe (N client threads share one router).  An
    endpoint marked dead is probed again lazily: every ``route`` call
    re-admits endpoints whose health snapshot became fresh again.
    """

    def __init__(self, endpoints: List[Any], *, max_reroutes: int = 1):
        if not endpoints:
            raise ValueError("router needs at least one endpoint")
        self.endpoints = list(endpoints)
        self.max_reroutes = int(max_reroutes)
        self._lock = threading.Lock()
        self._rr = 0   # rotating tie-break so equal loads round-robin
        self._inflight: Dict[str, int] = {e.id: 0 for e in self.endpoints}
        self._dead: Dict[str, bool] = {e.id: False for e in self.endpoints}
        self.routed_total = 0
        self.reroutes_total = 0
        self.failed_total = 0
        self.shed_total = 0            # queue_full surfaced to the caller
        self.per_worker: Dict[str, int] = {e.id: 0 for e in self.endpoints}

    # -- health / selection ---------------------------------------------
    def _probe(self, ep) -> Optional[Dict[str, Any]]:
        try:
            h = ep.health()
        except Exception:  # noqa: BLE001 - unreadable health = dead
            return None
        if h is None:
            return None
        updated = h.get("updated_at")
        if updated is not None and time.time() - float(updated) > HEALTH_STALE_S:
            return None
        return h

    def _pick(self, exclude: set) -> Optional[Any]:
        """The least-loaded live endpoint (drain penalty for swapping
        workers), or None when every candidate is dead/excluded."""
        best, best_load = None, None
        with self._lock:
            inflight = dict(self._inflight)
            dead = dict(self._dead)
            self._rr += 1
            offset = self._rr
        n = len(self.endpoints)
        for ep in (self.endpoints[(offset + i) % n] for i in range(n)):
            if ep.id in exclude:
                continue
            h = self._probe(ep)
            if h is None:
                with self._lock:
                    self._dead[ep.id] = True
                continue
            if dead.get(ep.id):
                # fresh health from a previously-dead endpoint: a
                # restarted worker re-admits itself via its heartbeat
                with self._lock:
                    self._dead[ep.id] = False
            load = inflight.get(ep.id, 0)
            if h.get("swapping"):
                load += SWAP_DRAIN_PENALTY
            if best_load is None or load < best_load:
                best, best_load = ep, load
        return best

    # -- dispatch --------------------------------------------------------
    def route(self, prompt: np.ndarray, *, max_new_tokens: int = 16,
              deadline_s: Optional[float] = None) -> RoutedResult:
        """Dispatch one single-row request; re-route at most
        ``max_reroutes`` times on worker death or shed."""
        prompt = np.asarray(prompt)
        tried: set = set()
        attempts = 0
        last_err: Optional[BaseException] = None
        while attempts <= self.max_reroutes:
            ep = self._pick(tried)
            if ep is None:
                break
            tried.add(ep.id)
            with self._lock:
                self._inflight[ep.id] = self._inflight.get(ep.id, 0) + 1
            try:
                out = ep.generate(prompt, max_new_tokens=max_new_tokens,
                                  deadline_s=deadline_s)
            except (EndpointDied, RequestRejected) as err:
                last_err = err
                if isinstance(err, EndpointDied):
                    with self._lock:
                        self._dead[ep.id] = True
                attempts += 1
                continue
            finally:
                with self._lock:
                    self._inflight[ep.id] -= 1
            with self._lock:
                self.routed_total += 1
                self.per_worker[ep.id] = self.per_worker.get(ep.id, 0) + 1
                if attempts > 0:
                    self.reroutes_total += 1
            return RoutedResult(
                tokens=np.asarray(out["tokens"]),
                iteration=out["iteration"], steps=out["steps"],
                batch_size=out.get("batch_size", 1),
                latency_s=out.get("latency_s", 0.0),
                worker_id=ep.id, rerouted=attempts > 0)
        with self._lock:
            self.failed_total += 1
            if isinstance(last_err, RequestRejected):
                self.shed_total += 1
        if last_err is not None:
            raise last_err
        raise EndpointDied("no live endpoint to route to")

    # -- observability ---------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "endpoints": [e.id for e in self.endpoints],
                "dead": sorted(k for k, v in self._dead.items() if v),
                "inflight": dict(self._inflight),
                "routed_total": self.routed_total,
                "reroutes_total": self.reroutes_total,
                "failed_total": self.failed_total,
                "shed_total": self.shed_total,
                "per_worker": dict(self.per_worker),
            }
