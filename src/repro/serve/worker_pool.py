"""WorkerPool: N ServingWorker processes behind socket endpoints.

Scale-out for the fuse-to-serve path (docs/serving.md): each pool member
is its OWN process running a ``ServingWorker`` — its own follower
(polling ``repository.json`` cross-process), its own engine, its own
namespaced ``serving_state-<id>.json`` — fronted by a tiny
newline-delimited-JSON TCP protocol on a loopback port.  The parent
``WorkerPool`` spawns the children (``python -m repro.serve.worker_pool``
is the child entry point), discovers each child's port from its state
file, and hands out ``SocketEndpoint``s that plug into
``repro.serve.router.Router``.

Isolation is the point: a worker kill -9'd mid-swap takes down one
process — its state file goes stale, the router marks it dead on the
transport error and re-routes the in-flight-failed request exactly once,
and every other worker keeps serving.  The repository's durability
discipline (base npz durable before ``repository.json`` names it) means
a restarted worker can only ever adopt a published, uncorrupted base.

Protocol (one JSON object per line, request/response):

    {"op": "generate", "prompt": [..], "max_new_tokens": 4}
      -> {"ok": true, "tokens": [..], "iteration": 3, "steps": 4,
          "batch_size": 2, "latency_s": 0.01}
      -> {"ok": false, "rejected": "queue_full"}     (worker shedding)
      -> {"ok": false, "error": "..."}               (worker error)
    {"op": "ping"}  -> {"ok": true, "iteration": 3}

The child's ``--engine value`` selects a closed-form fake engine
(generation returns the served tree's scalar ``w`` value, so a token
mismatch IS a version tear) — the cross-process pinning and kill-matrix
tests use it to verify exact served weights without paying a real
model; ``--engine real`` (the default) builds the ``Engine`` from a
reduced arch config.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import socketserver
import subprocess
import sys
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint import io as ckpt
from repro.serve.cold_service import serving_state_filename
from repro.serve.router import EndpointDied, Router
from repro.serve.scheduler import RequestRejected

__all__ = ["SocketEndpoint", "WorkerPool"]

_CONNECT_TIMEOUT_S = 5.0


class SocketEndpoint:
    """A pool child as a routable endpoint: health from its namespaced
    state file, generation over the loopback socket.  Transport failures
    (refused, reset, EOF, timeout) raise ``EndpointDied``; an alive
    worker's explicit shed raises ``RequestRejected`` — the router
    treats the two differently."""

    def __init__(self, root: str, worker_id: str, *,
                 request_timeout_s: float = 120.0):
        self.root = root
        self.id = str(worker_id)
        self.request_timeout_s = float(request_timeout_s)
        self._port: Optional[int] = None

    def health(self) -> Optional[Dict[str, Any]]:
        try:
            return ckpt.load_json(
                os.path.join(self.root, serving_state_filename(self.id)))
        except (FileNotFoundError, ValueError):
            return None

    def _resolve_port(self) -> int:
        # re-read on every miss: a restarted worker re-registers a NEW
        # port through the same state file
        h = self.health()
        if not h or not h.get("port"):
            raise EndpointDied(f"{self.id}: no registered port")
        self._port = int(h["port"])
        return self._port

    def _call(self, payload: Dict[str, Any]) -> Dict[str, Any]:
        port = self._port or self._resolve_port()
        try:
            with socket.create_connection(("127.0.0.1", port),
                                          timeout=_CONNECT_TIMEOUT_S) as sk:
                sk.settimeout(self.request_timeout_s)
                sk.sendall((json.dumps(payload) + "\n").encode())
                buf = b""
                while not buf.endswith(b"\n"):
                    chunk = sk.recv(65536)
                    if not chunk:
                        raise EndpointDied(f"{self.id}: connection closed "
                                           "mid-request")
                    buf += chunk
        except (OSError, socket.timeout) as err:
            self._port = None   # stale port: re-resolve next time
            raise EndpointDied(f"{self.id}: {err}") from err
        return json.loads(buf.decode())

    def ping(self) -> Dict[str, Any]:
        return self._call({"op": "ping"})

    def generate(self, prompt: np.ndarray, *, max_new_tokens: int,
                 deadline_s: Optional[float] = None) -> Dict[str, Any]:
        out = self._call({
            "op": "generate",
            "prompt": np.asarray(prompt).tolist(),
            "max_new_tokens": int(max_new_tokens),
            "deadline_s": deadline_s,
        })
        if not out.get("ok"):
            if out.get("rejected"):
                raise RequestRejected(out["rejected"], self.id)
            raise EndpointDied(f"{self.id}: {out.get('error')}")
        out["tokens"] = np.asarray(out["tokens"])
        return out


class WorkerPool:
    """Spawn and manage N serving-worker processes under one root.

    ``child_env`` maps worker id -> extra environment for that child —
    the kill-matrix tests arm ``REPRO_CRASH_POINT`` on one member so it
    dies at an exact swap seam while its peers keep serving.  Children
    inherit the parent environment minus ``XLA_FLAGS`` (a forced
    fake-device mesh belongs to the fusion daemon, not the CPU serving
    children)."""

    def __init__(self, root: str, n_workers: int, *, arch: str = None,
                 engine: str = "real", max_len: int = 64,
                 poll: float = 0.02, batch: bool = False,
                 queue_depth: int = 64, max_batch: int = 8,
                 batch_wait_s: float = 0.002, family: Optional[str] = None,
                 warm: Optional[tuple] = None,
                 env: Optional[Dict[str, str]] = None,
                 child_env: Optional[Dict[str, Dict[str, str]]] = None):
        if engine == "real" and not arch:
            raise ValueError("engine='real' needs an arch name")
        self.root = str(root)
        self.worker_ids = [f"w{i}" for i in range(int(n_workers))]
        self.arch, self.engine = arch, engine
        self.max_len, self.poll = int(max_len), float(poll)
        self.batch = bool(batch)
        self.queue_depth, self.max_batch = int(queue_depth), int(max_batch)
        self.batch_wait_s = float(batch_wait_s)
        self.family = family
        # (prompt_len, max_new_tokens) to pre-compile before admitting
        # traffic: the child warms its engine's jit cache across the
        # batch buckets at this shape, so a cold worker doesn't stall
        # its first clients for seconds per bucket
        self.warm = warm
        self.env = dict(env or {})          # applied to every child
        self.child_env = dict(child_env or {})   # per-worker overrides
        self._procs: Dict[str, subprocess.Popen] = {}
        self._logs: Dict[str, Any] = {}
        self.endpoints: List[SocketEndpoint] = []

    def _spawn(self, wid: str) -> subprocess.Popen:
        # repro is a namespace package (no __init__.py): derive src/ from
        # its search path, not __file__ (which is None)
        import repro
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.update(self.env)
        env.update(self.child_env.get(wid, {}))
        cmd = [sys.executable, "-m", "repro.serve.worker_pool",
               "--root", self.root, "--worker-id", wid,
               "--engine", self.engine, "--max-len", str(self.max_len),
               "--poll", str(self.poll),
               "--queue-depth", str(self.queue_depth),
               "--max-batch", str(self.max_batch),
               "--batch-wait", str(self.batch_wait_s)]
        if self.arch:
            cmd += ["--arch", self.arch]
        if self.warm:
            cmd += ["--warm", f"{self.warm[0]},{self.warm[1]}"]
        if self.batch:
            cmd += ["--batch"]
        if self.family:
            cmd += ["--family", self.family]
        log = open(os.path.join(self.root, f"worker-{wid}.log"), "ab")
        self._logs[wid] = log
        return subprocess.Popen(cmd, env=env, stdout=log,
                                stderr=subprocess.STDOUT)

    def start(self, *, timeout: float = 60.0) -> "WorkerPool":
        """Spawn every child and wait until each registered its port."""
        for wid in self.worker_ids:
            self._procs[wid] = self._spawn(wid)
        self.endpoints = [SocketEndpoint(self.root, wid)
                          for wid in self.worker_ids]
        deadline = time.monotonic() + timeout
        for ep in self.endpoints:
            while True:
                h = ep.health()
                if h and h.get("port"):
                    break
                proc = self._procs[ep.id]
                if proc.poll() is not None:
                    raise RuntimeError(
                        f"pool child {ep.id} exited with "
                        f"{proc.returncode} before registering (see "
                        f"worker-{ep.id}.log)")
                if time.monotonic() > deadline:
                    raise TimeoutError(f"pool child {ep.id} never "
                                       "registered a port")
                time.sleep(0.02)
        return self

    def wait_ready(self, *, iteration: Optional[int] = None,
                   timeout: float = 60.0) -> None:
        """Block until every LIVE worker adopted a base (optionally a
        specific iteration).  Workers that already died (e.g. an armed
        crash point fired) are skipped — the router's job is exactly to
        survive them."""
        deadline = time.monotonic() + timeout
        for ep in self.endpoints:
            while True:
                proc = self._procs.get(ep.id)
                if proc is not None and proc.poll() is not None:
                    break
                h = ep.health()
                it = None if h is None else h.get("iteration")
                if it is not None and (iteration is None
                                       or it == iteration):
                    break
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"worker {ep.id} never adopted "
                        f"{'a base' if iteration is None else iteration}")
                time.sleep(0.02)

    def router(self, **kw) -> Router:
        return Router(self.endpoints, **kw)

    def kill(self, wid: str) -> None:
        """kill -9 one member (the fault the router must survive)."""
        self._procs[wid].kill()
        self._procs[wid].wait()

    def alive(self) -> List[str]:
        return [wid for wid, p in self._procs.items() if p.poll() is None]

    def stop(self, *, timeout: float = 30.0) -> Dict[str, int]:
        """SIGTERM every live child (clean shutdown: final state persist)
        and reap; returns exit codes."""
        codes: Dict[str, int] = {}
        for wid, proc in self._procs.items():
            if proc.poll() is None:
                proc.terminate()
        for wid, proc in self._procs.items():
            try:
                codes[wid] = proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                proc.kill()
                codes[wid] = proc.wait()
        for log in self._logs.values():
            log.close()
        self._logs.clear()
        return codes

    def states(self) -> Dict[str, Optional[Dict[str, Any]]]:
        return {ep.id: ep.health() for ep in self.endpoints}


# ---------------------------------------------------------------------------
# child entry point
# ---------------------------------------------------------------------------


class _ValueEngine:
    """Closed-form fake engine (mirrors the hot_swap test fake): tokens
    are the served tree's scalar ``w`` value — any batch shape, so the
    scheduler path is exercised too."""

    def __init__(self, cfg, params, max_len):
        self.params = params

    def generate(self, prompts, *, max_new_tokens=16, params=None):
        import types
        p = self.params if params is None else params
        val = float(np.asarray(p["w"]).reshape(-1)[0])
        toks = np.full((prompts.shape[0], prompts.shape[1] + max_new_tokens),
                       val, np.float32)
        return types.SimpleNamespace(tokens=toks,
                                     prompt_len=int(prompts.shape[1]),
                                     steps=int(max_new_tokens))


def _child_main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="serving-pool worker process (docs/serving.md)")
    p.add_argument("--root", required=True)
    p.add_argument("--worker-id", required=True)
    p.add_argument("--arch", default=None)
    p.add_argument("--engine", choices=("real", "value"), default="real")
    p.add_argument("--family", default=None)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--poll", type=float, default=0.02)
    p.add_argument("--batch", action="store_true")
    p.add_argument("--queue-depth", type=int, default=64)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--batch-wait", type=float, default=0.002)
    p.add_argument("--warm", default=None, metavar="T,N",
                   help="pre-compile generate for prompt_len T / "
                        "max_new N across the batch buckets before "
                        "serving (first adoption blocks until warm)")
    args = p.parse_args(argv)

    from repro.serve.hot_swap import ServingWorker
    if args.engine == "value":
        cfg, factory = None, _ValueEngine
    else:
        from repro.configs import get_config, reduce_config
        cfg, factory = reduce_config(get_config(args.arch)), None
    worker = ServingWorker(
        cfg, args.root, family=args.family, max_len=args.max_len,
        name=args.worker_id, worker_id=args.worker_id,
        engine_factory=factory, batch_requests=args.batch,
        queue_depth=args.queue_depth, max_batch=args.max_batch,
        batch_wait_s=args.batch_wait)

    if args.warm:
        # adopt the first published base and pre-compile the bucketed
        # generate shapes NOW — a cold jit compile costs seconds per
        # shape, which must not stall the first clients (the parent's
        # start() waits on port registration, which happens after this)
        from repro.serve.scheduler import BATCH_BUCKETS
        warm_t, warm_n = (int(x) for x in args.warm.split(","))
        deadline = time.monotonic() + 120.0
        while worker.current_iteration is None:
            if worker.poll_once():
                break
            if time.monotonic() > deadline:
                break   # nothing published yet: serve cold
            time.sleep(0.05)
        if worker._engine is not None:
            dummy = np.full((1, warm_t), 2, np.int32)
            shapes = [b for b in BATCH_BUCKETS
                      if b <= args.max_batch] if args.batch else [1]
            for b in shapes:
                worker._engine.generate(np.repeat(dummy, b, axis=0),
                                        max_new_tokens=warm_n)

    class Handler(socketserver.StreamRequestHandler):
        def handle(self):
            for line in self.rfile:
                try:
                    req = json.loads(line.decode())
                    out = self._dispatch(req)
                except Exception as err:  # noqa: BLE001 - report, don't die
                    out = {"ok": False,
                           "error": f"{type(err).__name__}: {err}"}
                self.wfile.write((json.dumps(out) + "\n").encode())
                self.wfile.flush()

        def _dispatch(self, req):
            if req.get("op") == "ping":
                return {"ok": True, "iteration": worker.current_iteration}
            if req.get("op") != "generate":
                return {"ok": False, "error": f"unknown op {req.get('op')}"}
            prompt = np.asarray(req["prompt"])[None, :]
            try:
                res = worker.generate(
                    prompt, max_new_tokens=int(req["max_new_tokens"]),
                    deadline_s=req.get("deadline_s"))
            except RequestRejected as err:
                return {"ok": False, "rejected": err.reason}
            return {"ok": True, "tokens": np.asarray(res.tokens)[0].tolist(),
                    "iteration": res.iteration, "steps": res.steps,
                    "batch_size": res.batch_size,
                    "latency_s": res.latency_s}

    class Server(socketserver.ThreadingTCPServer):
        daemon_threads = True
        allow_reuse_address = True

    srv = Server(("127.0.0.1", 0), Handler)
    port = srv.server_address[1]
    worker.extra_state["port"] = port
    worker.extra_state["worker_id"] = args.worker_id
    # register the port BEFORE the watch thread starts: the parent pool
    # blocks on this state file
    worker._persist_state()
    worker.start(interval=args.poll)

    def _term(signum, frame):
        threading.Thread(target=srv.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    print(f"[pool-worker] {args.worker_id} serving on 127.0.0.1:{port} "
          f"(engine={args.engine}, batch={args.batch})", flush=True)
    try:
        srv.serve_forever(poll_interval=0.1)
    finally:
        srv.server_close()
        st = worker.stop()
        print(f"[pool-worker] {args.worker_id} stopped at iteration "
              f"{st['iteration']}: {st['requests_total']} requests, "
              f"{st['swaps_total']} swaps", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(_child_main())
