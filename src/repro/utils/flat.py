"""FlatParams — the contiguous flat-buffer parameter representation.

The Repository hot path (screen + fuse, paper §3/§9) is HBM-bandwidth-bound
streaming arithmetic over whole checkpoints.  Operating per-leaf costs one
device dispatch per (leaf, contributor) pair and forces the Pallas kernel
into one padded launch per leaf.  ``FlatSpec`` fixes the layout once:

* a **static spec** — an ordered tuple of ``(path, shape, dtype, offset)``
  records plus the treedef — hashable, so it can ride through ``jax.jit``
  as a static argument and be serialized next to checkpoints;
* a **1-D buffer** of ``spec.size`` elements in a single storage dtype
  (bf16 if every floating leaf is bf16, else f32), so K contributions stack
  into one ``[K, N]`` operand and the whole model fuses in ONE kernel launch.

Round-trips are views/reshapes inside jit (XLA fuses the slicing into the
consumer); nothing here allocates per-leaf Python-side temporaries beyond
the single concatenated buffer.

``ShardedFlatSpec`` layers a block-cyclic shard layout on top: it maps the
flat ``[N]`` buffer (and the stacked ``[K, N]`` staging buffer) onto a
``[S, shard_len]`` grid whose leading dim lands on a mesh axis, so the
Repository's staging and fuse can be distributed without any device ever
holding the full buffer (see docs/sharding.md).

``StagedBuffer`` and ``BufferPair`` are the staging-side primitives of the
async double-buffered Repository (docs/async_repository.md): a
``StagedBuffer`` is the explicit handle the fuse entry points accept (one
stacked cohort operand, single-device ``[K, N]`` or sharded
``[K, S, shard_len]``), and a ``BufferPair`` is the front/back pair of
staging sides — uploads append to the front while the back is being fused
on device.
"""
from __future__ import annotations

import functools
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import path_str

# minimum 1-D tile granularity on TPU (8 sublanes x 128 lanes); the Pallas
# kernel and the block-cyclic shard layout share this alignment so a shard's
# slice is always a whole number of kernel tiles
LANE = 1024
DEFAULT_SHARD_BLOCK = 64 * 1024

# buckets per row-sketch statistic (kernels/ops.row_sketch): small enough
# that a sketch is a few hundred bytes of JSON, large enough that distinct
# finetunes land distinct bucket profiles
SKETCH_BUCKETS = 32


@dataclass(frozen=True)
class LeafSpec:
    path: str
    shape: Tuple[int, ...]
    dtype: str          # canonical dtype name, e.g. "float32", "bfloat16"
    offset: int         # element offset into the flat buffer
    size: int           # number of elements

    def slice_of(self, buf: jax.Array) -> jax.Array:
        return buf[self.offset : self.offset + self.size].reshape(self.shape)


@dataclass(frozen=True)
class FlatSpec:
    """Static description of a pytree's flat layout.  Hashable/comparable so
    two checkpoints with the same architecture share one spec (and one jit
    cache entry)."""

    leaves: Tuple[LeafSpec, ...]
    treedef: Any                 # jax PyTreeDef (hashable)
    size: int                    # total elements
    dtype: str                   # storage dtype of the flat buffer

    # -- construction ---------------------------------------------------
    @classmethod
    def from_tree(cls, tree) -> "FlatSpec":
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs: List[LeafSpec] = []
        off = 0
        all_bf16 = True
        for path, leaf in flat:
            arr = jnp.asarray(leaf)
            n = int(np.prod(arr.shape)) if arr.shape else 1
            specs.append(LeafSpec(path_str(path), tuple(arr.shape), arr.dtype.name, off, n))
            if arr.dtype != jnp.bfloat16:
                all_bf16 = False
            off += n
        storage = "bfloat16" if (specs and all_bf16) else "float32"
        return cls(tuple(specs), treedef, off, storage)

    # -- round trips ----------------------------------------------------
    def flatten(self, tree) -> jax.Array:
        """Pytree -> contiguous [size] buffer in the storage dtype.

        Concrete leaves on the CPU backend are concatenated through numpy —
        XLA:CPU's many-operand concatenate is ~25x slower than a memcpy
        (measured: 94ms vs 3.9ms for 58 leaves / 4 MB) and this staging
        step IS the Repository upload hot path.  Tracers (or accelerator
        backends, where device->host would be the slow path) go through a
        cached jitted concatenation instead — one dispatch per call, not
        one per leaf."""
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        if len(flat) != len(self.leaves):
            raise ValueError(
                f"tree has {len(flat)} leaves, spec expects {len(self.leaves)}")
        leaves = []
        for spec, (path, leaf) in zip(self.leaves, flat):
            path = path_str(path)
            if path != spec.path:
                raise ValueError(f"leaf path {path!r} != spec path {spec.path!r}")
            shape = tuple(jnp.shape(leaf))
            if shape != spec.shape:
                raise ValueError(
                    f"leaf {spec.path}: shape {shape} != spec {spec.shape}")
            leaves.append(leaf)
        concrete = not any(isinstance(l, jax.core.Tracer) for l in leaves)
        if concrete and jax.default_backend() == "cpu":
            dt = jnp.dtype(self.dtype)
            parts = [np.ravel(np.asarray(l)).astype(dt, copy=False) for l in leaves]
            buf = np.concatenate(parts) if parts else np.zeros((0,), dt)
            return jnp.asarray(buf)
        return _flatten_fn(self)(tuple(leaves))

    def unflatten(self, buf) -> Any:
        """Contiguous [size] buffer -> pytree with original shapes/dtypes."""
        buf = jnp.asarray(buf)
        if buf.shape != (self.size,):
            raise ValueError(f"buffer shape {buf.shape} != ({self.size},)")
        return jax.tree.unflatten(self.treedef, _unflatten_fn(self)(buf))

    # -- serialization (for on-disk spill / flat checkpoints) -----------
    def to_json(self) -> Dict[str, Any]:
        return {
            "dtype": self.dtype,
            "size": self.size,
            "leaves": [
                {"path": s.path, "shape": list(s.shape), "dtype": s.dtype,
                 "offset": s.offset, "size": s.size}
                for s in self.leaves
            ],
        }

    @classmethod
    def from_json(cls, meta: Dict[str, Any]) -> "FlatSpec":
        """Rebuild a spec from its JSON form.  The treedef is reconstructed
        as a nested dict keyed by the path components — the same convention
        the npz checkpoint format uses — so a spec round-tripped through disk
        unflattens to a plain dict tree.

        The leaf tuple is re-derived by flattening that reconstructed dict
        (with each LeafSpec as its own placeholder), NOT taken in JSON file
        order: dicts flatten in sorted-key order, which differs from the
        original flatten order whenever paths do not sort lexicographically
        (e.g. list indices '0','1',...,'10' sort as '0','1','10','2',...).
        The recorded offsets keep every leaf pointing at its original slice
        of the buffer regardless of the new ordering."""
        nested: Dict[str, Any] = {}
        for s in meta["leaves"]:
            node = nested
            parts = s["path"].split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = LeafSpec(
                s["path"], tuple(s["shape"]), s["dtype"], s["offset"], s["size"])
        flat, treedef = jax.tree_util.tree_flatten(
            nested, is_leaf=lambda x: isinstance(x, LeafSpec))
        return cls(tuple(flat), treedef, int(meta["size"]), meta["dtype"])


@functools.lru_cache(maxsize=128)
def _flatten_fn(spec: FlatSpec):
    dt = jnp.dtype(spec.dtype)

    @jax.jit
    def f(leaves):
        if not leaves:
            return jnp.zeros((0,), dt)
        return jnp.concatenate([jnp.ravel(l).astype(dt) for l in leaves])

    return f


@functools.lru_cache(maxsize=128)
def _unflatten_fn(spec: FlatSpec):
    casts = [(s, jnp.dtype(s.dtype)) for s in spec.leaves]

    @jax.jit
    def f(buf):
        return [s.slice_of(buf).astype(dt) for s, dt in casts]

    return f


def flatten_tree(tree) -> Tuple[jax.Array, FlatSpec]:
    """Convenience: build the spec and flatten in one call."""
    spec = FlatSpec.from_tree(tree)
    return spec.flatten(tree), spec


def row_checksum(buf) -> str:
    """CRC32 (hex) over a flat row's raw bytes.

    The contribution queue stamps this into each submission so the service
    can verify, end to end, that the row that fuses is bit-identical to
    the row the contributor wrote — across the atomic npz round trip and,
    for per-shard submissions, across the shard/unshard rearrangement
    (checksummed in portable ``[N]`` form on both sides).  bf16 rows are
    viewed as their uint16 bit pattern, matching the npz storage."""
    arr = np.asarray(buf)
    if arr.dtype == jnp.bfloat16:
        arr = arr.view(np.uint16)
    # crc32 consumes the buffer protocol directly — no tobytes copy of a
    # multi-MB row on the submit path
    return f"{zlib.crc32(np.ascontiguousarray(arr)) & 0xFFFFFFFF:08x}"


# ---------------------------------------------------------------------------
# CohortSketch — the novelty admission screen's recency window
# ---------------------------------------------------------------------------


def row_sketch_host(row, n_buckets: int = SKETCH_BUCKETS) -> np.ndarray:
    """Host (numpy) twin of ``repro.kernels.ref.row_sketch`` — the same
    ``[2, n_buckets]`` tile-bucketed sums/sq-sums statistic, without a
    device round trip.  The submit path uses it to stamp rider sketches
    (the row is already host-resident there; dispatching jax costs ~5x).
    Parity with the kernel/oracle is pinned by tests/test_sketch.py."""
    x = np.asarray(row)
    if x.dtype == jnp.bfloat16:
        x = x.astype(np.float32)
    x = x.astype(np.float32, copy=False)
    t_full = x.shape[0] // LANE
    main = x[: t_full * LANE].reshape(t_full, LANE)
    ts = main.sum(axis=1)
    tq = np.einsum("ij,ij->i", main, main)
    tail = x[t_full * LANE:]
    if tail.size:  # the final partial tile (zero padding adds nothing)
        ts = np.append(ts, tail.sum())
        tq = np.append(tq, np.dot(tail, tail))
    pad = (-ts.shape[0]) % n_buckets
    if pad:
        ts = np.append(ts, np.zeros(pad, np.float32))
        tq = np.append(tq, np.zeros(pad, np.float32))
    # bucket of tile t is t % n_buckets: fold the tile axis over the buckets
    return np.stack([ts.reshape(-1, n_buckets).sum(axis=0),
                     tq.reshape(-1, n_buckets).sum(axis=0)])


class CohortSketch:
    """Recency window of admitted-row content sketches, plus the current
    base's sketch — the host half of the novelty admission screen
    (docs/service_loop.md).

    Each sketch is the ``[2, n_buckets]`` statistic of
    ``repro.kernels.ops.row_sketch``: tile-bucketed sums (projections onto
    bucket indicators) and tile-bucketed squared norms.  Both yield *lower
    bounds* on the true distance between two rows:

    * projections — ``Σ_j (p_a[j] − p_b[j])² / L ≤ ‖a − b‖²`` by
      Cauchy–Schwarz per bucket (``L`` = elements per bucket);
    * blockwise norms — ``Σ_j (√q_a[j] − √q_b[j])² ≤ ‖a − b‖²`` by the
      reverse triangle inequality per bucket.

    The screen compares the larger of the two bounds *relative to each
    row's distance from the base* (same bound, against ``base``): two
    contributions are near-duplicates when their mutual distance is small
    compared with how far either moved from the base — an exact replay
    scores 0 regardless of model scale, while independent finetunes of
    similar magnitude score O(1).  Normalizing by the base distance is what
    keeps the looseness of the bounds out of the decision: numerator and
    denominator lose the same statistical factor.

    ``add`` is idempotent per id (a re-admitted submission replaces its own
    entry — crash recovery must never flag a row as a duplicate of itself)
    and trims to the most recent ``window`` entries.  Each entry records
    the queue ``file`` it was sketched from: the self-match skip demands
    BOTH the id and the file agree, so a replay that forges a previously
    admitted rider id (ids are contributor-supplied) cannot talk its way
    past the screen — only the literal same queue file (the
    post-sketch-persist crash re-screen) is exempt.  ``to_json``/
    ``from_json`` round-trip the whole state; the Repository persists it
    atomically next to the staging manifest (``cohort_sketch.json``).
    """

    EPS = 1e-12

    def __init__(self, size: int, n_buckets: int = SKETCH_BUCKETS,
                 window: int = 32):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.size = int(size)
        self.n_buckets = int(n_buckets)
        self.window = int(window)
        self.base: Optional[np.ndarray] = None
        self.base_iteration: Optional[int] = None
        # recent base sketches by iteration — the router diffs a rider
        # against the base vintage its contributor actually finetuned from,
        # which may already have been superseded by the time the row admits
        self.bases: Dict[int, np.ndarray] = {}
        # (id, originating queue file, sketch, delta projections or None),
        # oldest first
        self.entries: List[Tuple[str, Optional[str], np.ndarray,
                                 Optional[np.ndarray]]] = []

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def seg_elems(self) -> int:
        """Upper bound on elements per bucket (the Cauchy–Schwarz L)."""
        tiles = -(-max(self.size, 1) // LANE)
        return -(-tiles // self.n_buckets) * LANE

    def _check(self, sketch) -> np.ndarray:
        arr = np.asarray(sketch, np.float64)
        if arr.shape != (2, self.n_buckets):
            raise ValueError(
                f"sketch shape {arr.shape} != (2, {self.n_buckets})")
        return arr

    # -- the lower-bound metric -----------------------------------------
    def _lb(self, a: np.ndarray, b: np.ndarray) -> float:
        dp2 = float(np.sum((a[0] - b[0]) ** 2)) / self.seg_elems
        dn2 = float(np.sum((np.sqrt(np.maximum(a[1], 0.0))
                            - np.sqrt(np.maximum(b[1], 0.0))) ** 2))
        return float(np.sqrt(max(dp2, dn2)))

    def distance(self, a, b) -> float:
        """Relative lower-bound distance between two sketches: mutual lb
        distance over the larger base-relative lb distance (row norms when
        no base sketch is set).  0 for exact duplicates; ~O(1) for
        independent contributions of comparable finetune magnitude."""
        a, b = self._check(a), self._check(b)
        d = self._lb(a, b)
        if self.base is not None:
            scale = max(self._lb(a, self.base), self._lb(b, self.base))
        else:
            scale = max(float(np.sqrt(max(np.sum(a[1]), 0.0))),
                        float(np.sqrt(max(np.sum(b[1]), 0.0))))
        if scale <= self.EPS:
            # both rows sit on the base (or are zero): identical for the
            # screen's purposes iff their mutual distance vanishes too
            return 0.0 if d <= self.EPS else float("inf")
        return d / scale

    # -- window maintenance ---------------------------------------------
    BASE_HISTORY = 8

    def set_base(self, sketch, iteration: Optional[int] = None) -> None:
        self.base = self._check(sketch)
        if iteration is not None:
            self.base_iteration = int(iteration)
            self.bases[int(iteration)] = self.base
            for it in sorted(self.bases)[: -self.BASE_HISTORY]:
                del self.bases[it]

    def base_at(self, iteration: Optional[int] = None
                ) -> Optional[np.ndarray]:
        """The base sketch at a given iteration (falling back to the
        current base when that vintage is unknown or unspecified)."""
        if iteration is not None and int(iteration) in self.bases:
            return self.bases[int(iteration)]
        return self.base

    def add(self, sub_id: str, sketch, *, file: Optional[str] = None,
            delta: Optional[Any] = None) -> None:
        arr = self._check(sketch)
        d = None if delta is None else np.asarray(delta, np.float64)
        self.entries = [e for e in self.entries if e[0] != sub_id]
        self.entries.append((str(sub_id), file, arr, d))
        del self.entries[: -self.window]

    def discard(self, sub_id: str) -> None:
        """Drop a submission's entry (admission failed after its sketch
        was recorded — the window must only hold rows that staged)."""
        self.entries = [e for e in self.entries if e[0] != sub_id]

    def nearest(self, sketch, *, skip_id: Optional[str] = None,
                skip_file: Optional[str] = None
                ) -> Optional[Tuple[str, float]]:
        """(id, relative distance) of the closest windowed entry, or None
        when the window is empty.  An entry is excluded only when BOTH its
        id matches ``skip_id`` and its recorded file matches ``skip_file``
        — the submission's own pre-crash entry, never a forged-id replay
        under a different queue file."""
        best: Optional[Tuple[str, float]] = None
        for sub_id, file, s, _d in self.entries:
            if (skip_id is not None and sub_id == skip_id
                    and file is not None and file == skip_file):
                continue
            d = self.distance(sketch, s)
            if best is None or d < best[1]:
                best = (sub_id, d)
        return best

    def match(self, sketch, threshold: float, *,
              skip_id: Optional[str] = None,
              skip_file: Optional[str] = None) -> Optional[Tuple[str, float]]:
        """The admission query: the (id, distance) of a windowed entry
        within ``threshold`` of ``sketch`` — i.e. the near-duplicate to
        reject for — or None when the row is novel."""
        hit = self.nearest(sketch, skip_id=skip_id, skip_file=skip_file)
        if hit is not None and hit[1] <= threshold:
            return hit
        return None

    # -- serialization (cohort_sketch.json) ------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "version": 1,
            "size": self.size,
            "n_buckets": self.n_buckets,
            "window": self.window,
            "base": None if self.base is None else self.base.tolist(),
            "base_iteration": self.base_iteration,
            "bases": {str(it): s.tolist() for it, s in self.bases.items()},
            "entries": [{"id": i, "file": f, "sketch": s.tolist(),
                         "delta": None if d is None else d.tolist()}
                        for i, f, s, d in self.entries],
        }

    @classmethod
    def from_json(cls, meta: Dict[str, Any]) -> "CohortSketch":
        sk = cls(int(meta["size"]), int(meta["n_buckets"]),
                 int(meta["window"]))
        for it, s in meta.get("bases", {}).items():
            sk.bases[int(it)] = sk._check(s)
        if meta.get("base") is not None:
            sk.set_base(meta["base"], iteration=meta.get("base_iteration"))
        for e in meta.get("entries", []):
            sk.add(e["id"], e["sketch"], file=e.get("file"),
                   delta=e.get("delta"))
        return sk


# ---------------------------------------------------------------------------
# FamilyRouter — sketch-distance routing over a family of bases
# ---------------------------------------------------------------------------


@dataclass
class RouteDecision:
    """Outcome of routing one submission against the base family.

    ``family`` is the member to fuse into (None when ``spawn`` — the
    service creates the new member and routes there); ``distance`` is the
    winning relative lower-bound distance (None when the decision was a
    bootstrap fallback); ``scores`` maps every scored member to its
    distance; ``delta`` is the rider's base-relative projection delta, the
    evidence recorded in the routed member's sketch window."""

    family: Optional[str]
    spawn: bool
    distance: Optional[float]
    scores: Dict[str, float]
    delta: Optional[np.ndarray]
    reason: str


class FamilyRouter:
    """Route submissions to their nearest base-family member by sketch
    distance (docs/service_loop.md).

    The unit of comparison is the **delta projection**: bucket projections
    are linear in the row, so ``rider_sketch[0] − base_sketch[0]`` is
    exactly the sketch of the contributor's finetune delta — the task
    direction, with the shared base subtracted out.  Two submissions from
    the same task stream have near-colinear deltas; streams from different
    tasks point elsewhere.  The router scores a rider against member ``m``
    as the minimum over

    * ``lb(rider, base_m) / ‖δ‖``  — how close the full row sits to
      ``m``'s base itself (catches resubmissions of a member's own base),
      using the same two-sided lower bound as the novelty screen; and
    * ``lb_p(δ − δ_e) / max(‖δ‖, ‖δ_e‖)`` over ``m``'s windowed delta
      entries ``δ_e`` — the base-relative distance between finetune
      directions (projection bound only: norms of deltas are not
      recoverable from row sq-norm sketches).

    Colinear same-stream deltas of magnitudes ``m1 ≤ m2`` score
    ``1 − m1/m2`` (small within a cohort window); independent task
    directions score O(1) or above.  Decision rules:

    * no member holds any delta evidence yet → route to the declared
      family (bootstrap: the first stream claims its declared base);
    * a vanishing rider delta (the row IS its declared base) → declared;
    * nearest distance ≤ ``split_threshold`` → route to the argmin
      (ties prefer the declared member);
    * nearest distance > ``split_threshold`` and the family is below
      ``max_bases`` → spawn a new member seeded from the declared base;
      at the cap, route to the argmin anyway (graceful saturation).
    """

    def __init__(self, *, split_threshold: float = 0.8, max_bases: int = 4):
        if split_threshold <= 0:
            raise ValueError(
                f"split_threshold must be > 0, got {split_threshold}")
        self.split_threshold = float(split_threshold)
        self.max_bases = int(max_bases)

    @staticmethod
    def _delta_norm(delta: np.ndarray, seg_elems: int) -> float:
        return float(np.sqrt(np.sum(np.asarray(delta, np.float64) ** 2)
                             / seg_elems))

    def route(self, sketch, sketches: Dict[str, CohortSketch], *,
              declared: str = "main",
              base_iteration: Optional[int] = None) -> RouteDecision:
        """Score ``sketch`` against every family member and decide.

        ``sketches`` maps member name → that member's ``CohortSketch``
        (base sketch + windowed delta evidence); ``declared`` /
        ``base_iteration`` identify the base vintage the rider claims it
        finetuned from, which anchors the delta."""
        if declared not in sketches:
            raise KeyError(f"unknown declared family {declared!r}")
        ref = sketches[declared]
        arr = ref._check(sketch)
        b0 = ref.base_at(base_iteration)
        if b0 is None:
            return RouteDecision(declared, False, None, {}, None,
                                 "declared member holds no base sketch yet")
        delta = arr[0] - np.asarray(b0, np.float64)[0]
        dn = self._delta_norm(delta, ref.seg_elems)
        if dn <= CohortSketch.EPS:
            return RouteDecision(declared, False, 0.0, {}, delta,
                                 "rider sits on its declared base")
        if not any(e[3] is not None for sk in sketches.values()
                   for e in sk.entries):
            return RouteDecision(declared, False, None, {}, delta,
                                 "bootstrap: no routing evidence yet")
        scores: Dict[str, float] = {}
        for name, sk in sketches.items():
            terms: List[float] = []
            if sk.base is not None:
                terms.append(ref._lb(arr, np.asarray(sk.base, np.float64))
                             / dn)
            for e in sk.entries:
                de = e[3]
                if de is None:
                    continue
                den = max(dn, self._delta_norm(de, ref.seg_elems),
                          CohortSketch.EPS)
                terms.append(
                    float(np.sqrt(np.sum((delta - de) ** 2)
                                  / ref.seg_elems)) / den)
            if terms:
                scores[name] = min(terms)
        nearest = min(scores, key=lambda n: (scores[n], n != declared, n))
        best = scores[nearest]
        if best > self.split_threshold and len(sketches) < self.max_bases:
            return RouteDecision(
                None, True, best, scores, delta,
                f"nearest member {nearest} at {best:.3f} > "
                f"split_threshold {self.split_threshold:g}")
        if best > self.split_threshold:
            reason = (f"at max_bases={self.max_bases}: routed to nearest "
                      f"{nearest} despite {best:.3f} > split_threshold")
        else:
            reason = f"nearest member {nearest} at {best:.3f}"
        return RouteDecision(nearest, False, best, scores, delta, reason)


# ---------------------------------------------------------------------------
# ShardedFlatSpec — block-cyclic layout of a flat buffer over a mesh axis
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardedFlatSpec:
    """Block-cyclic layout of a flat ``[N]`` buffer over ``n_shards`` shards.

    The padded buffer is a ``(G, S, B)`` grid of ``G·S`` blocks of ``B``
    elements: block ``j`` lives on shard ``j % S`` at slot ``j // S``
    (classic block-cyclic).  A sharded row is the ``[S, G·B]`` rearrangement
    of that grid, so placing its leading dim on a mesh axis gives every
    device a contiguous ``shard_len``-element slice that is

    * **balanced** — every shard holds exactly ``padded_size / S`` elements
      regardless of the leaf structure underneath, and
    * **tile-aligned** — ``B`` is a multiple of ``LANE`` (8x128), so each
      shard's slice is whole kernel tiles and the per-shard fuse needs no
      re-padding.

    Padding elements are zero; they contribute nothing to either the fused
    output (sliced away on unshard) or the ``sq_diff`` screening statistic
    (0 - 0 = 0), which is what lets the per-shard partials be all-reduced
    without any padding mask.

    The layout is independent of the leaf layout (`FlatSpec`): shard, fuse,
    and unshard all operate on the flat buffer; only the final publish
    re-derives the pytree.
    """

    size: int      # N — unpadded element count
    n_shards: int  # S — mesh-axis extent the layout targets
    block: int     # B — elements per layout block (LANE-aligned)

    # -- construction ---------------------------------------------------
    @classmethod
    def for_size(cls, size: int, n_shards: int,
                 block: Optional[int] = None) -> "ShardedFlatSpec":
        """Pick a layout for an ``[N]`` buffer over ``n_shards`` shards.

        ``block`` defaults to ``DEFAULT_SHARD_BLOCK`` clamped so tiny models
        do not pad to S full kernel blocks: the block shrinks (LANE-aligned)
        until one round of the cycle covers the whole buffer."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if block is None:
            per_shard = -(-max(size, 1) // n_shards)          # ceil
            aligned = -(-per_shard // LANE) * LANE            # lane-align up
            block = min(DEFAULT_SHARD_BLOCK, aligned)
        if block % LANE:
            raise ValueError(f"block {block} is not a multiple of LANE={LANE}")
        return cls(size, n_shards, block)

    @classmethod
    def from_spec(cls, spec: FlatSpec, n_shards: int,
                  block: Optional[int] = None) -> "ShardedFlatSpec":
        return cls.for_size(spec.size, n_shards, block)

    # -- derived geometry ----------------------------------------------
    @property
    def n_super(self) -> int:
        """G — rounds of the block cycle."""
        return -(-max(self.size, 1) // (self.n_shards * self.block))

    @property
    def padded_size(self) -> int:
        return self.n_super * self.n_shards * self.block

    @property
    def shard_len(self) -> int:
        return self.n_super * self.block

    def shard_of(self, i: int) -> Tuple[int, int]:
        """(shard, offset-within-shard) of flat element ``i``."""
        if not (0 <= i < self.size):
            raise ValueError(f"element {i} out of range [0, {self.size})")
        j, r = divmod(i, self.block)
        return j % self.n_shards, (j // self.n_shards) * self.block + r

    def global_of(self, shard: int, offsets) -> np.ndarray:
        """Inverse of ``shard_of``, vectorized: flat global indices of the
        given offsets *within* shard ``shard``.  Offsets that land in the
        block-grid padding map past ``size`` — callers filter those."""
        off = np.asarray(offsets, np.int64)
        slot, r = np.divmod(off, self.block)
        return (slot * self.n_shards + int(shard)) * self.block + r

    # -- rearrangement --------------------------------------------------
    def shard(self, buf) -> jax.Array:
        """``[..., N]`` -> ``[..., S, shard_len]`` block-cyclic rearrangement
        (zero-padded to the block grid)."""
        buf = jnp.asarray(buf)
        if buf.shape[-1] != self.size:
            raise ValueError(f"buffer last dim {buf.shape[-1]} != size {self.size}")
        lead = buf.shape[:-1]
        pad = self.padded_size - self.size
        if pad:
            buf = jnp.concatenate(
                [buf, jnp.zeros(lead + (pad,), buf.dtype)], axis=-1)
        grid = buf.reshape(lead + (self.n_super, self.n_shards, self.block))
        return jnp.swapaxes(grid, -3, -2).reshape(
            lead + (self.n_shards, self.shard_len))

    def unshard(self, arr) -> jax.Array:
        """``[..., S, shard_len]`` -> ``[..., N]`` (padding sliced away)."""
        arr = jnp.asarray(arr)
        want = (self.n_shards, self.shard_len)
        if arr.shape[-2:] != want:
            raise ValueError(f"sharded shape {arr.shape[-2:]} != {want}")
        lead = arr.shape[:-2]
        grid = arr.reshape(lead + (self.n_shards, self.n_super, self.block))
        flat = jnp.swapaxes(grid, -3, -2).reshape(lead + (self.padded_size,))
        return flat[..., : self.size]

    # -- host-side per-shard spill layout -------------------------------
    def shard_slices(self, row) -> List[np.ndarray]:
        """``[N]`` host row -> its S per-shard ``[shard_len]`` slices, in
        numpy (no device round trip) — the spill-per-shard write layout.
        Each slice is exactly what ``shard(row)[s]`` would hold."""
        row = np.asarray(row)
        if row.shape != (self.size,):
            raise ValueError(f"row shape {row.shape} != ({self.size},)")
        pad = self.padded_size - self.size
        if pad:
            row = np.concatenate([row, np.zeros((pad,), row.dtype)])
        grid = row.reshape(self.n_super, self.n_shards, self.block)
        return [np.ascontiguousarray(grid[:, s, :].reshape(self.shard_len))
                for s in range(self.n_shards)]

    def unshard_slices(self, slices: Sequence[np.ndarray]) -> np.ndarray:
        """Per-shard ``[shard_len]`` slices -> the ``[N]`` host row (the
        portability fallback when a spilled layout does not match the mesh
        the repository was reopened under)."""
        if len(slices) != self.n_shards:
            raise ValueError(f"{len(slices)} slices != n_shards {self.n_shards}")
        grid = np.stack([np.asarray(s).reshape(self.n_super, self.block)
                         for s in slices], axis=1)
        return grid.reshape(self.padded_size)[: self.size]

    # -- serialization (spill manifest) ---------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"size": self.size, "n_shards": self.n_shards, "block": self.block}

    @classmethod
    def from_json(cls, meta: Dict[str, Any]) -> "ShardedFlatSpec":
        return cls(int(meta["size"]), int(meta["n_shards"]), int(meta["block"]))


# ---------------------------------------------------------------------------
# Delta codec — top-k sparse / int8 compressed contributions
# ---------------------------------------------------------------------------

# int16 within-block offsets: a block may not exceed the int16 range
MAX_DELTA_BLOCK = 32768


@dataclass(frozen=True)
class DeltaPayload:
    """One compressed contribution delta: per-block top-k sparse indices,
    int8-quantized values, and per-block f32 scales (docs/service_loop.md
    §Compressed submissions).

    The row of ``size`` elements is partitioned into ``n_blocks`` blocks of
    ``block`` elements (LANE-aligned, so the decode kernel's grid is whole
    tiles); each block keeps exactly ``k_per_block`` entries — the fixed
    shape is what lets K payloads stack into one ``[K, nb, kb]`` kernel
    operand (a global top-k would be ragged).  Unused slots hold
    ``(offset 0, value 0)`` and decode to a harmless ``+0``.

    * ``indices`` — ``[nb, kb]`` int16 offsets *within* each block;
    * ``values``  — ``[nb, kb]`` int8 quantized deltas (±127 clip);
    * ``scales``  — ``[nb]`` f32, ``max|selected delta| / 127`` per block
      (0 for all-zero blocks).

    Reconstruction is ``delta ≈ values·scales`` scattered at the indices:
    kept entries carry ≤ ``scale/2`` quantization error, dropped entries
    err by their own magnitude (bounded by the smallest kept magnitude in
    their block) — the error-bound contract pinned by
    tests/test_delta_codec.py.
    """

    indices: np.ndarray   # [nb, kb] int16
    values: np.ndarray    # [nb, kb] int8
    scales: np.ndarray    # [nb] float32
    size: int             # decoded element count (N, or shard_len)
    block: int            # elements per codec block (LANE-aligned)

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"size must be >= 1, got {self.size}")
        if self.block % LANE or not (0 < self.block <= MAX_DELTA_BLOCK):
            raise ValueError(
                f"block {self.block} must be a LANE multiple in "
                f"(0, {MAX_DELTA_BLOCK}]")
        nb = -(-self.size // self.block)
        idx, val, scl = self.indices, self.values, self.scales
        if idx.dtype != np.int16 or val.dtype != np.int8 \
                or scl.dtype != np.float32:
            raise ValueError(
                f"payload dtypes ({idx.dtype}, {val.dtype}, {scl.dtype}) != "
                "(int16, int8, float32)")
        if idx.ndim != 2 or idx.shape[0] != nb or idx.shape != val.shape \
                or scl.shape != (nb,):
            raise ValueError(
                f"payload shapes idx{idx.shape} val{val.shape} "
                f"scl{scl.shape} inconsistent with size={self.size} "
                f"block={self.block}")
        if idx.shape[1] > self.block:
            raise ValueError(
                f"k_per_block {idx.shape[1]} > block {self.block}")
        if idx.size and (idx.min() < 0 or int(idx.max()) >= self.block):
            raise ValueError("payload indices out of block range")

    @property
    def n_blocks(self) -> int:
        return self.indices.shape[0]

    @property
    def k_per_block(self) -> int:
        return self.indices.shape[1]

    @property
    def nbytes(self) -> int:
        """Encoded payload bytes (the queue-bandwidth figure of merit)."""
        return self.indices.nbytes + self.values.nbytes + self.scales.nbytes


def _as_f32_row(buf, what: str) -> np.ndarray:
    arr = np.asarray(buf)
    if arr.dtype == jnp.bfloat16:
        arr = arr.astype(np.float32)
    arr = np.ascontiguousarray(arr, np.float32)
    if arr.ndim != 1:
        raise ValueError(f"{what} must be 1-D, got shape {arr.shape}")
    return arr


def delta_encode(row, base, *, k_per_block: int,
                 block: int = LANE) -> DeltaPayload:
    """Encode ``row − base`` as a per-block top-k / int8 ``DeltaPayload``.

    Selection is by |delta| per block with a stable order, so the same
    inputs always produce byte-identical payloads (the checksum contract).
    Non-finite deltas are a caller bug and raise — the service treats a
    non-finite *scale* on disk as a malformed rider."""
    row, base = _as_f32_row(row, "row"), _as_f32_row(base, "base")
    if row.shape != base.shape:
        raise ValueError(f"row shape {row.shape} != base shape {base.shape}")
    size = row.shape[0]
    if size < 1:
        raise ValueError("cannot encode an empty row")
    d = row - base
    if not np.isfinite(d).all():
        raise ValueError("delta contains non-finite values")
    nb = -(-size // block)
    kb = int(k_per_block)
    if not (0 <= kb <= block):
        raise ValueError(f"k_per_block {kb} not in [0, {block}]")
    pad = nb * block - size
    if pad:
        d = np.concatenate([d, np.zeros((pad,), np.float32)])
    d = d.reshape(nb, block)
    if kb == 0:
        return DeltaPayload(np.zeros((nb, 0), np.int16),
                            np.zeros((nb, 0), np.int8),
                            np.zeros((nb,), np.float32), size, block)
    # stable top-k by magnitude: deterministic for byte-identical payloads
    order = np.argsort(-np.abs(d), axis=1, kind="stable")[:, :kb]
    sel = np.take_along_axis(d, order, axis=1)            # [nb, kb]
    scales = (np.max(np.abs(sel), axis=1) / 127.0).astype(np.float32)
    with np.errstate(divide="ignore", invalid="ignore"):
        q = np.where(scales[:, None] > 0.0, sel / scales[:, None], 0.0)
    values = np.clip(np.rint(q), -127, 127).astype(np.int8)
    return DeltaPayload(order.astype(np.int16), values, scales, size, block)


def delta_decode(payload: DeltaPayload, base=None) -> np.ndarray:
    """Decode a payload to its dense f32 delta (or ``base + delta`` when a
    base row is given).  Duplicate indices accumulate — matching the
    decode+accumulate kernel's scatter-add semantics."""
    nb, kb = payload.indices.shape
    dense = np.zeros((nb * payload.block,), np.float32)
    if kb:
        flat_idx = (np.arange(nb, dtype=np.int64)[:, None] * payload.block
                    + payload.indices.astype(np.int64))
        dv = payload.values.astype(np.float32) * payload.scales[:, None]
        np.add.at(dense, flat_idx.reshape(-1), dv.reshape(-1))
    dense = dense[: payload.size]
    if base is None:
        return dense
    base = _as_f32_row(base, "base")
    if base.shape != dense.shape:
        raise ValueError(f"base shape {base.shape} != ({payload.size},)")
    return base + dense


def delta_entries(payload: DeltaPayload
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """(flat indices, dequantized delta values) of a payload's non-zero
    entries — padding-slot and zero-quantized entries dropped.  This is the
    sparse view the sketch correction consumes; no dense row materializes."""
    nb, kb = payload.indices.shape
    if kb == 0:
        return (np.zeros((0,), np.int64), np.zeros((0,), np.float32))
    gi = (np.arange(nb, dtype=np.int64)[:, None] * payload.block
          + payload.indices.astype(np.int64)).reshape(-1)
    dv = (payload.values.astype(np.float32)
          * payload.scales[:, None]).reshape(-1)
    keep = (gi < payload.size) & (dv != 0.0)
    return gi[keep], dv[keep]


def delta_encode_sharded(row, base, sspec: ShardedFlatSpec, *,
                         k_per_block: int,
                         block: int = LANE) -> List[DeltaPayload]:
    """Per-shard variant: encode each block-cyclic ``shard_slices`` slice of
    ``row`` against the matching slice of ``base`` — the compressed analog
    of ``save_flat_shards``'s spill layout.  ``sspec.block`` must be a
    multiple of the codec block so codec blocks never straddle shards."""
    if sspec.block % block:
        raise ValueError(
            f"shard block {sspec.block} not a multiple of codec block {block}")
    row_s = sspec.shard_slices(_as_f32_row(row, "row"))
    base_s = sspec.shard_slices(_as_f32_row(base, "base"))
    return [delta_encode(r, b, k_per_block=k_per_block, block=block)
            for r, b in zip(row_s, base_s)]


def delta_decode_sharded(payloads: Sequence[DeltaPayload],
                         sspec: ShardedFlatSpec, base=None) -> np.ndarray:
    """Per-shard payloads -> the dense ``[N]`` delta (or ``base + delta``)
    — the host fallback when a spilled compressed layout does not match the
    mesh the repository runs under."""
    if len(payloads) != sspec.n_shards:
        raise ValueError(
            f"{len(payloads)} payloads != n_shards {sspec.n_shards}")
    delta = sspec.unshard_slices([delta_decode(p) for p in payloads])
    if base is None:
        return delta
    return _as_f32_row(base, "base") + delta


def delta_checksum(payloads) -> str:
    """CRC32 (hex) over the *encoded* payload bytes, in canonical order
    (geometry, then indices/values/scales per payload).  This — not the
    decoded row's CRC — is what ``verify_checksums`` recomputes for a
    compressed submission: the checksum covers the bytes that actually
    cross the queue, so a liar rider stamping the decoded row's CRC is a
    per-file rejection."""
    if isinstance(payloads, DeltaPayload):
        payloads = [payloads]
    crc = 0
    for p in payloads:
        crc = zlib.crc32(f"{p.size}:{p.block}:{p.k_per_block};".encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(p.indices), crc)
        crc = zlib.crc32(np.ascontiguousarray(p.values), crc)
        crc = zlib.crc32(np.ascontiguousarray(p.scales), crc)
    return f"{crc & 0xFFFFFFFF:08x}"


def sketch_apply_delta(base_sketch, indices, dvals, base_at,
                       n_buckets: int = SKETCH_BUCKETS) -> np.ndarray:
    """Sketch of ``base + delta`` from the base's sketch and the sparse
    delta — no dense host row.  Exact in exact arithmetic:

    * bucket of flat element ``i`` is ``(i // LANE) % n_buckets`` (the
      tile-bucket convention of ``row_sketch_host``);
    * sums gain ``Σ dv`` per bucket, squared norms gain
      ``Σ dv·(dv + 2·base[i])`` per bucket (``(b+d)² − b²``).

    ``base_at`` is the base row gathered at ``indices`` — the only base
    values the correction needs."""
    sk = np.array(base_sketch, np.float64, copy=True)
    if sk.shape != (2, n_buckets):
        raise ValueError(f"base sketch shape {sk.shape} != (2, {n_buckets})")
    b = (np.asarray(indices, np.int64) // LANE) % n_buckets
    dv = np.asarray(dvals, np.float64)
    ba = np.asarray(base_at, np.float64)
    sk[0] += np.bincount(b, weights=dv, minlength=n_buckets)
    sk[1] += np.bincount(b, weights=dv * (dv + 2.0 * ba),
                         minlength=n_buckets)
    return sk


# ---------------------------------------------------------------------------
# StagedBuffer / BufferPair — the async double-buffered staging primitives
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StagedBuffer:
    """Explicit handle to one stacked cohort operand.

    The fuse entry points (``ops.fuse_flat``, ``ops.fuse_flat_sharded``,
    ``ops.cohort_fuse_sharded``, ``Repository.fuse_pending``) accept either
    a raw array or this handle; the handle names the layout so callers and
    the Repository can hand a staged cohort around without re-deriving what
    it is:

    * ``data`` is ``[K, N]`` (single device) or ``[K, S, shard_len]``
      (block-cyclic over a mesh, ``sharded`` True);
    * ``k`` is the cohort size (leading dim).
    """

    data: jax.Array

    @property
    def k(self) -> int:
        return self.data.shape[0]

    @property
    def sharded(self) -> bool:
        return self.data.ndim == 3

    @classmethod
    def from_rows(cls, rows: Sequence[jax.Array]) -> "StagedBuffer":
        """Stack K staged ``[N]`` (or ``[S, shard_len]``) rows."""
        if not rows:
            raise ValueError("cannot stage an empty cohort")
        return cls(jnp.stack(list(rows)))


class StagingSide:
    """One side of the double buffer: the parallel per-contribution lists
    the Repository staging keeps (row/path, fisher, weight, and — with
    spill — the manifest entry describing the on-disk row)."""

    __slots__ = ("rows", "fishers", "weights", "manifest")

    def __init__(self):
        self.rows: List[Any] = []
        self.fishers: List[Any] = []
        self.weights: List[Any] = []
        self.manifest: List[Dict[str, Any]] = []

    def __len__(self) -> int:
        return len(self.rows)


class BufferPair:
    """Front/back staging pair (docs/async_repository.md).

    ``upload`` appends to the **front** side; ``swap()`` moves the front
    cohort to the **back** (the fuse operand of the in-flight dispatch) and
    opens a fresh front, so uploads continue while the back is being fused
    on device.  ``retire_back()`` drops the back side once its fuse has
    published.  The pair never holds more than one in-flight cohort: a
    second ``swap()`` before ``retire_back()`` is a caller bug and raises.
    """

    def __init__(self):
        self.front = StagingSide()
        self.back: Optional[StagingSide] = None

    def swap(self) -> StagingSide:
        if self.back is not None:
            raise RuntimeError("back buffer still in flight — finalize the "
                               "pending fuse before swapping again")
        self.back = self.front
        self.front = StagingSide()
        return self.back

    def retire_back(self) -> None:
        self.back = None

    def manifest_entries(self) -> List[Dict[str, Any]]:
        """All staged-but-unfused manifest entries, back (in-flight, not yet
        published) first — exactly the rows a crash right now would need to
        recover.  Reads a local capture of ``back``: spill-executor workers
        call this under the Repository's manifest lock while the main
        thread swaps/retires under the same lock, but the capture keeps a
        concurrent retire from turning the None-check into an attribute
        error even if a future call site forgets the lock."""
        back = self.back
        entries = list(back.manifest) if back is not None else []
        return entries + list(self.front.manifest)
