"""Pytree helpers shared across the framework (no flax/optax available)."""
from __future__ import annotations

import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x, y):
    """a * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_lerp(a, b, t):
    """(1 - t) * a + t * b, leafwise (damped-fusion primitive)."""
    return jax.tree.map(lambda ai, bi: (1.0 - t) * ai + t * bi, a, b)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return sum(jax.tree.leaves(leaves))


def tree_sq_norm(tree):
    leaves = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree)
    return sum(jax.tree.leaves(leaves))


def tree_norm(tree):
    return jnp.sqrt(tree_sq_norm(tree))


def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_isfinite(tree):
    """Scalar bool: every floating leaf is finite everywhere."""
    oks = [
        jnp.all(jnp.isfinite(x))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
    ]
    if not oks:
        return jnp.asarray(True)
    return jnp.stack(oks).all()


def path_str(path) -> str:
    """Render a jax KeyPath as 'a/b/0/c'."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_name(fn: Callable[[str, Any], Any], tree):
    """Map ``fn(name, leaf)`` over a pytree, where name is the joined path."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(path_str(p), x), tree)


def first_match(rules, name: str, default=None):
    """Return the value of the first (regex, value) rule matching ``name``."""
    for pat, val in rules:
        if re.search(pat, name):
            return val
    return default
