"""Parse collective traffic out of (optimized) HLO text.

``compiled.cost_analysis()`` has no collective-bytes entry, so §Roofline's
collective term is derived here: scan the per-device HLO module for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops and sum their operand shard sizes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

# e.g. "  %all-reduce.5 = bf16[16,512]{1,0} all-reduce(%x), replica_groups=..."
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")


def shape_bytes(text: str) -> int:
    """Bytes of one shape literal (or tuple of shapes)."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self) -> Dict:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def collect_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shard sizes of every collective op in an HLO module.

    The result shape of the op is the per-device shard the collective
    produces — a faithful per-device traffic proxy (ring all-reduce moves
    ~2x the shard; the roofline applies kind-specific multipliers).
    """
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        kind = m.group("kind").replace("-start", "")
        b = shape_bytes(m.group("shape"))
        bytes_by[kind] += b
        count_by[kind] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


# Per-kind wire-traffic multiplier relative to the op's result bytes, for a
# ring/bidirectional-ring implementation on D participants (D large):
#   all-reduce: result is full tensor, wire ~2x tensor
#   all-gather: result is full gathered tensor, wire ~1x tensor
#   reduce-scatter: result is 1/D shard, wire ~1x full tensor ≈ D*result ~
#     (we conservatively use result*1: per-link bytes ≈ full/D * (D-1) ≈ full;
#      full = result*D — handled by caller passing participants)
def wire_bytes(stats: CollectiveStats, participants_by_kind: Dict[str, int] | None = None) -> int:
    mult = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}
    total = 0.0
    for kind, b in stats.bytes_by_kind.items():
        m = mult.get(kind, 1.0)
        if kind == "reduce-scatter" and participants_by_kind:
            m = float(participants_by_kind.get(kind, 1))
        total += m * b
    return int(total)
