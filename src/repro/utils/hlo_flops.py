"""Trip-count-aware HLO analyzer.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so every
``lax.scan`` (microbatch accumulation, scan-over-layers, chunked attention,
SSM time scans) is undercounted by its trip count — useless for a roofline.
XLA's optimized HLO annotates loops with ``backend_config=
{"known_trip_count":{"n":...}}``; this module walks the module text,
computing per-device totals with loop bodies scaled by their trip counts:

* ``flops``       — 2·M·N·K per dot (batch dims included), recursing into
                    fusions / called computations / while bodies;
* ``hbm_bytes``   — Σ (operand + result bytes) of top-level fusions, dots,
                    copies, dynamic-(update-)slices — XLA fusions are the
                    HBM traffic units, so this approximates bytes accessed;
* ``collectives`` — result-shard bytes per collective kind, trip-scaled
                    (an all-reduce inside a scan fires every iteration).

This is static analysis of the *optimized, partitioned* module — i.e. the
per-chip program — exactly what §Roofline needs.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$"
)
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_OPERANDS = re.compile(r"%([\w.\-]+)")


def _shape_info(text: str) -> Tuple[int, int]:
    """(total elements over all sub-shapes, total bytes)."""
    elems = 0
    byts = 0
    for m in _SHAPE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    shape: str  # result shape text
    opcode: str
    rest: str   # operand list + attributes (remainder of the line)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # %name -> shape text


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        hdr = _COMP_HDR.match(line.strip()) if ("{" in line and "->" in line) else None
        if hdr and not line.lstrip().startswith("%param"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            # parameters declared in the header get their shapes from use sites
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
        cur.ops.append(op)
        cur.shapes[op.name] = op.shape
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 x (batch x M x N x K) from operand shapes + contracting dims."""
    # operands are the first two %names in rest
    names = _OPERANDS.findall(op.rest)
    if len(names) < 2:
        return 0.0
    lhs = comp.shapes.get(names[0])
    rhs = comp.shapes.get(names[1])
    out_dims = _first_shape_dims(op.shape) or []
    if lhs is None:
        return 0.0
    lhs_dims = _first_shape_dims(lhs) or []
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contracting = [int(x) for x in mc.group(1).split(",")] if mc and mc.group(1) else []
    k = 1
    for c in contracting:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * k


@dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    dynamic_whiles: int = 0  # loops without a known trip count (counted once)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "bitcast-convert", "reshape", "after-all", "partition-id"}


class Analyzer:
    def __init__(self, hlo: str):
        self.comps = parse_module(hlo)
        self._cache: Dict[str, Analysis] = {}
        # entry = computation named like ENTRY (parse order: last 'main' wins)
        self.entry = None
        for name in self.comps:
            if name.startswith("main"):
                self.entry = name
        if self.entry is None and self.comps:
            self.entry = list(self.comps)[-1]

    def analyze(self, comp_name: Optional[str] = None, *, top_level: bool = True) -> Analysis:
        name = comp_name or self.entry
        if name in self._cache:
            return self._cache[name]
        comp = self.comps.get(name)
        out = Analysis()
        if comp is None:
            return out
        self._cache[name] = out  # guard recursion
        for op in comp.ops:
            oc = op.opcode
            kind = oc[:-6] if oc.endswith("-start") else oc
            if kind in COLLECTIVES:
                _, b = _shape_info(op.shape)
                out.collective_bytes[kind] += b
                out.collective_count[kind] += 1
                continue
            if oc == "dot":
                out.flops += _dot_flops(op, comp)
                _, rb = _shape_info(op.shape)
                ob = self._operand_bytes(op, comp)
                out.hbm_bytes += rb + ob
                continue
            if oc == "fusion" or oc == "call" or oc == "custom-call":
                sub = _CALLS.search(op.rest) or _TO_APPLY.search(op.rest)
                subname = sub.group(1) if sub else None
                if subname and subname in self.comps:
                    s = self.analyze(subname, top_level=False)
                    out.flops += s.flops
                    self._merge_coll(out, s, 1)
                # fusion boundary = HBM traffic unit.  In-place update fusions
                # (root dynamic-update-slice / scatter) only move the update
                # slice, not the full aliased buffer — critical for scans that
                # DUS into [S, ...] outputs every step.
                out.hbm_bytes += self._fusion_traffic(op, comp, subname)
                continue
            if oc in ("dynamic-slice", "gather"):
                _, rb = _shape_info(op.shape)
                out.hbm_bytes += 2 * rb  # reads + writes only the slice
                continue
            if oc in ("dynamic-update-slice", "scatter"):
                upd = self._update_operand_bytes(op, comp)
                out.hbm_bytes += 2 * upd
                continue
            if oc == "while":
                body = _BODY.search(op.rest)
                trip_m = _TRIP.search(op.rest)
                trips = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    out.dynamic_whiles += 1
                if body and body.group(1) in self.comps:
                    s = self.analyze(body.group(1), top_level=False)
                    out.flops += trips * s.flops
                    out.hbm_bytes += trips * s.hbm_bytes
                    self._merge_coll(out, s, trips)
                continue
            if oc == "conditional":
                for sub in _OPERANDS.findall(op.rest):
                    if sub in self.comps:
                        s = self.analyze(sub, top_level=False)
                        out.flops += s.flops
                        out.hbm_bytes += s.hbm_bytes
                        self._merge_coll(out, s, 1)
                continue
            if oc in ("reduce", "sort", "scatter", "map", "reduce-window", "select-and-scatter"):
                sub = _TO_APPLY.search(op.rest) or _CALLS.search(op.rest)
                # elementwise apply — flops negligible; count bytes
                _, rb = _shape_info(op.shape)
                out.hbm_bytes += rb + self._operand_bytes(op, comp)
                continue
            if oc in _SKIP_BYTES:
                continue
            # everything else: count memory traffic only
            _, rb = _shape_info(op.shape)
            out.hbm_bytes += rb + self._operand_bytes(op, comp)
        return out

    def _update_operand_bytes(self, op: Op, comp: Computation) -> float:
        """Bytes of the update operand (index 1) of a DUS/scatter op."""
        names = _OPERANDS.findall(op.rest.split("),")[0])
        if len(names) >= 2:
            sh = comp.shapes.get(names[1])
            if sh:
                return _shape_info(sh)[1]
        return _shape_info(op.shape)[1]

    def _fusion_traffic(self, op: Op, comp: Computation, subname: Optional[str]) -> float:
        """HBM traffic of one fusion: result write + per-operand reads, where

        * an operand consumed ONLY by dynamic-slice/gather ops inside the
          fusion is charged the slice bytes (scan xs slicing pattern);
        * an operand that is the in-place target of a root
          dynamic-update-slice/scatter is not read at all — the write is the
          update slice (scan ys accumulation pattern).
        """
        _, rb = _shape_info(op.shape)
        called = self.comps.get(subname) if subname else None
        operand_names = _OPERANDS.findall(op.rest.split("),")[0])
        if called is None:
            return self._operand_bytes(op, comp) + rb

        # parameter index -> internal name
        param_name: Dict[int, str] = {}
        for sop in called.ops:
            if sop.opcode == "parameter":
                m = re.match(r"\s*(\d+)", sop.rest)
                if m:
                    param_name[int(m.group(1))] = sop.name
        # internal consumers per value name
        consumers: Dict[str, List[Op]] = defaultdict(list)
        for sop in called.ops:
            if sop.opcode == "parameter":
                continue
            for nm in _OPERANDS.findall(sop.rest.split("),")[0]):
                consumers[nm].append(sop)

        # does the fusion write in place (DUS/scatter producing the result)?
        dus_ops = [s for s in called.ops if s.opcode in ("dynamic-update-slice", "scatter")]
        write_b = rb
        inplace_target: Optional[str] = None
        if dus_ops:
            write_b = sum(self._update_operand_bytes(s, called) for s in dus_ops)
            tgt = _OPERANDS.findall(dus_ops[0].rest.split("),")[0])
            if tgt:
                inplace_target = tgt[0]

        total = float(write_b)
        for i, nm in enumerate(operand_names):
            sh = comp.shapes.get(nm)
            if not sh:
                continue
            b = _shape_info(sh)[1]
            pname = param_name.get(i)
            if pname is not None:
                cons = consumers.get(pname, [])
                if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
                    b = sum(_shape_info(c.shape)[1] for c in cons)
                elif pname == inplace_target:
                    b = 0.0  # aliased output buffer, not re-read
            total += b
        return total

    def _operand_bytes(self, op: Op, comp: Computation) -> float:
        total = 0.0
        # operand list ends at first "), " — take names before attributes
        paren = op.rest.split("),")[0]
        for nm in _OPERANDS.findall(paren):
            sh = comp.shapes.get(nm)
            if sh:
                _, b = _shape_info(sh)
                total += b
        return total

    @staticmethod
    def _merge_coll(out: Analysis, sub: Analysis, mult: int):
        for k, v in sub.collective_bytes.items():
            out.collective_bytes[k] += mult * v
        for k, v in sub.collective_count.items():
            out.collective_count[k] += mult * v
        out.dynamic_whiles += sub.dynamic_whiles


def analyze_hlo(hlo: str) -> Analysis:
    return Analyzer(hlo).analyze()


def wire_bytes(analysis: Analysis) -> float:
    """Per-chip ICI wire traffic with ring multipliers (all-reduce 2x)."""
    mult = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}
    return sum(mult.get(k, 1.0) * v for k, v in analysis.collective_bytes.items())
