"""Fault-injection points for the kill-at-checkpoint tests.

A *crash point* is a named seam in a durability-critical sequence (queue
admit, fuse dispatch, base publish, manifest rewrite).  In production the
hooks are inert one-comparison no-ops; a test arms exactly one point by
exporting ``REPRO_CRASH_POINT=<name>`` in a child process, and the child
dies there with ``os._exit`` — no cleanup, no atexit, no flushing — which
is as close to ``kill -9`` as a same-process hook can get.

The armed name is read once at import: children receive the env var before
the interpreter starts, and a hot-path hook must not pay a getenv per call.

``tests/_faults.py`` holds the subprocess harness that drives these.
"""
from __future__ import annotations

import os
import sys

ENV = "REPRO_CRASH_POINT"
EXIT_CODE = 17  # distinguishes an armed crash from ordinary failures

_ARMED = os.environ.get(ENV)


def crash_point(name: str) -> None:
    """Die abruptly iff this point is the armed one (no-op otherwise)."""
    if _ARMED is not None and _ARMED == name:
        # stderr is unbuffered-ish and survives os._exit better than stdout;
        # the marker lets the harness assert the crash fired WHERE expected
        print(f"CRASH_POINT {name}", file=sys.stderr, flush=True)
        os._exit(EXIT_CODE)
