"""Roofline terms from a compiled dry-run artifact (DESIGN.md §5).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


@dataclass
class Roofline:
    """All quantities are per-chip, per-step."""

    flops: float              # HLO FLOPs executed by one chip
    hbm_bytes: float          # HLO bytes accessed by one chip
    collective_bytes: float   # wire bytes crossing one chip's ICI links
    model_flops: float        # 6·N(_active)·D tokens-math, per chip
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization implied by the roofline step time."""
        t = self.step_time_s
        return (self.model_flops / PEAK_FLOPS) / t if t else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "model_flops_per_chip": self.model_flops,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_step_s": self.step_time_s,
            "roofline_mfu": self.mfu,
        }


def model_flops_per_step(n_params_active: int, tokens: int, *, training: bool) -> float:
    """6·N·D for a train step (fwd+bwd); 2·N·D for inference."""
    c = 6.0 if training else 2.0
    return c * n_params_active * tokens
