"""The main ColD Fusion run (paper §5.1/§5.2) — shared engine behind the
Fig. 2 / Fig. 3 / Fig. 4 / Table 1 benchmarks.

Runs the full loop on the synthetic suite with a seen/unseen split and all
three baselines (pretrained, fused-once = Choshen'22b, standard multitask),
then caches every series + model snapshot under benchmarks/_cache.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from benchmarks import common as C
from repro.checkpoint import io as ckpt
from repro.core import Repository, evaluate_base_model, run_cold_fusion
from repro.train.multitask import train_multitask

CACHE_KEY = f"cold_main_{C.SCALE}"
N_SEEN = 24  # tasks 0..23 seen; 24..35 unseen (one fold of the paper's 3)


def _eval_both(cfg, body, tasks, eval_steps):
    ft = evaluate_base_model(cfg, body, tasks, frozen=False, steps=eval_steps, lr=C.EVAL_LR)
    fr = evaluate_base_model(cfg, body, tasks, frozen=True, steps=eval_steps, lr=C.EVAL_LR)
    return C.mean_acc(ft), C.mean_acc(fr), ft, fr


def run(force: bool = False) -> Dict:
    os.makedirs(C.CACHE, exist_ok=True)
    path = os.path.join(C.CACHE, CACHE_KEY + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    k = C.KNOBS
    cfg = C.repro_cfg()
    suite = C.make_suite(36)
    body0 = C.pretrained_body(cfg, suite)

    seen_ids = list(range(0, N_SEEN))
    unseen_ids = list(range(N_SEEN, 36))
    eval_seen = [C.make_eval_task(suite, t, n_train=256) for t in seen_ids[: k["n_eval"]]]
    eval_unseen = [C.make_eval_task(suite, t, n_train=256) for t in unseen_ids[: k["n_eval"]]]

    out: Dict = {"scale": C.SCALE, "knobs": k}
    t0 = time.time()

    # --- baselines -------------------------------------------------------
    pre_s_ft, pre_s_fr, pre_sft_per, _ = _eval_both(cfg, body0, eval_seen, k["eval_steps"])
    pre_u_ft, pre_u_fr, pre_uft_per, _ = _eval_both(cfg, body0, eval_unseen, k["eval_steps"])
    out["pretrained"] = {"seen_ft": pre_s_ft, "seen_fr": pre_s_fr,
                         "unseen_ft": pre_u_ft, "unseen_fr": pre_u_fr,
                         "seen_ft_per_task": pre_sft_per}

    contribs = [C.make_contributor(cfg, suite, t, n=k["n_train"], steps=k["steps"])
                for t in seen_ids]

    # fused-once (Choshen et al. 2022b): ONE iteration with every contributor
    repo1 = Repository(body0)
    run_cold_fusion(cfg, repo1, contribs, iterations=1)
    fused_body = repo1.download()
    f_s_ft, f_s_fr, f_sft_per, _ = _eval_both(cfg, fused_body, eval_seen, k["eval_steps"])
    f_u_ft, f_u_fr, *_ = _eval_both(cfg, fused_body, eval_unseen, k["eval_steps"])
    out["fused_once"] = {"seen_ft": f_s_ft, "seen_fr": f_s_fr,
                         "unseen_ft": f_u_ft, "unseen_fr": f_u_fr,
                         "seen_ft_per_task": f_sft_per}

    # standard multitask baseline (shared body, per-task heads)
    mt_steps = k["iters"] * k["per_iter"] * k["steps"]
    datasets = [(c.task_id, c.x, c.y, c.num_classes) for c in contribs]
    mt_body, _ = train_multitask(cfg, body0, datasets, steps=mt_steps, lr=C.LR)
    m_s_ft, m_s_fr, m_sft_per, _ = _eval_both(cfg, mt_body, eval_seen, k["eval_steps"])
    m_u_ft, m_u_fr, *_ = _eval_both(cfg, mt_body, eval_unseen, k["eval_steps"])
    out["multitask"] = {"seen_ft": m_s_ft, "seen_fr": m_s_fr,
                        "unseen_ft": m_u_ft, "unseen_fr": m_u_fr,
                        "seen_ft_per_task": m_sft_per}

    # --- ColD Fusion -------------------------------------------------------
    repo = Repository(body0, keep_history=True)
    eval_every = max(1, k["iters"] // 4)
    log = run_cold_fusion(
        cfg, repo, contribs, iterations=k["iters"], contributors_per_iter=k["per_iter"],
        eval_seen=eval_seen, eval_unseen=eval_unseen, eval_every=eval_every,
        eval_steps=k["eval_steps"], eval_lr=C.EVAL_LR, progress=True,
    )
    out["cold"] = {
        "eval_every": eval_every,
        "seen_ft": log.mean("seen_finetuned"),
        "seen_fr": log.mean("seen_frozen"),
        "unseen_ft": log.mean("unseen_finetuned"),
        "unseen_fr": log.mean("unseen_frozen"),
        "seen_ft_per_task_final": {str(t): v for t, v in log.seen_finetuned[-1].items()},
    }
    out["wall_s"] = time.time() - t0

    # snapshots for the few-shot benchmark (fig4)
    ckpt.save(os.path.join(C.CACHE, CACHE_KEY + "_final_body.npz"), repo.download())
    mid = max(0, repo.iteration // 2)
    ckpt.save(os.path.join(C.CACHE, CACHE_KEY + "_mid_body.npz"), repo.snapshot(mid))

    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def load_body(which: str):
    p = os.path.join(C.CACHE, f"{CACHE_KEY}_{which}_body.npz")
    return ckpt.load(p)


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
