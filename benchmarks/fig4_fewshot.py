"""Fig. 4 — few-shot (100 examples) finetuning on unseen tasks (claim C4):
the ColD base model's advantage grows when eval data is scarce."""
from benchmarks import cold_main
from benchmarks import common as C
from repro.core import evaluate_base_model


def run(rows: C.Rows):
    res, _ = C.timed(cold_main.run)
    cfg = C.repro_cfg()
    suite = C.make_suite(36)
    body_pre = C.pretrained_body(cfg, suite)
    body_mid = cold_main.load_body("mid")
    body_final = cold_main.load_body("final")
    k = C.KNOBS
    unseen = [C.make_eval_task(suite, t, n_train=256) for t in range(cold_main.N_SEEN, 36)][: k["n_eval"]]

    def few(body):
        return C.mean_acc(evaluate_base_model(
            cfg, body, unseen, frozen=False, steps=max(40, k["eval_steps"] // 2),
            lr=C.EVAL_LR, few_shot=100))

    (a_pre, us1) = C.timed(few, body_pre)
    (a_mid, us2) = C.timed(few, body_mid)
    (a_fin, us3) = C.timed(few, body_final)
    rows.add("fig4/pretrained_fewshot100", us1, f"acc={a_pre:.4f}")
    rows.add("fig4/cold_mid_fewshot100", us2, f"acc={a_mid:.4f}")
    rows.add("fig4/cold_final_fewshot100", us3, f"acc={a_fin:.4f}")
    full_delta = res["cold"]["unseen_ft"][-1] - res["pretrained"]["unseen_ft"]
    few_delta = a_fin - a_pre
    rows.add("fig4/claim_C4_fewshot_gain", us3,
             f"pass={a_fin > a_pre} delta={few_delta:+.4f}")
    rows.add("fig4/claim_C4b_gain_larger_than_fullshot", us3,
             f"pass={few_delta >= full_delta - 0.01} few={few_delta:+.4f} full={full_delta:+.4f}")
