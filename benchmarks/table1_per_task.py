"""Table 1 — per-task accuracy of ColD vs baselines, plus the consistency
comparison (App. C): ColD should help on most tasks with small worst-case
regression."""
import numpy as np

from benchmarks import cold_main
from benchmarks import common as C


def run(rows: C.Rows):
    res, us = C.timed(cold_main.run)
    pre = res["pretrained"]["seen_ft_per_task"]
    mt = res["multitask"]["seen_ft_per_task"]
    cold = res["cold"]["seen_ft_per_task_final"]
    for tid in sorted(cold, key=int):
        p = pre[str(tid)] if isinstance(pre, dict) else pre[tid]
        m = mt[str(tid)] if isinstance(mt, dict) else mt[tid]
        c = cold[tid]
        rows.add(f"table1/task{int(tid):02d}", us,
                 f"finetune={p:.4f};multitask={m:.4f};cold={c:.4f}")
    deltas = [cold[t] - (pre[str(t)] if isinstance(pre, dict) else pre[int(t)]) for t in cold]
    helped = sum(1 for d in deltas if d > 0)
    rows.add("table1/consistency", us,
             f"helped={helped}/{len(deltas)};worst={min(deltas):+.4f};mean={np.mean(deltas):+.4f}")
