"""Fig. 3 — seen vs unseen evaluation of the ColD base model (claim C3)."""
from benchmarks import cold_main
from benchmarks import common as C


def run(rows: C.Rows):
    res, us = C.timed(cold_main.run)
    cold, pre = res["cold"], res["pretrained"]
    u_ft, u_fr = cold["unseen_ft"][-1], cold["unseen_fr"][-1]
    s_ft, s_fr = cold["seen_ft"][-1], cold["seen_fr"][-1]
    rows.add("fig3/cold_unseen_ft_final", us, f"acc={u_ft:.4f}")
    rows.add("fig3/cold_unseen_fr_final", us, f"acc={u_fr:.4f}")
    rows.add("fig3/cold_unseen_ft_curve", us, "curve=" + "|".join(f"{v:.4f}" for v in cold["unseen_ft"]))
    # C3a: unseen performance rises through iterations (paper Fig. 3's rising
    # orange curve); the seen/unseen absolute gap is reported as data — the
    # paper's near-equality rests on 3-fold pools of matched difficulty,
    # which the mini-scale eval subsets don't guarantee.
    curve = cold["unseen_ft"]
    rows.add("fig3/claim_C3a_unseen_improves_over_iters", us,
             f"pass={curve[-1] > curve[0]} first={curve[0]:.4f} last={curve[-1]:.4f}")
    rows.add("fig3/seen_vs_unseen_gap", us, f"gap={s_ft - u_ft:+.4f}")
    # C3b: unseen ft beats pretrained unseen ft (transfer to new tasks)
    rows.add("fig3/claim_C3b_unseen_gt_pretrained", us,
             f"pass={u_ft > pre['unseen_ft']} delta={u_ft - pre['unseen_ft']:+.4f}")
    # C3c: frozen gap — seen-frozen should exceed unseen-frozen (body never saw unseen)
    rows.add("fig3/claim_C3c_frozen_seen_gt_unseen", us,
             f"pass={s_fr > u_fr} seen_fr={s_fr:.4f} unseen_fr={u_fr:.4f}")
