"""App. F — fixed number of examples per contributor (streaming-new-tasks
simulation): accuracy should still increase monotonically-ish."""
from benchmarks import common as C
from repro.core import Repository, run_cold_fusion


def run(rows: C.Rows):
    k = C.KNOBS
    cfg = C.repro_cfg()
    suite = C.make_suite(36)
    body0 = C.pretrained_body(cfg, suite)
    # every contributor capped at the same small budget (paper: 5000)
    contribs = [C.make_contributor(cfg, suite, t, n=512, steps=k["steps"] // 2)
                for t in range(12)]
    ev = [C.make_eval_task(suite, t, n_train=256) for t in (0, 1)]
    iters = max(4, k["iters"] // 2)
    repo = Repository(body0)
    log, us = C.timed(
        run_cold_fusion, cfg, repo, contribs, iterations=iters,
        contributors_per_iter=4, eval_seen=ev, eval_every=max(1, iters // 3),
        eval_steps=k["eval_steps"], eval_lr=C.EVAL_LR,
    )
    curve_ft = log.mean("seen_finetuned")
    curve_fr = log.mean("seen_frozen")
    rows.add("appF/fixed_examples_ft_curve", us, "curve=" + "|".join(f"{v:.4f}" for v in curve_ft))
    rows.add("appF/fixed_examples_fr_curve", us, "curve=" + "|".join(f"{v:.4f}" for v in curve_fr))
    # at this scale the finetuned eval saturates; the frozen (single-model)
    # series carries the paper's "still increasing" signal
    rows.add("appF/claim_increases_frozen", us,
             f"pass={curve_fr[-1] > curve_fr[0]} first={curve_fr[0]:.4f} last={curve_fr[-1]:.4f}")
