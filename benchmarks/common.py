"""Shared setup for the paper-reproduction benchmarks.

Scale: the paper's grid (RoBERTa-base, 36 HF datasets, 4800 A100-hours) is
reproduced at laptop scale — a 2-layer d=64 encoder over the synthetic
36-task suite (DESIGN.md §6).  Claims are validated on *orderings and curve
shapes*, not absolute accuracies.

Env knobs:
  REPRO_BENCH_SCALE=quick|std|full   (default std)
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Sequence

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs.roberta_base import TINY
from repro.core import Contributor, EvalTask
from repro.data.synthetic import SyntheticSuite
from repro.models import encoder as E
from repro.train.pretrain import pretrain_mlm

SEQ = 24
SCALE = os.environ.get("REPRO_BENCH_SCALE", "std")
CACHE = os.path.join(os.path.dirname(__file__), "_cache")
ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

# experiment-scale knobs per mode
KNOBS = {
    #        iters contrib/it steps  eval_steps eval_tasks n_train
    "quick": dict(iters=3, per_iter=4, steps=30, eval_steps=60, n_eval=2, n_train=1024),
    "std":   dict(iters=8, per_iter=6, steps=50, eval_steps=100, n_eval=3, n_train=2048),
    "full":  dict(iters=14, per_iter=8, steps=80, eval_steps=150, n_eval=5, n_train=3072),
}[SCALE]

LR = 2e-3
EVAL_LR = 2e-3


def repro_cfg():
    return dataclasses.replace(
        TINY, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, max_seq_len=SEQ + 8,
    )


def make_suite(num_tasks: int = 36, seed: int = 0) -> SyntheticSuite:
    return SyntheticSuite(vocab_size=256, num_tasks=num_tasks, seed=seed, noise=0.15)


def pretrained_body(cfg, suite, *, steps: int = 300, seed: int = 0):
    """MLM-pretrained body, cached on disk (the θ₀ of every experiment)."""
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"pretrained_s{seed}_{SCALE}.npz")
    if os.path.exists(path):
        return ckpt.load(path)
    body, _ = pretrain_mlm(cfg, suite, steps=steps, seq_len=SEQ, seed=seed)
    ckpt.save(path, body)
    return body


def make_contributor(cfg, suite, tid: int, *, n: int, steps: int, seed: int = 0) -> Contributor:
    d = suite.dataset(tid, n, 64, SEQ)
    return Contributor(
        cfg, tid, suite.tasks[tid].num_classes, d["x_train"], d["y_train"],
        steps=steps, batch_size=32, lr=LR, seed=seed * 131 + tid,
    )


def make_eval_task(suite, tid: int, *, n_train: int = 512, n_test: int = 384) -> EvalTask:
    d = suite.dataset(tid, n_train, n_test, SEQ, split_seed=1)
    return EvalTask(tid, suite.tasks[tid].num_classes,
                    d["x_train"], d["y_train"], d["x_test"], d["y_test"])


def mean_acc(scores: Dict[int, float]) -> float:
    return float(np.mean(list(scores.values())))


class Rows:
    """CSV accumulator: name,us_per_call,derived."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us: float, derived: str):
        self.rows.append(f"{name},{us:.1f},{derived}")

    def emit(self):
        for r in self.rows:
            print(r)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def save_json(name: str, payload):
    import json

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, name + ".json"), "w") as f:
        json.dump(payload, f, indent=2)
