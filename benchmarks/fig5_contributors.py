"""Fig. 5 — effect of the number of contributors per iteration (claim C5):
>=2 contributors reach similar quality; more = more stable."""
import json
import os

import numpy as np

from benchmarks import common as C
from repro.core import Repository, run_cold_fusion


def run(rows: C.Rows):
    k = C.KNOBS
    cfg = C.repro_cfg()
    suite = C.make_suite(36)
    body0 = C.pretrained_body(cfg, suite)
    contribs = [C.make_contributor(cfg, suite, t, n=k["n_train"], steps=k["steps"])
                for t in range(12)]
    # paper §4.1: a consistent sampled eval set for this compute-heavy sweep
    ev = [C.make_eval_task(suite, t, n_train=256) for t in (0, 1)]
    iters = max(3, k["iters"] // 2)
    finals = {}
    for n_c in (2, 5, 8):
        repo = Repository(body0)
        log, us = C.timed(
            run_cold_fusion, cfg, repo, contribs, iterations=iters,
            contributors_per_iter=n_c, eval_seen=ev, eval_every=iters,
            eval_steps=k["eval_steps"], eval_lr=C.EVAL_LR, seed=n_c,
        )
        acc = log.mean("seen_finetuned")[-1]
        finals[n_c] = acc
        rows.add(f"fig5/contributors{n_c}_ft", us, f"acc={acc:.4f}")
    spread = max(finals.values()) - min(finals.values())
    rows.add("fig5/claim_C5_insensitive_to_contributors", 0.0,
             f"pass={spread < 0.08} spread={spread:.4f}")
    C.save_json("fig5", finals)
