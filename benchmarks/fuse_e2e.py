"""End-to-end Repository fuse benchmark — the ColD Fusion hot path.

Compares, for K=8 contributions of a ~1M-param model (non-block-aligned
leaf shapes, ~58 leaves), upload -> screen -> fuse -> publish wall time on:

* **seed per-leaf path** (``REPRO_NO_KERNELS`` oracle): ``upload`` keeps K
  live pytrees, ``screen_contributions`` re-reads every contribution for
  its diff norm, ``fusion.average`` re-reads everything again leaf by leaf
  — 3+ passes over the data and O(K x leaves) tiny device ops.
* **streaming flat engine**: ``upload`` folds each contribution into a flat
  staging row, ``fuse_pending`` issues ONE kernel launch that returns the
  fused model and the screening statistics together.

The speedup is recorded in BENCH_kernels.json (benchmarks/run.py) so every
future PR inherits the perf trajectory.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core.repository import Repository
from repro.kernels import ops

K = 8
D = 100           # deliberately not a multiple of 8*128
N_BLOCKS = 8


def _model(key):
    """~1M params over ~58 non-aligned leaves (a small transformer's shape
    census, without the model code)."""
    ks = jax.random.split(key, 2 + N_BLOCKS)
    tree = {"embed": jax.random.normal(ks[0], (397, D), jnp.float32) * 0.02,
            "final_norm": jnp.ones((D,), jnp.float32), "blocks": {}}
    for b in range(N_BLOCKS):
        kb = jax.random.split(ks[2 + b], 6)
        tree["blocks"][f"b{b:02d}"] = {
            "wq": jax.random.normal(kb[0], (D, D)) * 0.02,
            "wk": jax.random.normal(kb[1], (D, D)) * 0.02,
            "wv": jax.random.normal(kb[2], (D, D)) * 0.02,
            "wo": jax.random.normal(kb[3], (D, D)) * 0.02,
            "w_up": jax.random.normal(kb[4], (D, 399)) * 0.02,
            "w_down": jax.random.normal(kb[5], (399, D)) * 0.02,
            "norm": jnp.ones((D,), jnp.float32),
        }
    return tree


def _contributions(base, k):
    out = []
    for i in range(k):
        key = jax.random.PRNGKey(1000 + i)
        out.append(jax.tree.map(
            lambda x: x + jax.random.normal(
                jax.random.fold_in(key, x.size), x.shape, jnp.float32) * 0.01,
            base))
    return out


def _run_once(base, contribs, *, flat: bool) -> float:
    t0 = time.time()
    repo = Repository(base, use_flat=flat)
    for c in contribs:
        repo.upload(c)
    repo.fuse_pending()
    jax.block_until_ready(jax.tree.leaves(repo.download()))
    return (time.time() - t0) * 1e6


def _best_of(base, contribs, *, flat: bool, reps: int = 3) -> float:
    _run_once(base, contribs, flat=flat)  # warm the jit caches
    return min(_run_once(base, contribs, flat=flat) for _ in range(reps))


def run(rows: C.Rows):
    base = _model(jax.random.PRNGKey(0))
    contribs = _contributions(base, K)
    n_params = sum(x.size for x in jax.tree.leaves(base))
    n_leaves = len(jax.tree.leaves(base))

    prev = ops.kernels_enabled()
    try:
        ops.use_kernels(False)
        us_seed = _best_of(base, contribs, flat=False)
        ops.use_kernels(True)
        us_flat = _best_of(base, contribs, flat=True)
    finally:
        ops.use_kernels(prev)

    speedup = us_seed / us_flat
    gb = (K + 2) * n_params * 4 / 1e9
    rows.add("fuse_e2e/seed_per_leaf", us_seed,
             f"K={K};params={n_params};leaves={n_leaves}")
    rows.add("fuse_e2e/flat_stream", us_flat,
             f"speedup={speedup:.2f}x;stream_GB={gb:.3f}")
