"""End-to-end Repository fuse benchmark — the ColD Fusion hot path.

Compares, for K=8 contributions of a ~1M-param model (non-block-aligned
leaf shapes, ~58 leaves), upload -> screen -> fuse -> publish wall time on:

* **seed per-leaf path** (``REPRO_NO_KERNELS`` oracle): ``upload`` keeps K
  live pytrees, ``screen_contributions`` re-reads every contribution for
  its diff norm, ``fusion.average`` re-reads everything again leaf by leaf
  — 3+ passes over the data and O(K x leaves) tiny device ops.
* **streaming flat engine**: ``upload`` folds each contribution into a flat
  staging row, ``fuse_pending`` issues ONE kernel launch that returns the
  fused model and the screening statistics together.

A third row covers the **mesh-sharded engine** (docs/sharding.md): the same
upload -> screen -> fuse -> publish flow with the staging buffer laid out
block-cyclically over a forced 8-device CPU mesh
(``--xla_force_host_platform_device_count``).  Because the fake devices
share one physical CPU this measures the sharding *overhead* (layout,
shard_map dispatch, the one all-reduce), not a speedup — the number to
watch is that overhead staying small relative to the fuse itself.  Run
directly with ``python -m benchmarks.fuse_e2e --mesh 8``; ``run()`` spawns
that subprocess automatically (device count must be fixed before jax
initializes) and the rows land in BENCH_kernels.json.

A fourth row measures the **async double-buffered repository**
(docs/async_repository.md): R rounds of K uploads each, synchronous
(``fuse_pending(wait=True)`` — every round blocks on its fuse) vs
double-buffered (``wait=False`` — the device fuses cohort i while the host
stages cohort i+1).  The overlap ratio is hardware-dependent: the upload
staging is host memcpy and the fuse is device streaming, so on a machine
with spare cores/bandwidth the async path approaches
``(upload + fuse) / max(upload, fuse)``; on a narrow container the two
contend and the ratio compresses toward 1.  Run directly with
``python -m benchmarks.fuse_e2e --async``.

The speedup is recorded in BENCH_kernels.json (benchmarks/run.py) so every
future PR inherits the perf trajectory.
"""
import argparse
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core.repository import Repository
from repro.kernels import ops

K = 8
D = 100           # deliberately not a multiple of 8*128
N_BLOCKS = 8


def _model(key):
    """~1M params over ~58 non-aligned leaves (a small transformer's shape
    census, without the model code)."""
    ks = jax.random.split(key, 2 + N_BLOCKS)
    tree = {"embed": jax.random.normal(ks[0], (397, D), jnp.float32) * 0.02,
            "final_norm": jnp.ones((D,), jnp.float32), "blocks": {}}
    for b in range(N_BLOCKS):
        kb = jax.random.split(ks[2 + b], 6)
        tree["blocks"][f"b{b:02d}"] = {
            "wq": jax.random.normal(kb[0], (D, D)) * 0.02,
            "wk": jax.random.normal(kb[1], (D, D)) * 0.02,
            "wv": jax.random.normal(kb[2], (D, D)) * 0.02,
            "wo": jax.random.normal(kb[3], (D, D)) * 0.02,
            "w_up": jax.random.normal(kb[4], (D, 399)) * 0.02,
            "w_down": jax.random.normal(kb[5], (399, D)) * 0.02,
            "norm": jnp.ones((D,), jnp.float32),
        }
    return tree


def _contributions(base, k):
    out = []
    for i in range(k):
        key = jax.random.PRNGKey(1000 + i)
        out.append(jax.tree.map(
            lambda x: x + jax.random.normal(
                jax.random.fold_in(key, x.size), x.shape, jnp.float32) * 0.01,
            base))
    return out


def _run_once(base, contribs, *, flat: bool, mesh=None) -> float:
    t0 = time.time()
    repo = Repository(base, use_flat=flat if mesh is None else None, mesh=mesh)
    for c in contribs:
        repo.upload(c)
    repo.fuse_pending()
    jax.block_until_ready(jax.tree.leaves(repo.download()))
    return (time.time() - t0) * 1e6


def _best_of(base, contribs, *, flat: bool, mesh=None, reps: int = 3) -> float:
    _run_once(base, contribs, flat=flat, mesh=mesh)  # warm the jit caches
    return min(_run_once(base, contribs, flat=flat, mesh=mesh) for _ in range(reps))


ASYNC_ROUNDS = 6


def _run_rounds(base, cohorts, *, asynchronous: bool) -> float:
    """R rounds of (K uploads -> fuse): the synchronous path blocks on
    every fuse; the async path dispatches with ``wait=False`` so the device
    fuses cohort i while the host stages cohort i+1, and finalizes on the
    next round's ``fuse_pending`` (double buffering)."""
    t0 = time.time()
    repo = Repository(base, use_flat=True)
    for cohort in cohorts:
        for c in cohort:
            repo.upload(c)
        repo.fuse_pending(wait=not asynchronous)
    repo.flush()
    jax.block_until_ready(jax.tree.leaves(repo.download()))
    return (time.time() - t0) * 1e6


def _async_rows(rows: C.Rows, base, n_params: int, reps: int = 5) -> None:
    cohorts = [_contributions(base, K) for _ in range(ASYNC_ROUNDS)]
    for mode in (False, True):
        _run_rounds(base, cohorts, asynchronous=mode)  # warm the jit caches
    us_sync = min(_run_rounds(base, cohorts, asynchronous=False)
                  for _ in range(reps))
    us_async = min(_run_rounds(base, cohorts, asynchronous=True)
                   for _ in range(reps))
    overlap = us_sync / us_async
    rows.add("fuse_e2e/async_overlap", us_async,
             f"overlap={overlap:.2f}x;sync_us={us_sync:.1f};"
             f"rounds={ASYNC_ROUNDS};K={K};params={n_params}")


def run(rows: C.Rows):
    base = _model(jax.random.PRNGKey(0))
    contribs = _contributions(base, K)
    n_params = sum(x.size for x in jax.tree.leaves(base))
    n_leaves = len(jax.tree.leaves(base))

    prev = ops.kernels_enabled()
    try:
        ops.use_kernels(False)
        us_seed = _best_of(base, contribs, flat=False)
        ops.use_kernels(True)
        us_flat = _best_of(base, contribs, flat=True)
        _async_rows(rows, base, n_params)
    finally:
        ops.use_kernels(prev)

    speedup = us_seed / us_flat
    gb = (K + 2) * n_params * 4 / 1e9
    rows.add("fuse_e2e/seed_per_leaf", us_seed,
             f"K={K};params={n_params};leaves={n_leaves}")
    rows.add("fuse_e2e/flat_stream", us_flat,
             f"speedup={speedup:.2f}x;stream_GB={gb:.3f}")

    # mesh-sharded engine: the fake device count must be set before jax
    # initializes, so the measurement runs in a subprocess and its rows are
    # merged here (same CSV contract -> same BENCH_kernels.json entries)
    for line in _mesh_bench_subprocess(8):
        name, us, derived = line.split(",", 2)
        rows.add(name, float(us), derived)


def _force_device_env(n_devices: int) -> dict:
    """Env with the forced host-platform device count APPENDED to any
    pre-existing XLA_FLAGS (so user tuning/determinism flags survive and
    the mesh rows are measured under the same XLA config as the rest)."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (flags + " " if flags else "") + \
        f"--xla_force_host_platform_device_count={n_devices}"
    return env


def _mesh_bench_subprocess(n_devices: int):
    env = _force_device_env(n_devices)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    try:
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.fuse_e2e", "--mesh", str(n_devices)],
            capture_output=True, text=True, env=env, timeout=600,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
    except subprocess.TimeoutExpired:
        return [f"fuse_e2e/mesh{n_devices}_ERROR,0.0,timeout"]
    if res.returncode != 0:
        return [f"fuse_e2e/mesh{n_devices}_ERROR,0.0,rc={res.returncode}"]
    return [l for l in res.stdout.splitlines() if l.startswith("fuse_e2e/")]


def _mesh_main(n_devices: int) -> None:
    """Entry for the subprocess: sharded vs single-device fuse on a forced
    n-device host-platform mesh.  Prints fuse_e2e/ CSV rows on stdout."""
    assert jax.device_count() == n_devices, (
        f"expected {n_devices} devices, got {jax.device_count()} — "
        "set XLA_FLAGS=--xla_force_host_platform_device_count before jax init")
    mesh = jax.make_mesh((n_devices,), ("model",))
    base = _model(jax.random.PRNGKey(0))
    contribs = _contributions(base, K)
    n_params = sum(x.size for x in jax.tree.leaves(base))
    us_flat = _best_of(base, contribs, flat=True)
    us_mesh = _best_of(base, contribs, flat=True, mesh=mesh)
    overhead = us_mesh / us_flat
    print(f"fuse_e2e/mesh{n_devices}_sharded,{us_mesh:.1f},"
          f"K={K};params={n_params};shards={n_devices};"
          f"vs_1dev={overhead:.2f}x;collectives=1_allreduce")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="measure the sharded engine on N forced host devices "
                         "(requires XLA_FLAGS=--xla_force_host_platform_device_count=N; "
                         "set automatically when invoked via run())")
    ap.add_argument("--async", dest="asynchronous", action="store_true",
                    help="measure ONLY the async double-buffered overlap row "
                         "(sync vs wait=False over %d rounds)" % ASYNC_ROUNDS)
    args = ap.parse_args()
    rows = C.Rows()
    if args.asynchronous:
        base = _model(jax.random.PRNGKey(0))
        n_params = sum(x.size for x in jax.tree.leaves(base))
        _async_rows(rows, base, n_params)
        rows.emit()
        return
    if args.mesh:
        if (jax.device_count() != args.mesh
                and os.environ.get("_REPRO_MESH_REEXEC") != "1"):
            # direct CLI use without the flag: re-exec ONCE with it set (the
            # guard env var stops an exec loop on backends where forcing the
            # host-platform count cannot yield args.mesh devices, e.g. GPU)
            env = _force_device_env(args.mesh)
            env["_REPRO_MESH_REEXEC"] = "1"
            os.execvpe(sys.executable,
                       [sys.executable, "-m", "benchmarks.fuse_e2e",
                        "--mesh", str(args.mesh)], env)
        _mesh_main(args.mesh)
    else:
        run(rows)
        rows.emit()


if __name__ == "__main__":
    main()

