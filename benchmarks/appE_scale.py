"""App. E — multitask scale: performance of the fused base model as the
seen-task pool grows (4 -> 24 datasets)."""
from benchmarks import common as C
from repro.core import Repository, run_cold_fusion


def run(rows: C.Rows):
    k = C.KNOBS
    cfg = C.repro_cfg()
    suite = C.make_suite(36)
    body0 = C.pretrained_body(cfg, suite)
    ev = [C.make_eval_task(suite, t, n_train=256) for t in (30, 31)]  # fixed unseen evals
    iters = max(3, k["iters"] // 2)
    finals = {}
    for pool in (4, 8, 16, 24):
        contribs = [C.make_contributor(cfg, suite, t, n=k["n_train"] // 2, steps=k["steps"])
                    for t in range(pool)]
        repo = Repository(body0)
        log, us = C.timed(
            run_cold_fusion, cfg, repo, contribs, iterations=iters,
            contributors_per_iter=min(4, pool), eval_unseen=ev, eval_every=iters,
            eval_steps=k["eval_steps"], eval_lr=C.EVAL_LR,
        )
        finals[pool] = log.mean("unseen_finetuned")[-1]
        rows.add(f"appE/pool{pool:02d}_unseen_ft", us, f"acc={finals[pool]:.4f}")
    rows.add("appE/claim_high_regime_beats_low", 0.0,
             f"pass={max(finals[16], finals[24]) >= max(finals[4], finals[8]) - 0.01} "
             f"low={max(finals[4], finals[8]):.4f} high={max(finals[16], finals[24]):.4f}")
    C.save_json("appE", finals)
