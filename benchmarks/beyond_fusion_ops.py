"""Beyond-paper ablation: fusion-operator comparison inside the ColD loop.

The paper fuses by uniform averaging (§3) and lists weighted / Fisher /
damped fusion as future work (§8).  This benchmark runs the same 4-iteration
loop with each operator and compares final base-model quality.
"""
from benchmarks import common as C
from repro.core import Repository, run_cold_fusion


def run(rows: C.Rows):
    k = C.KNOBS
    cfg = C.repro_cfg()
    suite = C.make_suite(36)
    body0 = C.pretrained_body(cfg, suite)
    ev = [C.make_eval_task(suite, t, n_train=256) for t in (0, 1)]
    iters = max(3, k["iters"] // 2)

    ops = {
        "average": dict(fusion_op="average"),
        "damped0.5": dict(fusion_op="damped", fusion_kwargs={"alpha": 0.5}),
        "ties": dict(fusion_op="ties", fusion_kwargs={"density": 0.3}),
        "fisher": dict(fusion_op="fisher"),
    }
    finals = {}
    for name, kwargs in ops.items():
        contribs = [
            C.make_contributor(cfg, suite, t, n=k["n_train"] // 2, steps=k["steps"])
            for t in range(8)
        ]
        if name == "fisher":
            for c in contribs:
                c.with_fisher = True
        repo = Repository(body0, **kwargs)
        log, us = C.timed(
            run_cold_fusion, cfg, repo, contribs, iterations=iters,
            contributors_per_iter=4, eval_seen=ev, eval_every=iters,
            eval_steps=k["eval_steps"], eval_lr=C.EVAL_LR,
        )
        finals[name] = log.mean("seen_finetuned")[-1]
        rows.add(f"beyond/fusion_{name}_seen_ft", us, f"acc={finals[name]:.4f}")
    best = max(finals, key=finals.get)
    rows.add("beyond/fusion_best_op", 0.0,
             f"best={best};" + ";".join(f"{k}={v:.4f}" for k, v in finals.items()))
    C.save_json("beyond_fusion", finals)
