"""Service-loop throughput: queue-driven ingest vs direct-call upload.

The contributor service loop (docs/service_loop.md) moves contributions
through a durable on-disk queue instead of direct ``Repository.upload``
calls.  Both paths write each row to disk once (the queue submission IS
the spill row — ``ingest_spilled`` registers it by reference, no copy),
so the queue's added cost is the scan + metadata peek + queue-manifest
bookkeeping.  This bench measures that overhead end to end:

* **direct** — ``upload`` x K into a ``spill=True`` repository, then
  ``fuse_pending`` + ``flush`` (the PR 3 hot path);
* **queue**  — ``ContributorClient.submit`` x K, then ``ColdService``
  poll cycles until the cohort publishes and the queue is GC'd.

The ``service_loop/throughput`` row records the queue path's us/cohort
with the direct-path baseline and the ratio in the derived column; the
acceptance bar is the ratio staying within 1.3x.

The ``service_loop/novelty_screen`` row measures the content-based
novelty admission screen (docs/service_loop.md) on top of that: the same
queue path with ``novelty_threshold`` armed — every admission pays one
row-sketch read plus the window comparison and the atomic
``cohort_sketch.json`` persist.  All K contributions are distinct, so
the row isolates the screen's *overhead* (the cost of admitting, not
rejecting); the bar is screened admission staying within 1.3x of the
unscreened queue path.

The ``service_loop/regression_gate`` row measures the forgetting
regression gate (docs/observability.md) the same way: the queue path
with ``--gate``-equivalent probes armed, so every publish additionally
pays the probe scoring, the gate-state persist, and the synchronous
(``wait=True``) fuse the gate requires.  The bar is the gated cycle
staying within 1.3x of the ungated queue path end to end.  Before the
row is recorded, ``_gate_rollback_check`` runs the gate's correctness
scenario — a harmful cohort must trip exactly one rollback, land in
quarantine, and leave the base bit-identical to the benign fixed point —
so a gate that stopped gating can never post a (fast) number.

The ``service_loop/routed_fusion`` row measures similarity-routed
multi-base admission (docs/service_loop.md routing section): the same
queue path served over a ``RepositoryFamily`` with ``max_bases > 1``, so
every admission additionally pays the router's sketch-delta scoring and
the atomic move into the routed member's queue directory.  The split
rule is disarmed for the timed run (everything routes to ``main``), so
the row isolates routing *overhead*.  The baseline is the sketch-armed
single-base queue path (the ``novelty_screen`` run): routing's evidence
IS the sketch window, so the sketch machinery's own cost — priced
separately by the ``service_loop/novelty_screen`` row — is common to
both sides and what remains is the router's scoring, the routes ring,
and the family bookkeeping.  The bar is routed admission staying within
1.3x of that sketch-armed single-base path (the unscreened ratio is
reported alongside for context).  Before the row posts,
``_routed_check`` asserts (1) parity — the routed-to-main fuse lands
bit-close to the single-base fuse over the same rows — and (2)
separation — two dissimilar patterned streams split onto two members,
each publishing the closed-form fuse of only its own stream — so a
router that stopped routing (or stopped separating) can never post a
number.

The ``service_loop/delta_compression`` row measures the delta-compressed
submission path (docs/service_loop.md): K=24 sparse contributions enqueued
as (top-k indices, int8 values, per-block scales) payloads vs the same
contributions enqueued dense.  Before the row posts, the compressed run's
published base is asserted against the dense run's within the codec's
quantization tolerance AND the queue-bytes reduction is asserted >= 5x —
a codec that silently stopped compressing (or stopped reconstructing)
can never post a number.  Run directly with
``python -m benchmarks.service_loop --compress``.
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from benchmarks.fuse_e2e import K, _contributions, _model
from repro.core.repository import (Repository, RepositoryFamily,
                                   family_member_root)
from repro.checkpoint import io as ckpt
from repro.serve.cold_service import (QUEUE_DIR, AdmissionPolicy, ColdService,
                                      ContributorClient)
from repro.serve.probes import ProbeSuite, RegressionGate
from repro.utils.flat import LANE, FlatSpec


def _direct_once(base, contribs):
    """(ingest_us, total_us): upload x K staged+durable, then fuse+publish."""
    with tempfile.TemporaryDirectory(prefix="svc_direct_") as root:
        t0 = time.time()
        repo = Repository(base, root=root, spill=True, use_flat=True,
                          screen=False)
        for c in contribs:
            repo.upload(c)
        t_ingest = time.time()
        repo.fuse_pending()
        repo.flush()
        jax.block_until_ready(jax.tree.leaves(repo.download()))
        return (t_ingest - t0) * 1e6, (time.time() - t0) * 1e6


def _queue_once(base, contribs, **policy_kw):
    """(ingest_us, total_us): submit x K + admit cycles until the whole
    cohort is staged, then service cycles to publish + queue GC."""
    with tempfile.TemporaryDirectory(prefix="svc_queue_") as root:
        t0 = time.time()
        repo = Repository(base, root=root, spill=True, use_flat=True,
                          screen=False)
        # min_cohort > K: admission completes without triggering the
        # dispatch, so the ingest split point matches the direct path's
        # (K rows staged + durable, fuse not yet started)
        svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=K + 1,
                                                       **policy_kw))
        client = ContributorClient(root, name="bench")
        for c in contribs:
            client.submit(c)
        for _ in range(64):
            if svc.run_once()["staged"] == K:
                break
        t_ingest = time.time()
        svc.policy.min_cohort = K
        for _ in range(64):
            st = svc.run_once()
            if st["iteration"] >= 1 and not st["inflight"] \
                    and st["staged"] == 0:
                break
        svc.close()
        # a run that screened out a distinct contribution (or never fused)
        # must fail loudly, not get timed as if it had done the work
        assert st["iteration"] >= 1 and st["rejected_total"] == 0, st
        jax.block_until_ready(jax.tree.leaves(repo.download()))
        return (t_ingest - t0) * 1e6, (time.time() - t0) * 1e6


def _gate_once(base, contribs, gate):
    """(ingest_us, total_us): the queue path with the regression gate
    armed — identical flow to ``_queue_once`` plus the per-publish probe
    scoring, gate-state persist, and the synchronous fuse."""
    with tempfile.TemporaryDirectory(prefix="svc_gate_") as root:
        t0 = time.time()
        repo = Repository(base, root=root, spill=True, use_flat=True,
                          screen=False)
        svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=K + 1),
                          gate=gate)
        client = ContributorClient(root, name="bench")
        for c in contribs:
            client.submit(c)
        for _ in range(64):
            if svc.run_once()["staged"] == K:
                break
        t_ingest = time.time()
        svc.policy.min_cohort = K
        for _ in range(64):
            st = svc.run_once()
            if st["iteration"] >= 1 and not st["inflight"] \
                    and st["staged"] == 0:
                break
        svc.close()
        # a benign cohort that tripped the gate (or never fused) must fail
        # loudly, not get timed as if it had published
        assert st["iteration"] >= 1 and st["rollbacks_total"] == 0, st
        jax.block_until_ready(jax.tree.leaves(repo.download()))
        return (t_ingest - t0) * 1e6, (time.time() - t0) * 1e6


def _gate_rollback_check(base, contribs, gate):
    """The gate's correctness scenario, asserted before the perf row is
    recorded: benign cohort publishes clean; a harmful cohort (large
    uniform-norm noise, invisible to the MAD screen) trips exactly one
    rollback; every harmful row is quarantined; the base converges back
    bit-identically to the benign fixed point."""
    bad = [jax.tree.map(
        lambda x: x + jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(7000 + i), x.size),
            x.shape, jnp.float32) * 10.0, base) for i in range(K)]
    with tempfile.TemporaryDirectory(prefix="svc_gate_chk_") as root:
        repo = Repository(base, root=root, spill=True, use_flat=True,
                          screen=False)
        svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=K),
                          gate=gate)
        client = ContributorClient(root, name="bench")
        for c in contribs:
            client.submit(c)
        for _ in range(64):
            st = svc.run_once()
            if st["iteration"] >= 1 and not st["inflight"] \
                    and st["staged"] == 0:
                break
        assert st["iteration"] == 1 and st["rollbacks_total"] == 0, st
        good = np.array(repo.flat_base_host(), copy=True)
        for c in bad:
            client.submit(c, base_iteration=1)
        for _ in range(64):
            st = svc.run_once()
            if st["rollbacks_total"] and not st["inflight"] \
                    and st["staged"] == 0 and st["queue_depth"] == 0:
                break
        svc.close()
        assert st["rollbacks_total"] == 1, st
        assert st["quarantined_total"] == K, st
        assert st["iteration"] == 1, st
        assert np.array_equal(repo.flat_base_host(), good), \
            "rollback did not restore the benign fixed point"


def _routed_serve(base, spec, submit, k, *, dispatch, split_threshold,
                  max_bases=3):
    """Drive the ROUTED queue path to quiescence: enqueue ``k`` rows via
    ``submit(client)`` against a fresh ``RepositoryFamily``, admit with
    the dispatch held back (min_cohort > k — routing and spawning happen
    at admission, so the ingest split point still matches the single-base
    path's), then publish every member.  Returns
    ({member: fused_flat_row}, status, ingest_us, total_us)."""
    with tempfile.TemporaryDirectory(prefix="svc_routed_") as root:
        t0 = time.time()
        family = RepositoryFamily.create(base, root=root, spill=True,
                                         use_flat=True, screen=False)
        svc = ColdService(family=family, policy=AdmissionPolicy(
            min_cohort=k + 1, max_bases=max_bases,
            split_threshold=split_threshold))
        client = ContributorClient(root, name="bench")
        submit(client)
        for _ in range(64):
            if svc.run_once()["staged"] == k:
                break
        t_ingest = time.time()
        svc.policy.min_cohort = dispatch
        for _ in range(128):
            st = svc.run_once()
            if (all(f["iteration"] >= 1 for f in st["families"].values())
                    and not st["inflight"] and st["staged"] == 0
                    and st["queue_depth"] == 0):
                break
        svc.close()
        t_total = time.time()
        # a run that rejected (or never published) a member must fail
        # loudly, not get timed as if it had done the work
        assert st["rejected_total"] == 0, st
        assert all(f["iteration"] >= 1 for f in st["families"].values()), st
        bases = {}
        for name, f in st["families"].items():
            tree = ckpt.load(os.path.join(
                family_member_root(root, name),
                f"base_iter{f['iteration']:04d}.npz"))
            bases[name] = np.asarray(spec.flatten(tree))
        return bases, st, (t_ingest - t0) * 1e6, (t_total - t0) * 1e6


def _routed_check(base):
    """The router's correctness scenarios, asserted before the perf row
    is recorded: with the split rule disarmed every submission routes to
    ``main`` and the fuse is bit-close to the single-base queue path;
    with it armed, two dissimilar streams separate onto two members."""
    spec = FlatSpec.from_tree(base)
    base_row = np.asarray(spec.flatten(base))
    n = base_row.size
    nb = (n + LANE - 1) // LANE

    def pat(t):
        # per-LANE-tile constant signs: random per-element signs would
        # cancel inside the sketch's bucket sums and blind the router
        p = np.ones((nb * LANE,), np.float32)
        for j in range(nb):
            if (j + t) % 2:
                p[j * LANE:(j + 1) * LANE] = -1.0
        return p[:n]

    rows_all = [base_row + (c + 1) * 0.1 * pat(t)
                for t in (0, 1) for c in (0, 1)]

    def submit_rows(client):
        for r in rows_all:
            client.submit(row=r, spec=spec, base_iteration=0)

    with tempfile.TemporaryDirectory(prefix="svc_single_") as root:
        _, _, single_fused = _serve_submissions(root, base, submit_rows, 4)
    bases, st, _, _ = _routed_serve(base, spec, submit_rows, 4, dispatch=4,
                                    split_threshold=1e6)
    assert st["families_spawned_total"] == 0 and list(bases) == ["main"], st
    err = float(np.max(np.abs(bases["main"] - single_fused)))
    assert err < 1e-5, f"routed(main-only) fuse diverged from single-base " \
                       f"fuse: max|diff|={err}"
    bases, st, _, _ = _routed_serve(base, spec, submit_rows, 4, dispatch=2,
                                    split_threshold=0.8)
    assert st["families_spawned_total"] == 1 and len(bases) == 2, st
    for t in (0, 1):
        want = base_row + 0.15 * pat(t)  # mean of the stream's two deltas
        hits = [nm for nm, row in bases.items()
                if np.allclose(row, want, atol=1e-5)]
        assert len(hits) == 1, (t, hits, sorted(bases))


def _routed_once(base, spec, contribs):
    """(ingest_us, total_us): the routed queue path over the standard
    contribution set with the split rule disarmed — pure routing overhead
    against ``_queue_once``, identical fuse outcome."""
    def submit(client):
        for c in contribs:
            client.submit(c)
    _, st, ingest_us, total_us = _routed_serve(
        base, spec, submit, K, dispatch=K, split_threshold=1e6)
    assert st["families_spawned_total"] == 0, st
    return ingest_us, total_us


CK = 24           # compression row: a bigger cohort, where queue bytes bite
CKB = 64          # k_per_block — the codec's default sparsity budget


def _sparse_rows(base_row, k, *, per_block=48, scale=0.01, seed=2000):
    """K flat contributions, each a sparse per-block delta off ``base_row``
    (``per_block`` < CKB live entries per LANE block, so the top-k encode
    keeps every one and the only loss is int8 quantization).  This is the
    regime the codec is built for — a finetune that moved a minority of
    each block's weights."""
    n = base_row.size
    nb = (n + LANE - 1) // LANE
    rows_out = []
    for i in range(k):
        rng = np.random.default_rng(seed + i)
        delta = np.zeros((nb * LANE,), np.float32)
        for b in range(nb):
            pos = rng.choice(LANE, size=per_block, replace=False)
            delta[b * LANE + pos] = rng.normal(0, scale, per_block)
        rows_out.append(base_row + delta[:n])
    return rows_out


def _serve_submissions(root, base, submit, k):
    """Shared drive loop: enqueue ``k`` rows via ``submit(client)``, admit
    with the dispatch held back (min_cohort > k), then publish + GC.
    Returns (queue_bytes_after_enqueue, total_us, fused_base_host)."""
    t0 = time.time()
    repo = Repository(base, root=root, spill=True, use_flat=True,
                      screen=False)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=k + 1))
    client = ContributorClient(root, name="bench")
    submit(client)
    qdir = os.path.join(root, QUEUE_DIR)
    q_bytes = sum(os.path.getsize(os.path.join(qdir, f))
                  for f in os.listdir(qdir) if f.endswith(".npz"))
    for _ in range(64):
        if svc.run_once()["staged"] == k:
            break
    svc.policy.min_cohort = k
    for _ in range(64):
        st = svc.run_once()
        if st["iteration"] >= 1 and not st["inflight"] and st["staged"] == 0:
            break
    svc.close()
    assert st["iteration"] == 1 and st["rejected_total"] == 0, st
    fused = np.array(repo.flat_base_host(), copy=True)
    return q_bytes, (time.time() - t0) * 1e6, fused


def _compression_pair(base, spec, base_row, contrib_rows):
    """One dense run + one compressed run over the SAME contributions.
    Returns ((dense_bytes, dense_us, dense_fused),
             (comp_bytes, comp_us, comp_fused))."""
    def dense_submit(client):
        for r in contrib_rows:
            client.submit(row=r, spec=spec, base_iteration=0)

    def comp_submit(client):
        for r in contrib_rows:
            client.submit(row=r, spec=spec, base_iteration=0,
                          compress=True, base=base_row, k_per_block=CKB)

    with tempfile.TemporaryDirectory(prefix="svc_dense_") as root:
        d = _serve_submissions(root, base, dense_submit, len(contrib_rows))
    with tempfile.TemporaryDirectory(prefix="svc_comp_") as root:
        c = _serve_submissions(root, base, comp_submit, len(contrib_rows))
    return d, c


def _compression_rows(rows: C.Rows, reps: int = 2):
    base = _model(jax.random.PRNGKey(0))
    spec = FlatSpec.from_tree(base)
    n_params = spec.size
    base_row = np.asarray(spec.flatten(base))
    contrib_rows = _sparse_rows(base_row, CK)
    _compression_pair(base, spec, base_row, contrib_rows)  # warm jit caches
    pairs = [_compression_pair(base, spec, base_row, contrib_rows)
             for _ in range(reps)]
    (db, _, df), (cb, _, cf) = pairs[0]
    # correctness first: the compressed cohort must land on the dense
    # cohort's base to int8-quantization tolerance, and must have MOVED it
    assert not np.allclose(cf, base_row, atol=1e-6), "fuse was a no-op"
    err = float(np.max(np.abs(cf - df)))
    assert err < 5e-4, f"compressed fuse diverged from dense: max|diff|={err}"
    reduction = db / cb
    assert reduction >= 5.0, \
        f"queue-bytes reduction {reduction:.2f}x below the 5x bar"
    dt = min(p[0][1] for p in pairs)
    ct = min(p[1][1] for p in pairs)
    rows.add("service_loop/delta_compression", ct,
             f"bytes_per_sub={cb / CK:.0f};dense_bytes_per_sub={db / CK:.0f};"
             f"reduction={reduction:.1f}x;e2e_vs_dense={ct / dt:.2f}x;"
             f"parity=max_abs_{err:.1e};K={CK};k_per_block={CKB};"
             f"params={n_params}")


def run(rows: C.Rows, reps: int = 5):
    base = _model(jax.random.PRNGKey(0))
    contribs = _contributions(base, K)
    n_params = sum(x.size for x in jax.tree.leaves(base))
    # 0.01 sits an order of magnitude above replay-level sketch distances
    # (~1e-6) and safely below genuinely-distinct content: independent
    # random finetunes of this model land ~0.03+ relative distance (the
    # isotropic norm growth every finetune shares dominates the base-
    # relative scale and compresses distinct-pair distances — see
    # docs/service_loop.md on threshold calibration)
    novelty = dict(novelty_threshold=0.01, sketch_window=2 * K)
    # one probe pool for every gated run: construction is service-start
    # cost, not per-cohort cost, so it stays outside the timed region
    gate = RegressionGate(ProbeSuite(FlatSpec.from_tree(base).size))
    spec = FlatSpec.from_tree(base)
    _gate_rollback_check(base, contribs, gate)
    _routed_check(base)
    _direct_once(base, contribs)  # warm the jit caches
    _queue_once(base, contribs)
    _queue_once(base, contribs, **novelty)
    _gate_once(base, contribs, gate)
    _routed_once(base, spec, contribs)
    d = [_direct_once(base, contribs) for _ in range(reps)]
    q = [_queue_once(base, contribs) for _ in range(reps)]
    n = [_queue_once(base, contribs, **novelty) for _ in range(reps)]
    g = [_gate_once(base, contribs, gate) for _ in range(reps)]
    r = [_routed_once(base, spec, contribs) for _ in range(reps)]
    di, dt = min(x[0] for x in d), min(x[1] for x in d)
    qi, qt = min(x[0] for x in q), min(x[1] for x in q)
    ni, nt = min(x[0] for x in n), min(x[1] for x in n)
    gi, gt = min(x[0] for x in g), min(x[1] for x in g)
    ri, rt = min(x[0] for x in r), min(x[1] for x in r)
    rows.add("service_loop/throughput", qi,
             f"contribs_per_s={K / (qi / 1e6):.1f};direct_us={di:.1f};"
             f"vs_direct={qi / di:.2f}x;e2e_vs_direct={qt / dt:.2f}x;"
             f"K={K};params={n_params}")
    rows.add("service_loop/novelty_screen", ni,
             f"contribs_per_s={K / (ni / 1e6):.1f};unscreened_us={qi:.1f};"
             f"vs_unscreened={ni / qi:.2f}x;e2e_vs_unscreened={nt / qt:.2f}x;"
             f"K={K};params={n_params}")
    rows.add("service_loop/regression_gate", gt,
             f"contribs_per_s={K / (gt / 1e6):.1f};ungated_us={qt:.1f};"
             f"e2e_vs_ungated={gt / qt:.2f}x;ingest_vs_ungated={gi / qi:.2f}x;"
             f"rollback_check=pass;K={K};params={n_params}")
    rows.add("service_loop/routed_fusion", ri,
             f"contribs_per_s={K / (ri / 1e6):.1f};screened_us={ni:.1f};"
             f"vs_screened_single_base={ri / ni:.2f}x;"
             f"vs_unscreened={ri / qi:.2f}x;"
             f"e2e_vs_screened={rt / nt:.2f}x;"
             f"separation_check=pass;K={K};params={n_params}")
    _compression_rows(rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compress", action="store_true",
                    help="measure ONLY the delta-compression row "
                         "(queue bytes + e2e vs dense, parity asserted)")
    args = ap.parse_args()
    rows = C.Rows()
    if args.compress:
        _compression_rows(rows)
    else:
        run(rows)
    rows.emit()


if __name__ == "__main__":
    main()
