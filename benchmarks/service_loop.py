"""Service-loop throughput: queue-driven ingest vs direct-call upload.

The contributor service loop (docs/service_loop.md) moves contributions
through a durable on-disk queue instead of direct ``Repository.upload``
calls.  Both paths write each row to disk once (the queue submission IS
the spill row — ``ingest_spilled`` registers it by reference, no copy),
so the queue's added cost is the scan + metadata peek + queue-manifest
bookkeeping.  This bench measures that overhead end to end:

* **direct** — ``upload`` x K into a ``spill=True`` repository, then
  ``fuse_pending`` + ``flush`` (the PR 3 hot path);
* **queue**  — ``ContributorClient.submit`` x K, then ``ColdService``
  poll cycles until the cohort publishes and the queue is GC'd.

The ``service_loop/throughput`` row records the queue path's us/cohort
with the direct-path baseline and the ratio in the derived column; the
acceptance bar is the ratio staying within 1.3x.
"""
import tempfile
import time

import jax

from benchmarks import common as C
from benchmarks.fuse_e2e import K, _contributions, _model
from repro.core.repository import Repository
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient


def _direct_once(base, contribs):
    """(ingest_us, total_us): upload x K staged+durable, then fuse+publish."""
    with tempfile.TemporaryDirectory(prefix="svc_direct_") as root:
        t0 = time.time()
        repo = Repository(base, root=root, spill=True, use_flat=True,
                          screen=False)
        for c in contribs:
            repo.upload(c)
        t_ingest = time.time()
        repo.fuse_pending()
        repo.flush()
        jax.block_until_ready(jax.tree.leaves(repo.download()))
        return (t_ingest - t0) * 1e6, (time.time() - t0) * 1e6


def _queue_once(base, contribs):
    """(ingest_us, total_us): submit x K + admit cycles until the whole
    cohort is staged, then service cycles to publish + queue GC."""
    with tempfile.TemporaryDirectory(prefix="svc_queue_") as root:
        t0 = time.time()
        repo = Repository(base, root=root, spill=True, use_flat=True,
                          screen=False)
        # min_cohort > K: admission completes without triggering the
        # dispatch, so the ingest split point matches the direct path's
        # (K rows staged + durable, fuse not yet started)
        svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=K + 1))
        client = ContributorClient(root, name="bench")
        for c in contribs:
            client.submit(c)
        for _ in range(64):
            if svc.run_once()["staged"] == K:
                break
        t_ingest = time.time()
        svc.policy.min_cohort = K
        for _ in range(64):
            st = svc.run_once()
            if st["iteration"] >= 1 and not st["inflight"] \
                    and st["staged"] == 0:
                break
        svc.close()
        jax.block_until_ready(jax.tree.leaves(repo.download()))
        return (t_ingest - t0) * 1e6, (time.time() - t0) * 1e6


def run(rows: C.Rows, reps: int = 3):
    base = _model(jax.random.PRNGKey(0))
    contribs = _contributions(base, K)
    n_params = sum(x.size for x in jax.tree.leaves(base))
    _direct_once(base, contribs)  # warm the jit caches
    _queue_once(base, contribs)
    d = [_direct_once(base, contribs) for _ in range(reps)]
    q = [_queue_once(base, contribs) for _ in range(reps)]
    di, dt = min(x[0] for x in d), min(x[1] for x in d)
    qi, qt = min(x[0] for x in q), min(x[1] for x in q)
    rows.add("service_loop/throughput", qi,
             f"contribs_per_s={K / (qi / 1e6):.1f};direct_us={di:.1f};"
             f"vs_direct={qi / di:.2f}x;e2e_vs_direct={qt / dt:.2f}x;"
             f"K={K};params={n_params}")
