"""Fuse-to-serve load harness: concurrent inference + contribution traffic.

The paper's synergistic loop closes only when publishes reach requests:
this harness runs ONE repository with the full hot path live —

* a ``ColdService`` daemon fusing queue submissions (cohort per round),
* a ``ServingWorker`` (repro/serve/hot_swap.py) hot-swapping the engine
  onto every published base,
* N inference client threads generating continuously throughout,
* a contributor thread submitting a finetune each round and waiting for
  the worker to adopt the published result before the next round —

and then *proves* the swap seam: every request's tokens are recomputed
against the on-disk ``base_iterNNNN.npz`` of the iteration that served
it (compaction off, so every published base is retained).  A request is
**failed** if ``generate`` raised, and **version-torn** if its tokens
disagree with its served version's oracle — i.e. any part of the decode
ran against a different base than the one stamped on the result.  The
acceptance bar is zero failed and zero torn requests across >=3 live
swaps; only then does the ``serve_load/hot_swap`` row post
(us/request with swap + pinning counters in the derived column).

Run standalone (CI runs this at demo scale, forced 8-fake-device mesh):

  PYTHONPATH=src python -m benchmarks.serve_load --rounds 4 --clients 2
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.serve_load --mesh 8
"""
import argparse
import os
import sys
import tempfile
import threading
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.checkpoint import io as ckpt
from repro.configs import get_config, reduce_config
from repro.core.repository import Repository
from repro.models.transformer import init_lm
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient
from repro.serve.engine import Engine
from repro.serve.hot_swap import ServingWorker

PROMPT_LEN = 4
MAX_NEW = 4
MAX_LEN = 16


def _wait(pred, *, timeout: float, desc: str, interval: float = 0.01):
    deadline = time.monotonic() + timeout
    while not pred():
        if time.monotonic() >= deadline:
            raise TimeoutError(f"serve_load: timed out waiting for {desc}")
        time.sleep(interval)


def harness(*, arch: str = "gemma3-1b", rounds: int = 4, clients: int = 2,
            mesh: int = 0, root: str = None, poll: float = 0.01,
            timeout: float = 300.0) -> dict:
    """Drive the loop; return stats (requests/failed/torn/swaps/...)."""
    cfg = reduce_config(get_config(arch))
    params = init_lm(cfg, jax.random.PRNGKey(0))
    kw = {}
    if mesh:
        if jax.device_count() < mesh:
            raise SystemExit(
                f"--mesh {mesh} needs {mesh} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={mesh})")
        kw["mesh"] = jax.make_mesh((mesh,), ("model",))
    tmp = None
    if root is None:
        tmp = tempfile.TemporaryDirectory(prefix="serve_load_")
        root = tmp.name
    repo = Repository(params, root=root, spill=True, screen=False, **kw)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1))
    worker = ServingWorker(cfg, root, repo=repo, max_len=MAX_LEN)
    worker.poll_once()  # adopt iteration 0 before traffic starts

    prompts = np.arange(2, 2 + PROMPT_LEN, dtype=np.int32)[None, :]
    stop = threading.Event()
    lock = threading.Lock()
    served = []    # (iteration, tokens) per completed request
    failed = []    # exceptions out of generate()
    lat_us = []

    def infer_loop():
        # warm start included: the first request compiles the engine
        while not stop.is_set():
            try:
                r = worker.generate(prompts, max_new_tokens=MAX_NEW)
            except Exception as err:  # noqa: BLE001 - the bar is zero of these
                with lock:
                    failed.append(f"{type(err).__name__}: {err}")
                continue
            with lock:
                served.append((r.iteration, np.array(r.tokens)))
                lat_us.append(r.latency_s * 1e6)

    def service_loop():
        while not stop.is_set():
            try:
                svc.run_once()
            except Exception as err:  # noqa: BLE001
                with lock:
                    failed.append(f"service: {type(err).__name__}: {err}")
            time.sleep(poll)

    threads = [threading.Thread(target=service_loop, daemon=True)]
    threads += [threading.Thread(target=infer_loop, daemon=True)
                for _ in range(clients)]
    for t in threads:
        t.start()
    worker.start(interval=poll)

    # contributor: one finetune per round, each recycled from the previous
    # published base; the next round starts only after the worker ADOPTED
    # the publish, so every round is a live swap under open traffic
    client = ContributorClient(root, name="bench")
    t0 = time.time()
    try:
        for rnd in range(1, rounds + 1):
            prev = ckpt.load(os.path.join(root, f"base_iter{rnd-1:04d}.npz"))
            finetuned = jax.tree.map(lambda x, r=rnd: x + 0.003 * r, prev)
            client.submit(finetuned, base_iteration=rnd - 1)
            _wait(lambda r=rnd: worker.current_iteration == r,
                  timeout=timeout / rounds,
                  desc=f"worker adoption of iteration {rnd} "
                       f"(failed={failed[:3]})")
        # drain: every client sees at least one request on the final base
        n_done = len(served)
        _wait(lambda: len(served) >= n_done + clients or failed,
              timeout=30.0, desc="post-swap requests")
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30.0)
        wstate = worker.stop()
        svc.close()
    wall_s = time.time() - t0

    # -- tear check: recompute every served version's oracle ------------
    oracle = Engine(cfg, params, max_len=MAX_LEN)
    expected = {}
    for it in sorted({it for it, _ in served}):
        base = ckpt.load(os.path.join(root, f"base_iter{it:04d}.npz"))
        expected[it] = oracle.generate(prompts, max_new_tokens=MAX_NEW,
                                       params=base).tokens
    torn = sum(1 for it, toks in served
               if not np.array_equal(toks, expected[it]))
    stats = {
        "requests": len(served),
        "failed": len(failed),
        "failures": failed[:5],
        "torn": torn,
        "swaps_total": wstate["swaps_total"],
        "live_swaps": wstate["live_swaps"],
        "requests_pinned_across_swaps": wstate["requests_pinned_across_swaps"],
        "versions_served": wstate["versions_served"],
        "iteration": wstate["iteration"],
        "us_per_request": float(np.mean(lat_us)) if lat_us else 0.0,
        "wall_s": wall_s,
        "rounds": rounds,
        "clients": clients,
        "mesh": mesh,
    }
    if tmp is not None:
        tmp.cleanup()
    return stats


def check(stats: dict) -> None:
    """The acceptance bar: zero failed/torn requests across >=3 live
    swaps with inference traffic actually flowing the whole time."""
    assert stats["failed"] == 0, f"failed requests: {stats['failures']}"
    assert stats["torn"] == 0, f"{stats['torn']} version-torn requests"
    assert stats["live_swaps"] >= 3, f"only {stats['live_swaps']} live swaps"
    assert stats["requests"] > 0, "no inference traffic was served"
    assert stats["iteration"] == stats["rounds"], (
        f"worker ended on iteration {stats['iteration']}, "
        f"expected {stats['rounds']}")


def run(rows: C.Rows):
    """Bench entry (benchmarks/run.py): the hot-swap row posts only after
    the zero-failed / zero-torn / >=3-live-swaps bar holds."""
    rounds = {"quick": 4, "std": 5, "full": 8}[C.SCALE]
    stats = harness(rounds=rounds, clients=2)
    check(stats)
    rows.add(
        "serve_load/hot_swap", stats["us_per_request"],
        f"requests={stats['requests']};torn=0;failed=0;"
        f"live_swaps={stats['live_swaps']};"
        f"pinned={stats['requests_pinned_across_swaps']};"
        f"versions={len(stats['versions_served'])};"
        f"clients={stats['clients']}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="fuse-to-serve load harness")
    p.add_argument("--arch", default="gemma3-1b")
    p.add_argument("--rounds", type=int, default=4,
                   help="publish rounds (= live swaps; must be >=3)")
    p.add_argument("--clients", type=int, default=2,
                   help="concurrent inference client threads")
    p.add_argument("--mesh", type=int, default=0,
                   help="run the daemon's repository on an N-device mesh")
    p.add_argument("--root", default=None,
                   help="repository root (default: fresh temp dir)")
    args = p.parse_args(argv)
    stats = harness(arch=args.arch, rounds=args.rounds, clients=args.clients,
                    mesh=args.mesh, root=args.root)
    check(stats)
    print(f"[serve_load] OK: {stats['requests']} requests "
          f"({stats['us_per_request']:.0f} us/req) across "
          f"{stats['live_swaps']} live swaps, "
          f"{stats['requests_pinned_across_swaps']} pinned across a swap, "
          f"0 failed, 0 torn (versions={stats['versions_served']}, "
          f"mesh={args.mesh or 'none'}, {stats['wall_s']:.1f}s)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
