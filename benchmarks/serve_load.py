"""Fuse-to-serve load harness: concurrent inference + contribution traffic.

The paper's synergistic loop closes only when publishes reach requests:
this harness runs ONE repository with the full hot path live —

* a ``ColdService`` daemon fusing queue submissions (cohort per round),
* a ``ServingWorker`` (repro/serve/hot_swap.py) hot-swapping the engine
  onto every published base,
* N inference client threads generating continuously throughout,
* a contributor thread submitting a finetune each round and waiting for
  the worker to adopt the published result before the next round —

and then *proves* the swap seam: every request's tokens are recomputed
against the on-disk ``base_iterNNNN.npz`` of the iteration that served
it (compaction off, so every published base is retained).  A request is
**failed** if ``generate`` raised, and **version-torn** if its tokens
disagree with its served version's oracle — i.e. any part of the decode
ran against a different base than the one stamped on the result.  The
acceptance bar is zero failed and zero torn requests across >=3 live
swaps; only then does the ``serve_load/hot_swap`` row post
(us/request with swap + pinning counters in the derived column).

**Scale-out mode** (``--workers N``): the same contract, but through the
refactored serving stack — a ``WorkerPool`` of N single-process
``ServingWorker``s (each its own follower + engine + namespaced state
file) behind the least-loaded ``Router``, optionally with the
``BatchScheduler`` coalescing client requests per worker
(``--batch``).  Every routed response is tear-checked against the
oracle *at the executed batch shape* (bucketed batches tile identical
rows, and argmax ties may in principle resolve differently across XLA
batch tilings, so the oracle must replay the same ``[B, T]``).  The
``serve_load/scale_out`` row posts the workers x clients x batching
sweep: batched vs unbatched single-worker throughput, and 4-worker vs
1-worker aggregate throughput at equal client load — the 2.5x scale bar
is enforced on hosts with >= 4 CPU cores (a 1-core container cannot
scale CPU-bound work by adding processes; there the sweep instead
enforces a no-collapse floor and records the measured ratio, following
the async_overlap precedent).

``REPRO_HOST_TUNING=1`` additionally applies the host tuning recipe to
the pool children (tcmalloc preload when installed) and sweeps
``--xla_force_host_platform_device_count`` over ``--sweep-device-counts``,
recording the best setting in the row notes.

Run standalone (CI runs this at demo scale, forced 8-fake-device mesh):

  PYTHONPATH=src python -m benchmarks.serve_load --rounds 4 --clients 2
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.serve_load --mesh 8
  PYTHONPATH=src python -m benchmarks.serve_load --workers 2 --batch \\
      --clients 8 --rounds 3
"""
import argparse
import os
import sys
import tempfile
import threading
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.checkpoint import io as ckpt
from repro.configs import get_config, reduce_config
from repro.core.repository import Repository
from repro.launch import host_tuning
from repro.models.transformer import init_lm
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient
from repro.serve.engine import Engine
from repro.serve.hot_swap import ServingWorker
from repro.serve.worker_pool import WorkerPool

PROMPT_LEN = 4
MAX_NEW = 4
MAX_LEN = 16
# the scale bar (>=2.5x aggregate throughput at 4 workers vs 1) is a
# statement about a host that can actually run 4 workers in parallel;
# below this core count the sweep enforces the no-collapse floor instead
SCALE_BAR_MIN_CORES = 4
SCALE_BAR = 2.5
SCALE_FLOOR = 0.45
BATCH_BAR = 1.5


def _wait(pred, *, timeout: float, desc: str, interval: float = 0.01):
    deadline = time.monotonic() + timeout
    while not pred():
        if time.monotonic() >= deadline:
            raise TimeoutError(f"serve_load: timed out waiting for {desc}")
        time.sleep(interval)


def harness(*, arch: str = "gemma3-1b", rounds: int = 4, clients: int = 2,
            mesh: int = 0, root: str = None, poll: float = 0.01,
            timeout: float = 300.0) -> dict:
    """Drive the loop; return stats (requests/failed/torn/swaps/...)."""
    cfg = reduce_config(get_config(arch))
    params = init_lm(cfg, jax.random.PRNGKey(0))
    kw = {}
    if mesh:
        if jax.device_count() < mesh:
            raise SystemExit(
                f"--mesh {mesh} needs {mesh} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={mesh})")
        kw["mesh"] = jax.make_mesh((mesh,), ("model",))
    tmp = None
    if root is None:
        tmp = tempfile.TemporaryDirectory(prefix="serve_load_")
        root = tmp.name
    repo = Repository(params, root=root, spill=True, screen=False, **kw)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1))
    worker = ServingWorker(cfg, root, repo=repo, max_len=MAX_LEN)
    worker.poll_once()  # adopt iteration 0 before traffic starts

    prompts = np.arange(2, 2 + PROMPT_LEN, dtype=np.int32)[None, :]
    stop = threading.Event()
    lock = threading.Lock()
    served = []    # (iteration, tokens) per completed request
    failed = []    # exceptions out of generate()
    lat_us = []

    def infer_loop():
        # warm start included: the first request compiles the engine
        while not stop.is_set():
            try:
                r = worker.generate(prompts, max_new_tokens=MAX_NEW)
            except Exception as err:  # noqa: BLE001 - the bar is zero of these
                with lock:
                    failed.append(f"{type(err).__name__}: {err}")
                continue
            with lock:
                served.append((r.iteration, np.array(r.tokens)))
                lat_us.append(r.latency_s * 1e6)

    def service_loop():
        while not stop.is_set():
            try:
                svc.run_once()
            except Exception as err:  # noqa: BLE001
                with lock:
                    failed.append(f"service: {type(err).__name__}: {err}")
            time.sleep(poll)

    threads = [threading.Thread(target=service_loop, daemon=True)]
    threads += [threading.Thread(target=infer_loop, daemon=True)
                for _ in range(clients)]
    for t in threads:
        t.start()
    worker.start(interval=poll)

    # contributor: one finetune per round, each recycled from the previous
    # published base; the next round starts only after the worker ADOPTED
    # the publish, so every round is a live swap under open traffic
    client = ContributorClient(root, name="bench")
    t0 = time.time()
    try:
        for rnd in range(1, rounds + 1):
            prev = ckpt.load(os.path.join(root, f"base_iter{rnd-1:04d}.npz"))
            finetuned = jax.tree.map(lambda x, r=rnd: x + 0.003 * r, prev)
            client.submit(finetuned, base_iteration=rnd - 1)
            _wait(lambda r=rnd: worker.current_iteration == r,
                  timeout=timeout / rounds,
                  desc=f"worker adoption of iteration {rnd} "
                       f"(failed={failed[:3]})")
        # drain: every client sees at least one request on the final base
        n_done = len(served)
        _wait(lambda: len(served) >= n_done + clients or failed,
              timeout=30.0, desc="post-swap requests")
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30.0)
        wstate = worker.stop()
        svc.close()
    wall_s = time.time() - t0

    # -- tear check: recompute every served version's oracle ------------
    oracle = Engine(cfg, params, max_len=MAX_LEN)
    expected = {}
    for it in sorted({it for it, _ in served}):
        base = ckpt.load(os.path.join(root, f"base_iter{it:04d}.npz"))
        expected[it] = oracle.generate(prompts, max_new_tokens=MAX_NEW,
                                       params=base).tokens
    torn = sum(1 for it, toks in served
               if not np.array_equal(toks, expected[it]))
    stats = {
        "requests": len(served),
        "failed": len(failed),
        "failures": failed[:5],
        "torn": torn,
        "swaps_total": wstate["swaps_total"],
        "live_swaps": wstate["live_swaps"],
        "requests_pinned_across_swaps": wstate["requests_pinned_across_swaps"],
        "versions_served": wstate["versions_served"],
        "iteration": wstate["iteration"],
        "us_per_request": float(np.mean(lat_us)) if lat_us else 0.0,
        "wall_s": wall_s,
        "rounds": rounds,
        "clients": clients,
        "mesh": mesh,
    }
    if tmp is not None:
        tmp.cleanup()
    return stats


def check(stats: dict) -> None:
    """The acceptance bar: zero failed/torn requests across >=3 live
    swaps with inference traffic actually flowing the whole time."""
    assert stats["failed"] == 0, f"failed requests: {stats['failures']}"
    assert stats["torn"] == 0, f"{stats['torn']} version-torn requests"
    assert stats["live_swaps"] >= 3, f"only {stats['live_swaps']} live swaps"
    assert stats["requests"] > 0, "no inference traffic was served"
    assert stats["iteration"] == stats["rounds"], (
        f"worker ended on iteration {stats['iteration']}, "
        f"expected {stats['rounds']}")


def harness_pool(*, arch: str = "gemma3-1b", rounds: int = 3,
                 clients: int = 8, workers: int = 1, batch: bool = False,
                 poll: float = 0.01, timeout: float = 600.0,
                 root: str = None, measure_s: float = 4.0,
                 queue_depth: int = 64,
                 device_count: int = None) -> dict:
    """Scale-out harness: the daemon in-process, N worker PROCESSES
    (WorkerPool) behind the least-loaded Router, M client threads
    routing continuously while a contributor publishes each round.

    Throughput is measured over a steady-state window AFTER the last
    swap (jit warmup and adoption waits excluded — both cells of a
    ratio must measure the same regime); correctness (zero failed, zero
    torn) is asserted over the WHOLE run, swaps included.
    ``device_count`` forces ``--xla_force_host_platform_device_count``
    on the children (the host-tuning sweep's knob)."""
    cfg = reduce_config(get_config(arch))
    params = init_lm(cfg, jax.random.PRNGKey(0))
    tmp = None
    if root is None:
        tmp = tempfile.TemporaryDirectory(prefix="serve_scale_")
        root = tmp.name
    repo = Repository(params, root=root, spill=True, screen=False)
    repo.flush()   # iteration 0 durable before the children look
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1))
    env = {}
    if host_tuning.enabled():
        env = host_tuning.host_tuning_env(device_count=device_count)
    elif device_count is not None:
        env = {"XLA_FLAGS":
               f"--xla_force_host_platform_device_count={device_count}"}
    pool = WorkerPool(root, workers, arch=arch, engine="real",
                      max_len=MAX_LEN, poll=poll, batch=batch,
                      queue_depth=queue_depth, env=env,
                      warm=(PROMPT_LEN, MAX_NEW))
    pool.start(timeout=timeout)
    router = pool.router()

    prompt = np.arange(2, 2 + PROMPT_LEN, dtype=np.int32)
    stop = threading.Event()
    lock = threading.Lock()
    served = []    # (iteration, tokens[T+new], batch_size, t_done, lat_us)
    failed = []

    def client_loop():
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                r = router.route(prompt, max_new_tokens=MAX_NEW)
            except Exception as err:  # noqa: BLE001 - the bar is zero of these
                with lock:
                    failed.append(f"{type(err).__name__}: {err}")
                continue
            lat = (time.perf_counter() - t0) * 1e6
            with lock:
                served.append((r.iteration, np.array(r.tokens),
                               r.batch_size, time.monotonic(), lat))

    def service_loop():
        while not stop.is_set():
            try:
                svc.run_once()
            except Exception as err:  # noqa: BLE001
                with lock:
                    failed.append(f"service: {type(err).__name__}: {err}")
            time.sleep(poll)

    try:
        pool.wait_ready(iteration=0, timeout=timeout)
        threads = [threading.Thread(target=service_loop, daemon=True)]
        threads += [threading.Thread(target=client_loop, daemon=True)
                    for _ in range(clients)]
        for t in threads:
            t.start()

        contributor = ContributorClient(root, name="bench")
        for rnd in range(1, rounds + 1):
            prev = ckpt.load(os.path.join(root,
                                          f"base_iter{rnd-1:04d}.npz"))
            finetuned = jax.tree.map(lambda x, r=rnd: x + 0.003 * r, prev)
            contributor.submit(finetuned, base_iteration=rnd - 1)
            pool.wait_ready(iteration=rnd, timeout=timeout / rounds)
        # steady-state throughput window: all swaps done, caches warm,
        # and traffic demonstrably flowing post-swap (>= one request per
        # client since the final adoption)
        n_final = len(served)
        _wait(lambda: len(served) >= n_final + clients or failed,
              timeout=60.0, desc="post-swap traffic before measurement")
        t_m0 = time.monotonic()
        time.sleep(measure_s)
        t_m1 = time.monotonic()
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30.0)
        svc.close()
        worker_states = pool.states()
        pool.stop()

    # -- tear check at the EXECUTED batch shape -------------------------
    oracle = Engine(cfg, params, max_len=MAX_LEN)
    expected = {}
    torn = 0
    for it, toks, bsz, _t, _lat in served:
        key = (it, bsz)
        if key not in expected:
            base = ckpt.load(os.path.join(root, f"base_iter{it:04d}.npz"))
            tiled = np.repeat(prompt[None, :], bsz, axis=0)
            expected[key] = oracle.generate(
                tiled, max_new_tokens=MAX_NEW, params=base).tokens[0]
        if not np.array_equal(toks, expected[key]):
            torn += 1

    in_window = [(t, lat) for _it, _tk, _b, t, lat in served
                 if t_m0 <= t <= t_m1]
    window_s = max(t_m1 - t_m0, 1e-9)
    rstats = router.stats()
    live_swaps_total = sum(int((w or {}).get("live_swaps") or 0)
                           for w in worker_states.values())
    stats = {
        "requests": len(served),
        "failed": len(failed),
        "failures": failed[:5],
        "torn": torn,
        "workers": workers,
        "clients": clients,
        "batch": batch,
        "rounds": rounds,
        "live_swaps_total": live_swaps_total,
        "worker_iterations": {wid: (w or {}).get("iteration")
                              for wid, w in worker_states.items()},
        "requests_batched": sum(int((w or {}).get("requests_batched") or 0)
                                for w in worker_states.values()),
        "per_worker": rstats["per_worker"],
        "reroutes": rstats["reroutes_total"],
        "requests_measured": len(in_window),
        "throughput_rps": len(in_window) / window_s,
        "us_per_request": (float(np.mean([l for _t, l in in_window]))
                           if in_window else 0.0),
        "device_count": device_count,
    }
    if tmp is not None:
        tmp.cleanup()
    return stats


def check_pool(stats: dict, cell: str = "") -> None:
    """Per-cell acceptance: zero failed, zero torn, every worker ended
    on the final published base, every round was a live swap on every
    worker, and the measurement window actually saw traffic."""
    tag = f"[{cell}] " if cell else ""
    assert stats["failed"] == 0, (
        f"{tag}failed requests: {stats['failures']}")
    assert stats["torn"] == 0, f"{tag}{stats['torn']} version-torn requests"
    assert stats["live_swaps_total"] >= stats["rounds"] * stats["workers"], (
        f"{tag}only {stats['live_swaps_total']} live swaps across "
        f"{stats['workers']} workers x {stats['rounds']} rounds")
    bad = {w: it for w, it in stats["worker_iterations"].items()
           if it != stats["rounds"]}
    assert not bad, f"{tag}workers not on iteration {stats['rounds']}: {bad}"
    assert stats["requests_measured"] > 0, f"{tag}empty measurement window"
    if stats["batch"]:
        assert stats["requests_batched"] > 0, (
            f"{tag}batching enabled but no request was ever coalesced")


def run(rows: C.Rows):
    """Bench entry (benchmarks/run.py): the hot-swap row posts only after
    the zero-failed / zero-torn / >=3-live-swaps bar holds, then the
    scale-out sweep posts ``serve_load/scale_out`` — every swept cell
    must hold zero failed / zero torn, batched >= {BATCH_BAR}x unbatched
    at 1 worker, and 4-vs-1-worker aggregate throughput >= {SCALE_BAR}x
    on hosts with >= {SCALE_BAR_MIN_CORES} cores (no-collapse floor and
    an explicit note below that)."""
    rounds = {"quick": 4, "std": 5, "full": 8}[C.SCALE]
    stats = harness(rounds=rounds, clients=2)
    check(stats)
    rows.add(
        "serve_load/hot_swap", stats["us_per_request"],
        f"requests={stats['requests']};torn=0;failed=0;"
        f"live_swaps={stats['live_swaps']};"
        f"pinned={stats['requests_pinned_across_swaps']};"
        f"versions={len(stats['versions_served'])};"
        f"clients={stats['clients']}")

    # -- workers x clients x batching sweep -----------------------------
    # Two independent throughput axes, measured separately so each ratio
    # is apples-to-apples at equal client load: the BATCHING axis
    # (batched vs unbatched, 1 worker) and the SCALE-OUT axis (4 vs 1
    # workers, both unbatched — batching concentrates 8 clients into
    # near-full batches on 1 worker, so comparing batched cells across
    # worker counts conflates shrinking batch sizes with scaling).  The
    # combined cell (4 workers, batched) is the headline row.
    p_rounds = {"quick": 3, "std": 3, "full": 4}[C.SCALE]
    measure_s = {"quick": 4.0, "std": 8.0, "full": 12.0}[C.SCALE]
    clients = 8
    cells = {}
    for name, w, b in (("w1_unbatched", 1, False),
                       ("w1_batched", 1, True),
                       ("w4_unbatched", 4, False),
                       ("w4_batched", 4, True)):
        cells[name] = harness_pool(workers=w, clients=clients, batch=b,
                                   rounds=p_rounds, measure_s=measure_s)
        check_pool(cells[name], name)
    batch_ratio = (cells["w1_batched"]["throughput_rps"]
                   / max(cells["w1_unbatched"]["throughput_rps"], 1e-9))
    scale_ratio = (cells["w4_unbatched"]["throughput_rps"]
                   / max(cells["w1_unbatched"]["throughput_rps"], 1e-9))
    cores = os.cpu_count() or 1
    assert batch_ratio >= BATCH_BAR, (
        f"batched throughput only {batch_ratio:.2f}x unbatched at "
        f"{clients} clients (bar {BATCH_BAR}x)")
    if cores >= SCALE_BAR_MIN_CORES:
        scale_note = f"scale_bar={SCALE_BAR}x:enforced(cores={cores})"
        assert scale_ratio >= SCALE_BAR, (
            f"4-worker throughput only {scale_ratio:.2f}x 1-worker "
            f"(bar {SCALE_BAR}x on {cores} cores)")
    else:
        # a 1-core host cannot scale CPU-bound serving by adding
        # processes; enforce no-collapse and record the bar condition
        scale_note = (f"scale_bar={SCALE_BAR}x:needs>="
                      f"{SCALE_BAR_MIN_CORES}cores(have={cores})")
        assert scale_ratio >= SCALE_FLOOR, (
            f"4-worker throughput collapsed to {scale_ratio:.2f}x "
            f"1-worker (floor {SCALE_FLOOR}x)")
    tuning_note = ""
    if host_tuning.enabled():
        sweep = {}
        for n in (1, 2):
            st = harness_pool(workers=1, clients=clients, batch=True,
                              rounds=p_rounds, measure_s=measure_s,
                              device_count=n)
            check_pool(st, f"host_devices={n}")
            sweep[n] = st["throughput_rps"]
        best = max(sweep, key=sweep.get)
        tuning_note = (
            f";host_devices_best={best}"
            f";tcmalloc={'on' if host_tuning.tcmalloc_path() else 'absent'}")
    rows.add(
        "serve_load/scale_out", cells["w4_batched"]["us_per_request"],
        f"thr_w1={cells['w1_unbatched']['throughput_rps']:.1f}rps;"
        f"thr_w1_batched={cells['w1_batched']['throughput_rps']:.1f}rps;"
        f"thr_w4={cells['w4_unbatched']['throughput_rps']:.1f}rps;"
        f"thr_w4_batched={cells['w4_batched']['throughput_rps']:.1f}rps;"
        f"batch_ratio={batch_ratio:.2f};scale_ratio={scale_ratio:.2f};"
        f"{scale_note};clients={clients};torn=0;failed=0;"
        f"reroutes={cells['w4_batched']['reroutes']}{tuning_note}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="fuse-to-serve load harness")
    p.add_argument("--arch", default="gemma3-1b")
    p.add_argument("--rounds", type=int, default=4,
                   help="publish rounds (= live swaps; must be >=3)")
    p.add_argument("--clients", type=int, default=2,
                   help="concurrent inference client threads")
    p.add_argument("--mesh", type=int, default=0,
                   help="run the daemon's repository on an N-device mesh")
    p.add_argument("--root", default=None,
                   help="repository root (default: fresh temp dir)")
    p.add_argument("--workers", type=int, default=0,
                   help="scale-out mode: N worker PROCESSES behind the "
                        "router (0 = the classic in-process harness)")
    p.add_argument("--batch", action="store_true",
                   help="coalesce client requests per worker "
                        "(BatchScheduler; scale-out mode)")
    p.add_argument("--queue-depth", type=int, default=64,
                   help="per-worker bounded request queue (scale-out)")
    p.add_argument("--measure", type=float, default=4.0,
                   help="steady-state throughput window seconds "
                        "(scale-out)")
    p.add_argument("--sweep-device-counts", default=None, metavar="N,M",
                   help="also sweep --xla_force_host_platform_device_count "
                        "over these values on the pool children, printing "
                        "throughput per setting (scale-out)")
    args = p.parse_args(argv)
    if args.workers:
        stats = harness_pool(arch=args.arch, rounds=args.rounds,
                             clients=args.clients, workers=args.workers,
                             batch=args.batch, root=args.root,
                             queue_depth=args.queue_depth,
                             measure_s=args.measure)
        check_pool(stats)
        print(f"[serve_load] scale-out OK: {stats['requests']} requests "
              f"({stats['throughput_rps']:.1f} rps steady-state, "
              f"{stats['us_per_request']:.0f} us/req) across "
              f"{stats['workers']} workers x {stats['clients']} clients, "
              f"{stats['live_swaps_total']} live swaps, 0 failed, 0 torn "
              f"(batch={stats['batch']}, "
              f"coalesced={stats['requests_batched']}, "
              f"reroutes={stats['reroutes']}, "
              f"per_worker={stats['per_worker']})", flush=True)
        if args.sweep_device_counts:
            for n in (int(x) for x in args.sweep_device_counts.split(",")):
                st = harness_pool(arch=args.arch, rounds=args.rounds,
                                  clients=args.clients,
                                  workers=args.workers, batch=args.batch,
                                  queue_depth=args.queue_depth,
                                  measure_s=args.measure, device_count=n)
                check_pool(st, f"host_devices={n}")
                print(f"[serve_load]   host_devices={n}: "
                      f"{st['throughput_rps']:.1f} rps", flush=True)
        return 0
    stats = harness(arch=args.arch, rounds=args.rounds, clients=args.clients,
                    mesh=args.mesh, root=args.root)
    check(stats)
    print(f"[serve_load] OK: {stats['requests']} requests "
          f"({stats['us_per_request']:.0f} us/req) across "
          f"{stats['live_swaps']} live swaps, "
          f"{stats['requests_pinned_across_swaps']} pinned across a swap, "
          f"0 failed, 0 torn (versions={stats['versions_served']}, "
          f"mesh={args.mesh or 'none'}, {stats['wall_s']:.1f}s)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
