"""Roofline table (deliverable g): aggregate the dry-run artifacts into
per-(arch x shape x mesh) roofline rows."""
import glob
import json
import os

from benchmarks import common as C

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(rows: C.Rows):
    paths = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not paths:
        rows.add("roofline/NO_ARTIFACTS", 0.0,
                 "run `python -m repro.launch.dryrun --all --mesh both` first")
        return
    n_ok = n_skip = n_fail = 0
    for p in paths:
        with open(p) as f:
            d = json.load(f)
        name = os.path.basename(p)[:-5]
        if d.get("skipped"):
            n_skip += 1
            rows.add(f"roofline/{name}", 0.0, "skipped=subquadratic-only-shape")
            continue
        if not d.get("ok"):
            n_fail += 1
            rows.add(f"roofline/{name}", 0.0, f"FAILED={d.get('error', '?')[:60]}")
            continue
        n_ok += 1
        r = d["roofline"]
        peak = d.get("memory_analysis", {}).get("peak_memory_in_bytes", 0)
        rows.add(
            f"roofline/{name}",
            r["roofline_step_s"] * 1e6,
            f"bottleneck={r['bottleneck']};compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};collective_ms={r['collective_s']*1e3:.2f};"
            f"useful={r['useful_flops_ratio']:.3f};mfu={r['roofline_mfu']:.3f};"
            f"peak_GiB={peak/2**30:.2f};chips={d['chips']}",
        )
    rows.add("roofline/summary", 0.0, f"ok={n_ok};skipped={n_skip};failed={n_fail}")
