"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.render_roofline [--dir artifacts/dryrun]
"""
import argparse
import glob
import json
import os
from collections import defaultdict

LEVERS = {
    # one-sentence "what would move the dominant term down", keyed by (arch-prefix, bottleneck)
    "compute": "raise useful-FLOP ratio: window-limited attention, tighter MoE capacity, less remat",
    "memory": "cut activation traffic: window-limited KV slices, fused attention (Pallas on TPU), bf16 score accum",
    "collective": "reshard: keep grads sharded (reduce-scatter), shard attention heads/seq, raise ColD fusion interval H",
}


def fmt_row(d):
    r = d["roofline"]
    m = d.get("memory_analysis", {})
    return (
        f"| {d['arch']} | {d['shape']} | {d.get('strategy','sync')} | "
        f"{r['compute_s']*1e3:9.1f} | {r['memory_s']*1e3:9.1f} | {r['collective_s']*1e3:9.1f} | "
        f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} | {r['roofline_mfu']*100:5.1f}% | "
        f"{m.get('peak_memory_in_bytes',0)/2**30:6.2f} |"
    )


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="artifacts/dryrun")
    p.add_argument("--mesh", default="pod1")
    args = p.parse_args()

    rows = []
    skips = []
    fails = []
    pods2 = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        d = json.load(open(path))
        tag = os.path.basename(path)[:-5]
        if d.get("skipped"):
            if args.mesh in tag:
                skips.append((d.get("arch", tag), d.get("shape", ""), d["reason"]))
            continue
        if not d.get("ok"):
            fails.append((tag, d.get("error", "")))
            continue
        if f"__{args.mesh}" in tag:
            rows.append(d)
        elif "__pod2" in tag:
            pods2.append(d)

    print(f"### Single-pod (16x16 = 256 chips) roofline — {len(rows)} combos\n")
    print("| arch | shape | strat | compute ms | memory ms | collective ms | bottleneck | useful | roof-MFU | peak GiB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        print(fmt_row(d))
    if skips:
        print(f"\nSkipped ({len(skips)}, per DESIGN.md §4): " +
              "; ".join(f"{a} x {s}" for a, s, _ in skips))
    if fails:
        print(f"\nFAILURES ({len(fails)}):")
        for t, e in fails:
            print(f"  {t}: {e[:100]}")

    if pods2:
        print(f"\n### Multi-pod (2x16x16 = 512 chips) — {len(pods2)} combos, all compiled\n")
        print("| arch | shape | collective ms (pod2) | bottleneck | peak GiB |")
        print("|---|---|---|---|---|")
        for d in sorted(pods2, key=lambda x: (x["arch"], x["shape"])):
            r = d["roofline"]
            m = d.get("memory_analysis", {})
            print(f"| {d['arch']} | {d['shape']} | {r['collective_s']*1e3:9.1f} | "
                  f"{r['bottleneck']} | {m.get('peak_memory_in_bytes',0)/2**30:6.2f} |")

    # bottleneck summary + levers
    by_b = defaultdict(list)
    for d in rows:
        by_b[d["roofline"]["bottleneck"]].append(f"{d['arch']}x{d['shape']}")
    print("\n### Dominant bottlenecks\n")
    for b, lst in sorted(by_b.items()):
        print(f"- **{b}** ({len(lst)}): {', '.join(lst)}")
        print(f"  - lever: {LEVERS[b]}")


if __name__ == "__main__":
    main()
