"""Benchmark entry point: one module per paper table/figure + kernel micro +
the dry-run roofline table.  Prints ``name,us_per_call,derived`` CSV.

Kernel-level rows (``kernel/*`` and ``fuse_e2e/*``) are also written to
``BENCH_kernels.json`` at the repo root so the perf trajectory of the
Repository hot path survives across PRs.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,fig5] [--skip-main]
  REPRO_BENCH_SCALE=quick|std|full
"""
import argparse
import datetime
import json
import os
import sys
import time
import traceback

_KERNEL_PREFIXES = ("kernel/", "fuse_e2e/", "service_loop/", "serve_load/")
_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")


def _emit_kernel_json(rows) -> None:
    entries = {}
    for r in rows.rows:
        if not r.startswith(_KERNEL_PREFIXES):
            continue
        name, us, derived = r.split(",", 2)
        entries[name] = {"us_per_call": float(us), "derived": derived}
    if not entries:
        return
    import jax  # deferred: only the benches themselves need jax otherwise

    payload = {
        "generated": datetime.date.today().isoformat(),
        "scale": os.environ.get("REPRO_BENCH_SCALE", "std"),
        # pallas_interp rows run the interpret-mode harness regardless of
        # backend; the rest use the backend named here
        "backend": jax.default_backend(),
        "entries": entries,
    }
    with open(_BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {os.path.normpath(_BENCH_JSON)}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None, help="comma-separated bench names")
    args = p.parse_args()

    from benchmarks import common as C
    from benchmarks import (appE_scale, appF_fixed_examples, beyond_fusion_ops,
                            fig2_main, fig3_unseen, fig4_fewshot, fig5_contributors,
                            fig6_single_dataset, fuse_e2e, kernels_micro, roofline,
                            serve_load, service_loop, table1_per_task)

    benches = {
        "kernels": kernels_micro.run,
        "fuse_e2e": fuse_e2e.run,
        "service_loop": service_loop.run,
        "serve_load": serve_load.run,
        "fig2": fig2_main.run,
        "fig3": fig3_unseen.run,
        "fig4": fig4_fewshot.run,
        "table1": table1_per_task.run,
        "fig5": fig5_contributors.run,
        "fig6": fig6_single_dataset.run,
        "appE": appE_scale.run,
        "appF": appF_fixed_examples.run,
        "beyond_fusion": beyond_fusion_ops.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None
    rows = C.Rows()
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t1 = time.time()
        try:
            fn(rows)
        except Exception as e:
            rows.add(f"{name}/ERROR", 0.0, f"{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
        rows.rows.append(f"# {name} done in {time.time()-t1:.0f}s")
    rows.emit()
    _emit_kernel_json(rows)
    print(f"# total {time.time()-t0:.0f}s scale={C.SCALE}")


if __name__ == "__main__":
    main()
