"""Benchmark entry point: one module per paper table/figure + kernel micro +
the dry-run roofline table.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,fig5] [--skip-main]
  REPRO_BENCH_SCALE=quick|std|full
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None, help="comma-separated bench names")
    args = p.parse_args()

    from benchmarks import common as C
    from benchmarks import (appE_scale, appF_fixed_examples, beyond_fusion_ops,
                            fig2_main, fig3_unseen, fig4_fewshot, fig5_contributors,
                            fig6_single_dataset, kernels_micro, roofline,
                            table1_per_task)

    benches = {
        "kernels": kernels_micro.run,
        "fig2": fig2_main.run,
        "fig3": fig3_unseen.run,
        "fig4": fig4_fewshot.run,
        "table1": table1_per_task.run,
        "fig5": fig5_contributors.run,
        "fig6": fig6_single_dataset.run,
        "appE": appE_scale.run,
        "appF": appF_fixed_examples.run,
        "beyond_fusion": beyond_fusion_ops.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None
    rows = C.Rows()
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t1 = time.time()
        try:
            fn(rows)
        except Exception as e:
            rows.add(f"{name}/ERROR", 0.0, f"{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
        rows.rows.append(f"# {name} done in {time.time()-t1:.0f}s")
    rows.emit()
    print(f"# total {time.time()-t0:.0f}s scale={C.SCALE}")


if __name__ == "__main__":
    main()
