"""Fig. 2 — ColD Fusion vs pretrained / fused-once / multitask baselines on
seen tasks, both multitask goals (finetuned + linear probe)."""
from benchmarks import cold_main
from benchmarks import common as C


def run(rows: C.Rows):
    res, us = C.timed(cold_main.run)
    cold = res["cold"]
    pre, fused, mt = res["pretrained"], res["fused_once"], res["multitask"]
    final_ft, final_fr = cold["seen_ft"][-1], cold["seen_fr"][-1]
    rows.add("fig2/pretrained_seen_ft", us, f"acc={pre['seen_ft']:.4f}")
    rows.add("fig2/fused_once_seen_ft", us, f"acc={fused['seen_ft']:.4f}")
    rows.add("fig2/multitask_seen_ft", us, f"acc={mt['seen_ft']:.4f}")
    rows.add("fig2/cold_seen_ft_final", us, f"acc={final_ft:.4f}")
    rows.add("fig2/cold_seen_fr_final", us, f"acc={final_fr:.4f}")
    rows.add("fig2/cold_seen_ft_curve", us, "curve=" + "|".join(f"{v:.4f}" for v in cold["seen_ft"]))
    rows.add("fig2/cold_seen_fr_curve", us, "curve=" + "|".join(f"{v:.4f}" for v in cold["seen_fr"]))
    # claims: C1 ColD beats pretrained (and ideally fused/multitask); C2 frozen close to ft
    rows.add("fig2/claim_C1_cold_gt_pretrained", us,
             f"pass={final_ft > pre['seen_ft']} delta={final_ft - pre['seen_ft']:+.4f}")
    rows.add("fig2/claim_C1b_cold_ge_fused_once", us,
             f"pass={final_ft >= fused['seen_ft'] - 0.005} delta={final_ft - fused['seen_ft']:+.4f}")
    rows.add("fig2/claim_C2_frozen_improves", us,
             f"pass={final_fr > pre['seen_fr']} delta={final_fr - pre['seen_fr']:+.4f}")
