"""Recompute roofline JSONs from stored gzipped HLO (no recompilation).

  PYTHONPATH=src python -m benchmarks.reanalyze [--hlo artifacts/hlo] [--out artifacts/dryrun]
"""
import argparse
import glob
import gzip
import json
import os

from repro.utils.hlo_flops import analyze_hlo, wire_bytes
from repro.utils.roofline import Roofline


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--hlo", default="artifacts/hlo")
    p.add_argument("--out", default="artifacts/dryrun")
    args = p.parse_args()
    for path in sorted(glob.glob(os.path.join(args.hlo, "*.hlo.gz"))):
        tag = os.path.basename(path)[: -len(".hlo.gz")]
        # map hlo tag (mesh as 16x16) back to artifact tag (pod1/pod2)
        parts = tag.split("__")
        meshmap = {"16x16": "pod1", "2x16x16": "pod2"}
        if len(parts) >= 3 and parts[2] in meshmap:
            parts[2] = meshmap[parts[2]]
        jpath = os.path.join(args.out, "__".join(parts) + ".json")
        if not os.path.exists(jpath):
            continue
        with open(jpath) as f:
            d = json.load(f)
        if not d.get("ok"):
            continue
        with gzip.open(path, "rt") as f:
            hlo = f.read()
        an = analyze_hlo(hlo)
        roof = Roofline(
            flops=an.flops, hbm_bytes=an.hbm_bytes,
            collective_bytes=float(wire_bytes(an)),
            model_flops=d["roofline"]["model_flops_per_chip"],
            chips=d["chips"],
        )
        d["roofline"] = roof.as_dict()
        d["collectives"] = {
            "bytes_by_kind": {k: float(v) for k, v in an.collective_bytes.items()},
            "count_by_kind": {k: int(v) for k, v in an.collective_count.items()},
            "total_bytes": float(an.total_collective_bytes),
            "dynamic_whiles": an.dynamic_whiles,
        }
        with open(jpath, "w") as f:
            json.dump(d, f, indent=2)
        print("reanalyzed", os.path.basename(jpath))


if __name__ == "__main__":
    main()
