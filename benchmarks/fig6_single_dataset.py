"""Fig. 6 — single-dataset (federated) analysis, claims C6-C8:
(a) fresh data each iteration keeps improving (federated learning);
(b) more data per contributor -> closer to centralized finetuning;
(c) more contributors on fixed data -> better but slower convergence;
(d) distributing a fixed budget mostly delays convergence."""
import numpy as np

from benchmarks import common as C
from repro.core import Contributor, EvalTask, Repository, evaluate_base_model, run_cold_fusion
from repro.data.synthetic import SyntheticSuite
from repro.train import finetune as FT
from repro.models import encoder as E
import jax

TASK = 0  # the big "MNLI" analog


def _eval_task(suite, n_test=512):
    d = suite.dataset(TASK, 512, n_test, C.SEQ, split_seed=9)
    return EvalTask(TASK, suite.tasks[TASK].num_classes,
                    d["x_train"], d["y_train"], d["x_test"], d["y_test"])


def _frozen_acc(cfg, body, ev, steps):
    return C.mean_acc(evaluate_base_model(cfg, body, [ev], frozen=True,
                                          steps=steps, lr=C.EVAL_LR))


def run(rows: C.Rows):
    k = C.KNOBS
    cfg = C.repro_cfg()
    suite = C.make_suite(36)
    body0 = C.pretrained_body(cfg, suite)
    ev = _eval_task(suite)
    iters = max(3, k["iters"] // 2)
    es = k["eval_steps"]

    # (a) fresh samples per contributor per iteration — federated stream
    rng = np.random.default_rng(0)
    stream_contribs = []
    for c in range(5):
        d = suite.dataset(TASK, k["n_train"] * 4, 8, C.SEQ, split_seed=100 + c)
        stream_contribs.append(Contributor(
            cfg, TASK, suite.tasks[TASK].num_classes, d["x_train"], d["y_train"],
            steps=k["steps"], batch_size=32, lr=C.LR, seed=c))
    repo = Repository(body0)
    accs = []
    us_total = 0.0
    for it in range(iters):
        base = repo.download()
        for c in stream_contribs:
            # fresh slice each iteration = never-ending data flow
            lo = it * 1000 % (len(c.x) - 1000)
            xc, yc = c.x[lo:lo + 1000], c.y[lo:lo + 1000]
            head = c._ensure_head()
            body_ft, head, _ = FT.finetune(cfg, base, head, xc, yc,
                                           steps=k["steps"], batch_size=32,
                                           lr=C.LR, seed=it * 10 + c.seed)
            c._head = head
            repo.upload(body_ft)
        repo.fuse_pending()
        accs.append(_frozen_acc(cfg, repo.download(), ev, es))
    rows.add("fig6a/federated_frozen_curve", 0.0, "curve=" + "|".join(f"{a:.4f}" for a in accs))
    rows.add("fig6a/claim_C6_stream_improves", 0.0,
             f"pass={accs[-1] > accs[0]} first={accs[0]:.4f} last={accs[-1]:.4f}")

    # (b) dataset size per contributor (10 contributors, frozen eval)
    sizes = (256, 512, 1024)
    size_final = {}
    for n in sizes:
        contribs = []
        for c in range(4):
            d = suite.dataset(TASK, n, 8, C.SEQ, split_seed=200 + c * 17)
            contribs.append(Contributor(cfg, TASK, suite.tasks[TASK].num_classes,
                                        d["x_train"], d["y_train"],
                                        steps=max(15, n // 32), batch_size=32, lr=C.LR, seed=c))
        repo = Repository(body0)
        run_cold_fusion(cfg, repo, contribs, iterations=iters)
        size_final[n] = _frozen_acc(cfg, repo.download(), ev, es)
        rows.add(f"fig6b/size{n}_frozen", 0.0, f"acc={size_final[n]:.4f}")
    # centralized baseline: all data at once
    import itertools
    big = suite.dataset(TASK, sizes[-1] * 4, 8, C.SEQ, split_seed=777)
    key = jax.random.PRNGKey(0)
    head = E.init_cls_head(cfg, key, suite.tasks[TASK].num_classes)
    body_c, head_c, _ = FT.finetune(cfg, body0, head, big["x_train"], big["y_train"],
                                    steps=iters * max(15, sizes[-1] // 32), batch_size=32, lr=C.LR)
    central = FT.evaluate(cfg, body_c, head_c, ev.x_test, ev.y_test)
    rows.add("fig6b/centralized", 0.0, f"acc={central:.4f}")
    mono = size_final[sizes[0]] <= size_final[sizes[-1]] + 0.02
    rows.add("fig6b/claim_C7_more_data_closer_to_central", 0.0,
             f"pass={mono} small={size_final[sizes[0]]:.4f} large={size_final[sizes[-1]]:.4f} central={central:.4f}")

    # (c) number of contributors, same 1024 examples each
    nc_final = {}
    for n_c in (2, 5):
        contribs = []
        for c in range(n_c):
            d = suite.dataset(TASK, 1024, 8, C.SEQ, split_seed=300 + c * 31)
            contribs.append(Contributor(cfg, TASK, suite.tasks[TASK].num_classes,
                                        d["x_train"], d["y_train"],
                                        steps=32, batch_size=32, lr=C.LR, seed=c))
        repo = Repository(body0)
        run_cold_fusion(cfg, repo, contribs, iterations=iters)
        nc_final[n_c] = _frozen_acc(cfg, repo.download(), ev, es)
        rows.add(f"fig6c/contributors{n_c}_frozen", 0.0, f"acc={nc_final[n_c]:.4f}")
    rows.add("fig6c/claim_C8a_more_contributors_not_worse", 0.0,
             f"pass={nc_final[5] >= nc_final[2] - 0.03} c2={nc_final[2]:.4f} c5={nc_final[5]:.4f}")

    # (d) fixed total budget split across contributors
    total = 4096
    split_final = {}
    for n_c in (2, 8):
        per = total // n_c
        contribs = []
        for c in range(n_c):
            d = suite.dataset(TASK, per, 8, C.SEQ, split_seed=400 + c * 13)
            contribs.append(Contributor(cfg, TASK, suite.tasks[TASK].num_classes,
                                        d["x_train"], d["y_train"],
                                        steps=max(15, per // 32), batch_size=32, lr=C.LR, seed=c))
        repo = Repository(body0)
        run_cold_fusion(cfg, repo, contribs, iterations=iters)
        split_final[n_c] = _frozen_acc(cfg, repo.download(), ev, es)
        rows.add(f"fig6d/split{n_c}_frozen", 0.0, f"acc={split_final[n_c]:.4f}")
    rows.add("fig6d/claim_C8b_distribution_small_effect", 0.0,
             f"pass={abs(split_final[2] - split_final[8]) < 0.08} "
             f"c2={split_final[2]:.4f} c8={split_final[8]:.4f}")
    C.save_json("fig6", {"a": accs, "b": size_final, "c": nc_final, "d": split_final})
