"""Kernel micro-benchmarks: wall time per call (interpret mode on CPU — the
numbers validate plumbing, not TPU performance) and oracle-path timings with
derived bandwidth."""
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.kernels import ops, ref
from repro.kernels.cold_fuse import cold_fuse
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan


def _time(fn, *args, n=5, **kw):
    # block on the warmup so compile time never leaks into the timed loop,
    # and on EVERY timed call — jax dispatch is async, so un-blocked calls
    # only measure enqueue time, not the kernel
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.time() - t0) / n * 1e6


def run(rows: C.Rows):
    key = jax.random.PRNGKey(0)

    # cold_fuse: the Repository update for a 1M-param model, K=8 contributors
    K, N = 8, 1_000_000
    ks = jax.random.split(key, 3)
    base = jax.random.normal(ks[0], (N,), jnp.float32)
    contribs = jax.random.normal(ks[1], (K, N), jnp.float32)
    w = jnp.ones((K,))
    us_k = _time(cold_fuse, base, contribs, w, 1.0, n=3)
    us_r = _time(ref.cold_fuse, base, contribs, w, 1.0, n=3)
    gb = (K + 2) * N * 4 / 1e9
    rows.add("kernel/cold_fuse_pallas_interp", us_k, f"K={K};N={N};stream_GB={gb:.3f}")
    rows.add("kernel/cold_fuse_ref_xla", us_r, f"GBps={gb / (us_r / 1e6):.2f}")

    # flash attention 1k tokens
    q = jax.random.normal(ks[0], (1, 1024, 4, 64), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1024, 2, 64), jnp.float32)
    us_k = _time(flash_attention, q, kk, v, causal=True, n=1)
    us_r = _time(ref.flash_attention, q, kk, v, causal=True, n=3)
    fl = 4 * 1024 * 1024 * 4 * 64 / 2
    rows.add("kernel/flash_attn_pallas_interp", us_k, "S=1024;H=4;hd=64")
    rows.add("kernel/flash_attn_ref_xla", us_r, f"GFLOPs={fl/1e9:.2f}")

    # rwkv6 scan
    r = jax.random.normal(ks[0], (1, 256, 4, 32), jnp.float32)
    k2 = jax.random.normal(ks[1], (1, 256, 4, 32), jnp.float32)
    v2 = jax.random.normal(ks[2], (1, 256, 4, 32), jnp.float32)
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[0], (1, 256, 4, 32)) - 1.5), -4.0, -1e-3)
    u = jax.random.normal(ks[1], (4, 32)) * 0.5
    s0 = jnp.zeros((1, 4, 32, 32), jnp.float32)
    us_k = _time(rwkv6_scan, r, k2, v2, logw, u, s0, n=1)
    w6 = jnp.exp(logw)
    us_r = _time(ref.rwkv6_scan, r, k2, v2, w6, u, s0, n=3)
    rows.add("kernel/rwkv6_pallas_interp", us_k, "T=256;H=4;hd=32;chunk=16")
    rows.add("kernel/rwkv6_ref_scan_xla", us_r, f"speed_ratio={us_r/us_k:.3f}")

    # pytree-level fuse (8 contributors of the tiny encoder)
    from repro.models import encoder as E
    cfg = C.repro_cfg()
    bodies = [E.init_encoder_body(cfg, jax.random.PRNGKey(i)) for i in range(8)]
    (fused, sq), us = C.timed(ops.fuse_pytrees, bodies[0], bodies)
    rows.add("kernel/fuse_pytrees_8x", us, f"leaves={len(jax.tree.leaves(fused))}")
