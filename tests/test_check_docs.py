"""scripts/check_docs.py rule 4: documented call signatures are verified
against the live code via inspect.signature — stale docs fail the check."""
import importlib.util
import os
import sys

import pytest

_SCRIPTS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "scripts")


@pytest.fixture()
def checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(_SCRIPTS, "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.problems.clear()
    return mod


def _run(mod, text):
    mod.problems.clear()
    mod.check_signatures(os.path.join(mod.ROOT, "docs", "x.md"), text)
    return list(mod.problems)


def test_valid_signature_passes(checker):
    text = ("`repro.core.repository.Repository.fuse_pending(buffer=None, "
            "wait=True)` and `repro.kernels.ops.fuse_flat(base, contribs, "
            "weights, alpha, donate=False)`")
    assert _run(checker, text) == []


def test_ellipsis_and_star_markers_are_elided(checker):
    assert _run(checker,
                "`repro.core.repository.Repository.upload(params, ...)`") == []


def test_stale_parameter_fails(checker):
    probs = _run(checker,
                 "`repro.core.repository.Repository.fuse_pending(cohort=3)`")
    assert len(probs) == 1 and "no parameter 'cohort'" in probs[0]


def test_unresolvable_path_fails(checker):
    probs = _run(checker, "`repro.core.repository.Repository.no_such_fn(x)`")
    assert len(probs) == 1 and "does not resolve" in probs[0]


def test_class_constructor_checked(checker):
    assert _run(checker,
                "`repro.core.repository.Repository(base_params, spill=True, "
                "spill_workers=1, mesh=None)`") == []
    probs = _run(checker, "`repro.core.repository.Repository(bogus_kw=1)`")
    assert len(probs) == 1


def test_documented_params_parser(checker):
    f = checker._documented_params
    assert f("a, b=1, *, c=..., ...") == ["a", "b", "c"]
    assert f("") == []
    assert f("x={'k': (1, 2)}, y=[3, 4]") == ["x", "y"]
