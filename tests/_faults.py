"""Reusable fault-injection helpers for kill-at-checkpoint tests.

Generalizes the PR 3 kill-and-reopen pattern (tests/test_repository.py):
a child process runs a scenario script, armed to die with ``os._exit`` at
one named crash point (``repro.utils.faults.crash_point`` seams inside
the service/repository), and the test asserts the restarted process
converges to the uninterrupted run's state.

Also home of ``wait_until`` — the bounded polling helper the service
tests use instead of bare ``time.sleep`` (flake-hardening: every wait has
a deadline and a description, and polls a predicate rather than guessing
a duration).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import Callable, Optional, Sequence

from repro.utils import faults

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the hot-swap worker's kill windows (repro/serve/hot_swap.py): before the
# new base transfer, after transfer but before the pointer flip, and after
# the flip but before the serving-state persist.  The crash matrix
# (tests/test_hot_swap.py, docs/serving.md) proves a worker restarted from
# any of them serves a published, uncorrupted base.
SWAP_SEAMS = (
    "worker.pre_transfer",
    "worker.post_transfer_pre_flip",
    "worker.post_flip",
)


def run_child(script: str, args: Sequence[str] = (), *,
              crash_at: Optional[str] = None,
              env: Optional[dict] = None,
              timeout: float = 600.0) -> subprocess.CompletedProcess:
    """Run ``script`` (a ``python -c`` text, expected to put src/ on its
    own path) as a child process from the repo root.

    With ``crash_at`` the child is armed to die at that crash point; the
    call asserts it actually did (exit code ``faults.EXIT_CODE`` and the
    ``CRASH_POINT <name>`` marker on stderr) — a scenario that never
    reaches its armed point fails loudly instead of silently passing.
    Without ``crash_at`` the child must exit 0."""
    child_env = dict(os.environ)
    child_env.pop("XLA_FLAGS", None)
    child_env.pop(faults.ENV, None)
    child_env.update(env or {})
    if crash_at is not None:
        child_env[faults.ENV] = crash_at
    res = subprocess.run(
        [sys.executable, "-c", script, *args],
        capture_output=True, text=True, env=child_env, timeout=timeout,
        cwd=REPO_ROOT,
    )
    detail = f"rc={res.returncode}\n--- stdout ---\n{res.stdout}\n--- stderr ---\n{res.stderr}"
    if crash_at is not None:
        assert res.returncode == faults.EXIT_CODE, (
            f"child did not die at crash point {crash_at!r}: {detail}")
        assert f"CRASH_POINT {crash_at}" in res.stderr, (
            f"crash marker missing for {crash_at!r}: {detail}")
    else:
        assert res.returncode == 0, f"child failed: {detail}"
    return res


def wait_until(pred: Callable[[], object], *, timeout: float = 30.0,
               interval: float = 0.01, desc: str = "condition"):
    """Poll ``pred`` until truthy; return its value.  Raises TimeoutError
    with ``desc`` at the deadline — never an unbounded (or blind) sleep."""
    deadline = time.monotonic() + timeout
    while True:
        val = pred()
        if val:
            return val
        if time.monotonic() >= deadline:
            raise TimeoutError(f"timed out after {timeout}s waiting for {desc}")
        time.sleep(interval)
