"""Train-step machinery: microbatch-accumulation equivalence, loss descent,
linear probing freezes the body."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models.transformer import init_lm
from repro.optim.optimizers import constant_lr, make_optimizer, sgd
from repro.train import finetune as FT
from repro.train.step import make_train_state, make_train_step
from repro.models import encoder as E


def test_microbatch_equals_full_batch_grads(key):
    """SGD step with 4 microbatches == single-batch step (linear loss in
    grads => averaging microbatch grads is exact)."""
    cfg = reduce_config(get_config("gemma3-1b"))
    params = init_lm(cfg, key)
    opt = sgd(constant_lr(0.1))
    batch = {"tokens": jax.random.randint(key, (8, 16), 3, cfg.vocab_size)}
    s1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(make_train_state(params, opt), batch)
    s4, m4 = jax.jit(make_train_step(cfg, opt, microbatches=4))(make_train_state(params, opt), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_lm_loss_decreases(key):
    cfg = reduce_config(get_config("mistral-nemo-12b"))
    params = init_lm(cfg, key)
    opt = make_optimizer("adamw", constant_lr(3e-3))
    step = jax.jit(make_train_step(cfg, opt))
    state = make_train_state(params, opt)
    batch = {"tokens": jax.random.randint(key, (4, 16), 3, cfg.vocab_size)}
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_linear_probe_freezes_body(tiny_cfg, key):
    body = E.init_encoder_body(tiny_cfg, key)
    head = E.init_cls_head(tiny_cfg, key, 3)
    x = np.random.default_rng(0).integers(3, 64, (64, 16)).astype(np.int32)
    y = np.random.default_rng(1).integers(0, 3, 64).astype(np.int32)
    body2, head2, _ = FT.finetune(tiny_cfg, body, head, x, y, steps=5, frozen_body=True)
    for a, b in zip(jax.tree.leaves(body), jax.tree.leaves(body2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(head), jax.tree.leaves(head2))
    )
    assert changed


def test_full_finetune_changes_body(tiny_cfg, key):
    body = E.init_encoder_body(tiny_cfg, key)
    head = E.init_cls_head(tiny_cfg, key, 2)
    x = np.random.default_rng(0).integers(3, 64, (64, 16)).astype(np.int32)
    y = np.random.default_rng(1).integers(0, 2, 64).astype(np.int32)
    body2, _, _ = FT.finetune(tiny_cfg, body, head, x, y, steps=5)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(body), jax.tree.leaves(body2))
    )
    assert changed
