"""Similarity-routed multi-base fusion (docs/service_loop.md routing
section): admission routing + spawn/cap semantics, per-family compressed
vintage pinning, per-member gate isolation, the routing kill -9 crash
matrix (new seams ``service.post_route`` / ``repo.post_family_spawn``
plus the original five windows), a seeded interleaving property suite
over mixed-task streams, and the 20-run deflake proof for the
``--duplicates`` demo."""
import os
import subprocess
import sys

import numpy as np
import pytest

from _faults import run_child
from repro.checkpoint import io as ckpt
from repro.core.repository import (Repository, RepositoryFamily,
                                   family_member_root)
from repro.serve.cold_service import (QUEUE_DIR, AdmissionPolicy,
                                      ColdService, ContributorClient)
from repro.serve.probes import ProbeSuite, RegressionGate
from repro.utils.flat import LANE, FlatSpec, delta_encode

W, B = 2048, 17  # >= 2 full LANE tiles on w, so tile-sign patterns exist
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _pat(t, mod=2):
    """Task t's finetune direction: per-LANE-tile constant signs (random
    per-element signs would cancel inside the sketch's bucket sums and
    make every task look identical to the router)."""
    w = np.ones((W,), np.float32)
    for j in range(W // LANE):
        if (j + t) % mod == (0 if mod == 3 else 1):
            w[j * LANE:(j + 1) * LANE] = -1.0
    return {"w": w, "b": np.ones((B,), np.float32)}


def _zeros():
    return {"w": np.zeros((W,), np.float32),
            "b": np.zeros((B,), np.float32)}


def _fam(root, **kw):
    kw.setdefault("screen", False)
    kw.setdefault("spill", True)
    return RepositoryFamily.create(_zeros(), root=str(root), **kw)


def _svc(root, **pol):
    pol.setdefault("min_cohort", 2)
    pol.setdefault("max_bases", 3)
    return ColdService(family=_fam(root), policy=AdmissionPolicy(**pol))


def _drain(svc, max_cycles=200):
    for _ in range(max_cycles):
        st = svc.run_once()
        if (st["queue_depth"] == 0 and st["staged"] == 0
                and not st["inflight"]):
            return st
    raise AssertionError(f"service did not drain in {max_cycles} cycles: {st}")


def _member_base(root, name, iteration):
    bb = ckpt.load(os.path.join(family_member_root(str(root), name),
                                f"base_iter{iteration:04d}.npz"),
                   as_jax=False)
    return {k: np.asarray(v) for k, v in bb.items()}


def _match_members(root, st, tasks, want_w, *, mod=2):
    """Content-determined task->member matching: which member's base is
    the closed-form fuse of task t's stream (name assignment depends on
    arrival order, so tests must never assume 'main' == task 0)."""
    fams = st["families"]
    matched = {}
    for t in range(tasks):
        want = {k: want_w * v for k, v in _pat(t, mod=mod).items()}
        hits = [n for n, f in fams.items()
                if all(np.allclose(_member_base(root, n, f["iteration"])[k],
                                   want[k], atol=1e-5) for k in want)]
        assert len(hits) == 1, (t, hits, sorted(fams))
        matched[t] = hits[0]
    assert len(set(matched.values())) == tasks, matched
    return matched


def _submit_round(root, t, c, r, home="main", base=None):
    delta = (c + 1) * 0.1 * (r + 1)
    pat = _pat(t)
    if base is None:
        base = _zeros()
    fin = {k: np.asarray(base[k]) + delta * pat[k] for k in pat}
    return ContributorClient(root, name=f"t{t}c{c}").submit(
        fin, weight=1.0, base_iteration=r, family=home)


# ---------------------------------------------------------------------------
# separation: two dissimilar streams end up on two members, closed form
# ---------------------------------------------------------------------------


def test_two_streams_separate_closed_form(tmp_path):
    """Round 0 declared against main routes task 0 and task 1 onto two
    different members, each publishing the closed-form fuse of only its
    own stream; round 1 follows the routed member and stays separated."""
    root = str(tmp_path / "repo")
    svc = _svc(root)
    for t in range(2):
        for c in range(2):
            _submit_round(root, t, c, 0)
    st = _drain(svc)
    assert sorted(st["families"]) == ["f1", "main"]
    assert st["families_spawned_total"] == 1
    for f in st["families"].values():
        assert f["iteration"] == 1 and f["fused_contributions"] == 2
    matched = _match_members(root, st, 2, 0.15)
    # round 1: each stream follows its routed home member
    for t in range(2):
        home = matched[t]
        base = _member_base(root, home, 1)
        for c in range(2):
            _submit_round(root, t, c, 1, home=home, base=base)
    st = _drain(svc)
    assert sorted(st["families"]) == ["f1", "main"]
    for f in st["families"].values():
        assert f["iteration"] == 2 and f["fused_contributions"] == 4
    assert _match_members(root, st, 2, 0.45) == matched
    svc.close()


def test_routes_ring_and_route_of(tmp_path):
    """Every routed admission lands in the status routes ring with its
    decision; ``ContributorClient.route_of`` finds it by submission id."""
    root = str(tmp_path / "repo")
    svc = _svc(root)
    subs = [_submit_round(root, t, c, 0) for t in range(2) for c in range(2)]
    st = _drain(svc)
    routes = {r["id"]: r for r in st["routes"]}
    assert set(routes) == set(subs)
    assert "bootstrap" in routes[subs[0]]["reason"]
    assert sum(1 for r in routes.values() if r["spawned"]) == 1
    client = ContributorClient(root)
    for sub in subs:
        r = client.route_of(sub)
        assert r is not None and r["family"] in st["families"]
    # same-stream rows landed together, cross-stream rows apart
    assert routes[subs[0]]["family"] == routes[subs[1]]["family"]
    assert routes[subs[2]]["family"] == routes[subs[3]]["family"]
    assert routes[subs[0]]["family"] != routes[subs[2]]["family"]
    svc.close()


def test_spawn_cap_routes_to_nearest(tmp_path):
    """At ``max_bases`` the router stops minting members: a third
    dissimilar stream fuses into its nearest existing member instead of
    spawning, and nothing is dropped."""
    root = str(tmp_path / "repo")
    svc = _svc(root, min_cohort=1, max_bases=2)
    for t in range(3):
        for c in range(2):
            _submit_round(root, t, c, 0)
    st = _drain(svc)
    assert len(st["families"]) == 2
    assert st["families_spawned_total"] == 1
    assert sum(f["fused_contributions"]
               for f in st["families"].values()) == 6
    assert st["rejected_total"] == 0
    svc.close()


def test_unknown_declared_family_is_malformed(tmp_path):
    """A rider declaring a family the manifest has never heard of is a
    per-file rejection, not a crash and not a silent reroute."""
    root = str(tmp_path / "repo")
    svc = _svc(root, min_cohort=1)
    ContributorClient(root, name="c0").submit(
        {k: 0.1 * v for k, v in _pat(0).items()}, base_iteration=0,
        family="nope")
    st = _drain(svc)
    assert st["rejected_total"] == 1
    assert "unknown family" in st["recent_rejects"][0]["reason"]
    assert st["fused_contributions"] == 0
    svc.close()


# ---------------------------------------------------------------------------
# compressed submissions pin their vintage to (family, base_iteration)
# ---------------------------------------------------------------------------


def _two_member_family(root, svc):
    """Build a 2-member family: one benign round of two streams."""
    for t in range(2):
        for c in range(2):
            _submit_round(root, t, c, 0)
    st = _drain(svc)
    assert len(st["families"]) == 2
    return _match_members(root, st, 2, 0.15)


def test_compressed_cross_family_route_is_stale_reject(tmp_path):
    """Satellite bugfix: a delta encoded against family A's base whose
    content routes to family B must be a per-file 'stale' rejection —
    decoding it against B's base would silently corrupt B's cohort."""
    root = str(tmp_path / "repo")
    svc = _svc(root)
    matched = _two_member_family(root, svc)
    a, b = matched[0], matched[1]
    base_a = _member_base(root, a, 1)
    # hand-forge the cross-family rider: encoded against A's CURRENT
    # base (so the vintage itself is fresh), but the content moves in
    # task 1's direction, so the router sends it to B
    fin = {k: base_a[k] + 0.3 * _pat(1)[k] for k in base_a}
    sub = ContributorClient(root, name="forger").submit(
        fin, weight=1.0, base_iteration=svc._lanes[a].repo.iteration,
        compress=True, base=base_a, family=a)
    st = _drain(svc)
    rej = [r for r in st["recent_rejects"] if r["file"] == sub + ".npz"]
    assert len(rej) == 1, st["recent_rejects"]
    assert "stale" in rej[0]["reason"] and a in rej[0]["reason"]
    # nothing decoded, nothing fused, both members untouched
    for f in st["families"].values():
        assert f["iteration"] == 1 and f["fused_contributions"] == 2
    svc.close()


def test_compressed_spawn_decision_is_stale_reject(tmp_path):
    """A compressed rider whose content would FOUND a new member is
    equally unfusable (the new member's base is not the encoding base):
    rejected before any member is minted."""
    root = str(tmp_path / "repo")
    svc = _svc(root, min_cohort=1)
    # seed main with task-0 evidence so a task-1 row scores a spawn
    _submit_round(root, 0, 0, 0)
    st = _drain(svc)
    assert st["families_spawned_total"] == 0
    base = _member_base(root, "main", 1)
    fin = {k: base[k] + 0.3 * _pat(1)[k] for k in base}
    sub = ContributorClient(root, name="forger").submit(
        fin, weight=1.0, base_iteration=1, compress=True, base=base,
        family="main")
    st = _drain(svc)
    rej = [r for r in st["recent_rejects"] if r["file"] == sub + ".npz"]
    assert len(rej) == 1 and "stale" in rej[0]["reason"]
    assert st["families_spawned_total"] == 0  # no member minted for it
    assert len(st["families"]) == 1
    svc.close()


def test_ingest_spilled_cross_family_backstop(tmp_path):
    """Defense in depth under the service: a member Repository refuses
    outright to decode a delta declared against another family member,
    even if a (buggy) caller hands it one directly."""
    root = str(tmp_path / "repo")
    fam = _fam(root)
    fam.spawn(name="f1")
    main = fam.members["main"]
    spec = FlatSpec.from_tree(_zeros())
    base = np.zeros((spec.size,), np.float32)
    row = 0.1 * np.asarray(spec.flatten(_pat(0)), np.float32)
    pay = delta_encode(row, base, k_per_block=LANE)
    path = os.path.join(root, QUEUE_DIR, "forged.npz")
    ckpt.save_flat_delta(path, pay, spec, extra={
        "id": "x-000000", "base_iteration": 0, "family": "f1"})
    with pytest.raises(ValueError, match="stale.*family 'f1'"):
        main.ingest_spilled(path, weight=1.0)


# ---------------------------------------------------------------------------
# per-member gate isolation
# ---------------------------------------------------------------------------


def _gate(size):
    return RegressionGate(ProbeSuite(size, seed=0), tolerance=0.5)


def test_gate_trip_quarantines_only_one_member(tmp_path):
    """Satellite bugfix: a harmful cohort routed to one family member
    trips only that member's gate — it alone rolls back, the victim rows
    alone are quarantined, and the other member's base, iteration, and
    gate baseline never move."""
    root = str(tmp_path / "repo")
    fam = _fam(root)
    spec_size = FlatSpec.from_tree(_zeros()).size
    svc = ColdService(family=fam, policy=AdmissionPolicy(
        min_cohort=2, max_bases=2), gate=_gate(spec_size))
    matched = _two_member_family(root, svc)
    victim, bystander = matched[0], matched[1]
    pre_victim = _member_base(root, victim, 1)
    pre_bystander = _member_base(root, bystander, 1)
    # harmful cohort: colinear 40x-magnitude rows in the victim's task
    # direction — at the member cap they route to the victim (nearest),
    # pass the (disabled) screen, and wreck its probes
    for j in range(2):
        fin = {k: pre_victim[k] + (40.0 + j) * _pat(0)[k]
               for k in pre_victim}
        ContributorClient(root, name=f"bad{j}").submit(
            fin, weight=1.0, base_iteration=1, family=victim)
    st = _drain(svc)
    assert st["rollbacks_total"] == 1
    assert st["quarantined_total"] == 2
    vf, bf = st["families"][victim], st["families"][bystander]
    assert vf["iteration"] == 1          # rolled back to the benign base
    assert vf["last_gate"]["regressed"]  # the tripped tasks, per member
    np.testing.assert_allclose(
        _member_base(root, victim, 1)["w"], pre_victim["w"], atol=1e-6)
    # the bystander never noticed
    assert bf["iteration"] == 1 and bf["fused_contributions"] == 2
    assert bf["last_gate"] is None or not bf["last_gate"]["regressed"]
    np.testing.assert_allclose(
        _member_base(root, bystander, 1)["w"], pre_bystander["w"],
        atol=1e-6)
    svc.close()


# ---------------------------------------------------------------------------
# service-driven cross-fuse
# ---------------------------------------------------------------------------


def test_cross_fuse_every_blends_members_closed_form(tmp_path):
    """With ``cross_fuse_every`` armed, a quiescent family blends: every
    member lands exactly on the mean of the pre-cross bases, one
    iteration on, and the counter persists."""
    root = str(tmp_path / "repo")
    svc = _svc(root, cross_fuse_every=2)
    for t in range(2):
        for c in range(2):
            _submit_round(root, t, c, 0)
    st = _drain(svc)
    # the two round-0 publishes hit the schedule, so the blend already
    # fired inside the drain — on the quiescent cycle after the second
    assert st["cross_fuses_total"] == 1
    assert len(st["families"]) == 2
    # pre-cross bases (iteration 1) are the per-task closed forms ...
    pre = {n: _member_base(root, n, 1) for n in st["families"]}
    for t in range(2):
        want = {k: 0.15 * v for k, v in _pat(t).items()}
        assert sum(all(np.allclose(pre[n][k], want[k], atol=1e-5)
                       for k in want) for n in pre) == 1
    # ... and the blend (iteration 2) lands every member on their mean
    mean = {k: np.mean([bb[k] for bb in pre.values()], axis=0)
            for k in ("w", "b")}
    for n, f in st["families"].items():
        assert f["iteration"] == 2
        got = _member_base(root, n, 2)
        for k in mean:
            np.testing.assert_allclose(got[k], mean[k], atol=1e-5)
    svc.close()


# ---------------------------------------------------------------------------
# seeded interleaving property suite: streams never cross-contaminate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_mixed_task_interleavings_never_cross_contaminate(tmp_path, seed):
    """Shuffle three dissimilar streams' submissions into an arbitrary
    arrival order with service cycles interleaved at random points: the
    family always converges to exactly three members, each the closed
    form of one task's stream — a row never fuses into a foreign member
    and never fuses twice."""
    rng = np.random.default_rng(seed)
    root = str(tmp_path / "repo")
    svc = _svc(root, min_cohort=2, max_bases=3)
    subs = [(t, c) for t in range(3) for c in range(2)]
    rng.shuffle(subs)
    for t, c in subs:
        pat = _pat(t, mod=3)
        fin = {k: (c + 1) * 0.1 * v for k, v in pat.items()}
        ContributorClient(root, name=f"t{t}c{c}").submit(
            fin, weight=1.0, base_iteration=0, family="main")
        for _ in range(int(rng.integers(0, 3))):
            svc.run_once()
    st = _drain(svc)
    assert len(st["families"]) == 3
    assert st["families_spawned_total"] == 2
    for f in st["families"].values():
        assert f["iteration"] == 1 and f["fused_contributions"] == 2
    _match_members(root, st, 3, 0.15, mod=3)
    svc.close()


# ---------------------------------------------------------------------------
# routing crash matrix: kill -9 at every seam converges to the same states
# ---------------------------------------------------------------------------

_ROUTE_SCENARIO = '''
import os, sys
sys.path.insert(0, "src")
import numpy as np
from repro.checkpoint import io as ckpt
from repro.core.repository import RepositoryFamily, family_member_root
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient

root, phase = sys.argv[1], sys.argv[2]
W, B, LANE = 2048, 17, 1024

def pat(t):
    w = np.ones((W,), np.float32)
    for j in range(W // LANE):
        if (j + t) % 2:
            w[j*LANE:(j+1)*LANE] = -1.0
    return {"w": w, "b": np.ones((B,), np.float32)}

def zeros():
    return {"w": np.zeros((W,), np.float32), "b": np.zeros((B,), np.float32)}

if phase == "prep":
    RepositoryFamily.create(zeros(), root=root, spill=True, screen=False)
    for t in range(2):
        for c in range(2):
            fin = {k: (c + 1) * 0.1 * v for k, v in pat(t).items()}
            ContributorClient(root, name=f"t{t}c{c}").submit(
                fin, weight=1.0, base_iteration=0, family="main")
    print("PREP_OK", flush=True)
    sys.exit(0)

# phase == "serve": poll to quiescence (or die at the armed crash point)
fam = RepositoryFamily.open(root, spill=True)
svc = ColdService(family=fam,
                  policy=AdmissionPolicy(min_cohort=2, max_bases=3))
for _ in range(300):
    st = svc.run_once()
    fams = st.get("families") or {}
    if (st["queue_depth"] == 0 and st["staged"] == 0 and not st["inflight"]
            and len(fams) == 2
            and all(f["iteration"] >= 1 for f in fams.values())):
        break
else:
    print("NO_CONVERGENCE", st, flush=True)
    sys.exit(3)
st = svc.close()
fams = st["families"]
match = []
for t in range(2):
    want = {k: 0.15 * np.asarray(v) for k, v in pat(t).items()}
    hits = []
    for n, f in fams.items():
        mr = family_member_root(root, n)
        bb = ckpt.load(os.path.join(mr, f"base_iter{f['iteration']:04d}.npz"),
                       as_jax=False)
        if all(np.allclose(np.asarray(bb[k]), want[k], atol=1e-5)
               for k in want):
            hits.append(n)
    match.append(len(hits))
fused = sorted(f["fused_contributions"] for f in fams.values())
its = sorted(f["iteration"] for f in fams.values())
n_q = sum(len([f for f in os.listdir(l.queue_dir) if f.endswith(".npz")])
          for l in svc._lanes.values())
print(f"DONE members={len(fams)} match={match[0]}{match[1]} "
      f"fused={fused[0]}{fused[1]} its={its[0]}{its[1]} qfiles={n_q}",
      flush=True)
'''

# every window a routed submission's lifecycle crosses, in order: the
# routing move itself, the member mint, sketch persist, staging, fuse
# dispatch, the publish windows, and queue GC
ROUTE_CRASH_POINTS = [
    "service.post_route",
    "repo.post_family_spawn",
    "service.post_sketch",
    "service.post_ingest",
    "service.post_dispatch",
    "repo.post_publish_pre_manifest",
    "service.post_publish",
    "service.mid_gc",
]

_ROUTE_DONE = {"members": "2", "match": "11", "fused": "22", "its": "11",
               "qfiles": "0"}


def _done_line(res):
    line = [l for l in res.stdout.splitlines() if l.startswith("DONE")][0]
    return dict(kv.split("=") for kv in line.split()[1:])


@pytest.mark.slow
@pytest.mark.parametrize("point", ROUTE_CRASH_POINTS)
def test_routing_exactly_once_across_crash_points(tmp_path, point):
    """kill -9 the routed daemon at any seam, restart it: the family
    converges to the same two members as the uninterrupted run, every
    row fused exactly once into exactly one member (each member's base
    is the closed form of one task's stream), queues fully GC'd —
    never a third member, never a double-fuse, never a lost row."""
    root = str(tmp_path / "repo")
    run_child(_ROUTE_SCENARIO, [root, "prep"])
    run_child(_ROUTE_SCENARIO, [root, "serve"], crash_at=point)
    done = _done_line(run_child(_ROUTE_SCENARIO, [root, "serve"]))
    assert done == _ROUTE_DONE, (point, done)


@pytest.mark.slow
def test_routing_uninterrupted_reference_run(tmp_path):
    """The oracle the routing crash matrix compares against."""
    root = str(tmp_path / "repo")
    run_child(_ROUTE_SCENARIO, [root, "prep"])
    done = _done_line(run_child(_ROUTE_SCENARIO, [root, "serve"]))
    assert done == _ROUTE_DONE, done


# ---------------------------------------------------------------------------
# gate seams under a 2-member family: the trip replays onto ONE member
# ---------------------------------------------------------------------------

_ROUTE_GATE_SCENARIO = '''
import os, sys
sys.path.insert(0, "src")
import numpy as np
from repro.checkpoint import io as ckpt
from repro.core.repository import RepositoryFamily, family_member_root
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient
from repro.serve.probes import ProbeSuite, RegressionGate
from repro.utils.flat import FlatSpec

root, phase = sys.argv[1], sys.argv[2]
W, B, LANE = 2048, 17, 1024

def pat(t):
    w = np.ones((W,), np.float32)
    for j in range(W // LANE):
        if (j + t) % 2:
            w[j*LANE:(j+1)*LANE] = -1.0
    return {"w": w, "b": np.ones((B,), np.float32)}

def zeros():
    return {"w": np.zeros((W,), np.float32), "b": np.zeros((B,), np.float32)}

def gate():
    return RegressionGate(ProbeSuite(W + B, seed=0), tolerance=0.5)

def member_base(n, it):
    mr = family_member_root(root, n)
    bb = ckpt.load(os.path.join(mr, f"base_iter{it:04d}.npz"), as_jax=False)
    return {k: np.asarray(v) for k, v in bb.items()}

def victim_name(fams):
    # content-determined: the member whose benign base is task 0's
    want = 0.15 * pat(0)["w"]
    for n in fams:
        if np.allclose(member_base(n, 1)["w"], want, atol=1e-5):
            return n
    raise AssertionError("no member matches task 0")

def serve(stop):
    fam = RepositoryFamily.open(root, spill=True)
    svc = ColdService(family=fam, policy=AdmissionPolicy(
        min_cohort=2, max_bases=2), gate=gate())
    for _ in range(300):
        st = svc.run_once()
        if stop(st):
            break
    else:
        print("NO_CONVERGENCE", st, flush=True)
        sys.exit(3)
    st = svc.close()
    return st

if phase == "prep":
    RepositoryFamily.create(zeros(), root=root, spill=True, screen=False)
    for t in range(2):
        for c in range(2):
            fin = {k: (c + 1) * 0.1 * v for k, v in pat(t).items()}
            ContributorClient(root, name=f"t{t}c{c}").submit(
                fin, weight=1.0, base_iteration=0, family="main")
    print("PREP_OK", flush=True)
    sys.exit(0)

if phase == "serve_clean":
    serve(lambda st: len(st.get("families") or {}) == 2
          and all(f["iteration"] >= 1
                  for f in st["families"].values())
          and not st["inflight"] and st["staged"] == 0
          and st["queue_depth"] == 0)
    sys.exit(0)

if phase == "plant":
    fam = RepositoryFamily.open(root, spill=True)
    victim = victim_name(list(fam.members))
    vb = member_base(victim, 1)
    for j in range(2):
        fin = {k: vb[k] + (40.0 + j) * pat(0)[k] for k in vb}
        ContributorClient(root, name=f"bad{j}").submit(
            fin, weight=1.0, base_iteration=1, family=victim)
    print("PLANT_OK", flush=True)
    sys.exit(0)

# phase == "serve": drive the harmful cohort through
# route -> publish -> probe -> quarantine -> rollback on ONE member
st = serve(lambda st: st["rollbacks_total"] >= 1
           and not st["inflight"] and st["staged"] == 0
           and st["queue_depth"] == 0)
fams = st["families"]
victim = victim_name(list(fams))
bystander = [n for n in fams if n != victim][0]
v_ok = (fams[victim]["iteration"] == 1
        and np.allclose(member_base(victim, 1)["w"],
                        0.15 * pat(0)["w"], atol=1e-5))
b_ok = (fams[bystander]["iteration"] == 1
        and fams[bystander]["fused_contributions"] == 2
        and np.allclose(member_base(bystander, 1)["w"],
                        0.15 * pat(1)["w"], atol=1e-5))
qdir = os.path.join(root, "quarantine")
n_quar = (len([f for f in os.listdir(qdir) if f.endswith(".npz")])
          if os.path.isdir(qdir) else 0)
print(f"DONE members={len(fams)} rb={st['rollbacks_total']} "
      f"quarc={st['quarantined_total']} quar={n_quar} "
      f"victim_ok={v_ok} bystander_ok={b_ok}", flush=True)
'''

ROUTE_GATE_POINTS = [
    "service.post_route",
    "service.post_probe",
    "service.post_quarantine",
    "repo.mid_rollback",
]

_ROUTE_GATE_DONE = {"members": "2", "rb": "1", "quarc": "2", "quar": "2",
                    "victim_ok": "True", "bystander_ok": "True"}


@pytest.mark.slow
@pytest.mark.parametrize("point", ROUTE_GATE_POINTS)
def test_gate_isolation_across_crash_points(tmp_path, point):
    """kill -9 anywhere in a routed member's gate-trip path and restart:
    exactly one rollback on the harmful member, the harmful rows alone
    quarantined, and the bystander member's base and counters bit-equal
    to the benign closed form."""
    root = str(tmp_path / "repo")
    run_child(_ROUTE_GATE_SCENARIO, [root, "prep"])
    run_child(_ROUTE_GATE_SCENARIO, [root, "serve_clean"])
    run_child(_ROUTE_GATE_SCENARIO, [root, "plant"])
    run_child(_ROUTE_GATE_SCENARIO, [root, "serve"], crash_at=point)
    done = _done_line(run_child(_ROUTE_GATE_SCENARIO, [root, "serve"]))
    assert done == _ROUTE_GATE_DONE, (point, done)


@pytest.mark.slow
def test_gate_isolation_uninterrupted_reference_run(tmp_path):
    root = str(tmp_path / "repo")
    run_child(_ROUTE_GATE_SCENARIO, [root, "prep"])
    run_child(_ROUTE_GATE_SCENARIO, [root, "serve_clean"])
    run_child(_ROUTE_GATE_SCENARIO, [root, "plant"])
    done = _done_line(run_child(_ROUTE_GATE_SCENARIO, [root, "serve"]))
    assert done == _ROUTE_GATE_DONE, done


# ---------------------------------------------------------------------------
# migration + worker-follows-member + the demo deflake proof
# ---------------------------------------------------------------------------


def test_single_base_layout_migrates_in_place(tmp_path):
    """A pre-family repository.json opens as a one-member family ('main'
    = the old layout, in place) and serves routed admission from there."""
    root = str(tmp_path / "repo")
    Repository(_zeros(), root=root, spill=True, screen=False)
    fam = RepositoryFamily.open(root, spill=True)
    assert list(fam.members) == ["main"]
    assert fam.members["main"].root == root
    svc = ColdService(family=fam, policy=AdmissionPolicy(
        min_cohort=1, max_bases=2))
    _submit_round(root, 0, 0, 0)
    st = _drain(svc)
    assert st["families"]["main"]["iteration"] == 1
    svc.close()


def test_serving_worker_follows_named_member(tmp_path):
    """``ServingWorker(cfg, root, family=...)`` watches that member's own
    repository.json: it swaps on the member's publishes and never on the
    other members'."""
    from repro.serve.hot_swap import ServingWorker

    root = str(tmp_path / "repo")
    svc = _svc(root)
    matched = _two_member_family(root, svc)
    follow = matched[1]

    class _Noop:
        def __init__(self, cfg, params, max_len):
            pass

        def generate(self, prompts, *, max_new_tokens=16, params=None):
            raise NotImplementedError

    worker = ServingWorker(None, root, family=follow,
                           engine_factory=_Noop)
    assert worker.root == family_member_root(root, follow)
    assert worker.poll_once() is True
    assert worker.current_iteration == 1
    # another publish on the OTHER member must not move this worker
    other = matched[0]
    base = _member_base(root, other, 1)
    for c in range(2):
        _submit_round(root, 0, c, 1, home=other, base=base)
    st = _drain(svc)
    assert st["families"][other]["iteration"] == 2
    assert worker.poll_once() is False
    assert worker.current_iteration == 1
    assert worker.serve_state()["family"] == follow
    svc.close()
    with pytest.raises(ValueError, match="family="):
        ServingWorker(None, None, repo=svc._lanes["main"].repo,
                      family="f1", engine_factory=_Noop)


@pytest.mark.slow
def test_duplicates_demo_exits_zero_20_consecutive_runs():
    """The deflake proof for the --duplicates demo (was ~50-80% flaky:
    the replayer's last planted near-duplicate raced the daemon's
    --max-iterations stop).  Twenty back-to-back runs, zero retries."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "examples/cold_service_demo.py",
           "--contributors", "2", "--rounds", "2", "--duplicates", "1",
           "--timeout", "120"]
    for i in range(20):
        res = subprocess.run(cmd, cwd=_REPO_ROOT, env=env,
                             capture_output=True, text=True, timeout=180)
        assert res.returncode == 0, (i, res.stdout[-2000:],
                                     res.stderr[-2000:])
