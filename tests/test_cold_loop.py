"""Integration: the full ColD Fusion loop on the tiny encoder + synthetic
suite reproduces the paper's qualitative behaviour at micro scale."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    Contributor, EvalTask, Repository, evaluate_base_model, run_cold_fusion,
)
from repro.data.synthetic import SyntheticSuite
from repro.models import encoder as E

SEQ = 20


@pytest.fixture(scope="module")
def setup(tiny_cfg):
    suite = SyntheticSuite(vocab_size=256, num_tasks=8, seed=0, noise=0.1)
    key = jax.random.PRNGKey(0)
    body = E.init_encoder_body(tiny_cfg, key)
    contribs = []
    for tid in range(4):
        d = suite.dataset(tid, 768, 64, SEQ)
        contribs.append(
            Contributor(tiny_cfg, tid, suite.tasks[tid].num_classes,
                        d["x_train"], d["y_train"], steps=25, batch_size=32,
                        lr=2e-3, seed=tid)
        )
    d0 = suite.dataset(0, 512, 256, SEQ)
    ev = [EvalTask(0, suite.tasks[0].num_classes, d0["x_train"], d0["y_train"],
                   d0["x_test"], d0["y_test"])]
    return tiny_cfg, suite, body, contribs, ev


def test_cold_loop_improves_frozen_eval(setup):
    cfg, suite, body, contribs, ev = setup
    before = np.mean(list(evaluate_base_model(cfg, body, ev, frozen=True,
                                              steps=40, lr=2e-3).values()))
    repo = Repository(body)
    log = run_cold_fusion(cfg, repo, contribs, iterations=3,
                          eval_seen=ev, eval_every=3, eval_steps=40, eval_lr=2e-3)
    after = log.mean("seen_frozen")[-1]
    # linear probing on a *seen* task must beat probing the random-ish base
    assert after > before + 0.05, (before, after)


def test_cold_loop_repository_history(setup):
    cfg, suite, body, contribs, ev = setup
    repo = Repository(body, keep_history=True)
    run_cold_fusion(cfg, repo, contribs, iterations=2, contributors_per_iter=2)
    assert repo.iteration == 2
    assert len(repo.history) == 2
    assert all(r.n_accepted >= 1 for r in repo.history)


def test_contributor_sampling_subset(setup):
    cfg, suite, body, contribs, ev = setup
    repo = Repository(body)
    run_cold_fusion(cfg, repo, contribs, iterations=1, contributors_per_iter=2)
    assert repo.history[0].n_contributions == 2
