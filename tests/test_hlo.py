"""HLO collective parser unit tests."""
from repro.utils.hlo import collect_collectives, shape_bytes, wire_bytes

HLO = """
HloModule test
  %p = bf16[16,512]{1,0} parameter(0)
  %ar = bf16[16,512]{1,0} all-reduce(%p), replica_groups={{0,1}}
  %ag = f32[4,128]{1,0} all-gather(%p), dimensions={0}
  %rs = f32[2,64]{1,0} reduce-scatter(%ag), dimensions={0}
  %a2a = s32[8]{0} all-to-all(%x)
  %cp = bf16[3,3]{1,0} collective-permute(%p)
  %ars = bf16[16,512]{1,0} all-reduce-start(%p)
  %tuple = (f32[2,2]{1,0}, f32[4]{0}) all-to-all(%a, %b)
"""


def test_shape_bytes():
    assert shape_bytes("bf16[16,512]{1,0}") == 16 * 512 * 2
    assert shape_bytes("f32[4,128]") == 4 * 128 * 4
    assert shape_bytes("(f32[2,2]{1,0}, f32[4]{0})") == 16 + 16
    assert shape_bytes("pred[7]") == 7
    assert shape_bytes("s32[]") == 4


def test_collect_collectives():
    st = collect_collectives(HLO)
    assert st.count_by_kind["all-reduce"] == 2  # all-reduce + all-reduce-start
    assert st.count_by_kind["all-gather"] == 1
    assert st.count_by_kind["reduce-scatter"] == 1
    assert st.count_by_kind["all-to-all"] == 2
    assert st.count_by_kind["collective-permute"] == 1
    assert st.bytes_by_kind["all-reduce"] == 2 * 16 * 512 * 2
    assert st.bytes_by_kind["all-to-all"] == 8 * 4 + 32
    assert st.total_count == 7


def test_wire_bytes_multipliers():
    st = collect_collectives(HLO)
    w = wire_bytes(st)
    # all-reduce counts 2x
    assert w > st.total_bytes
