"""Fuse-to-serve hot path (repro/serve/hot_swap.py, docs/serving.md):
swap atomicity units (residency-before-flip, version pinning across
forward and rollback swaps), an interleaving property suite over
publish/swap/generate/rollback, the real-eval regression-gate probes,
and the swap-seam kill -9 crash matrix."""
import os
import shutil
import tempfile
import threading
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _faults import SWAP_SEAMS, run_child, wait_until
from _hypothesis_compat import given, settings, st
from repro.checkpoint import io as ckpt
from repro.core.repository import Repository
from repro.serve import base_follower
from repro.serve.cold_service import (METRICS_FILE, SERVING_STATE_FILE,
                                      AdmissionPolicy, ColdService)
from repro.serve.hot_swap import ServingWorker
from repro.serve.probes import MultitaskEvals, ProbeSuite, RegressionGate

PROMPTS = np.zeros((1, 2), np.int32)


def _m(v, n=64):
    return {"w": jnp.full((n,), float(v)), "b": jnp.full((5,), float(v))}


def _repo(root, **kw):
    kw.setdefault("screen", False)
    return Repository(_m(0), root=str(root), spill=True, **kw)


def _publish(repo, v) -> int:
    """One single-row average fuse: the published base becomes _m(v)."""
    repo.upload(_m(v))
    repo.fuse_pending()
    repo.flush()
    return repo.iteration


class _ValueEngine:
    """Fake engine for the swap units: 'generation' returns the served
    tree's scalar w value, so a token mismatch IS a version tear.  An
    optional gate blocks mid-request to model an in-flight generate."""

    def __init__(self, cfg, params, max_len):
        self.params = params
        self.max_len = max_len
        self.gate = None

    def generate(self, prompts, *, max_new_tokens=16, params=None):
        p = self.params if params is None else params
        if self.gate is not None:
            self.gate["started"].set()
            assert self.gate["release"].wait(10.0), "gate never released"
        val = float(np.asarray(p["w"])[0])
        toks = np.full((prompts.shape[0], prompts.shape[1] + max_new_tokens),
                       val, np.float32)
        return types.SimpleNamespace(tokens=toks,
                                     prompt_len=int(prompts.shape[1]),
                                     steps=int(max_new_tokens))


def _fake(cfg, params, max_len):
    return _ValueEngine(cfg, params, max_len)


def _served_value(worker, **kw):
    return float(worker.generate(PROMPTS, **kw).tokens[0, -1])


# ---------------------------------------------------------------------------
# swap atomicity units
# ---------------------------------------------------------------------------


def test_pointer_flips_only_after_residency(tmp_path, monkeypatch):
    """The residency barrier must run BEFORE the pointer flip: while the
    next base transfers, requests still see the old complete version."""
    repo = _repo(tmp_path)
    w = ServingWorker(None, str(tmp_path), repo=repo, engine_factory=_fake)
    assert w.poll_once() and w.current_iteration == 0
    at_barrier = []
    real = base_follower._block_until_ready
    monkeypatch.setattr(
        base_follower, "_block_until_ready",
        lambda tree: (at_barrier.append(w.current_iteration), real(tree))[1])
    _publish(repo, 7.0)
    assert w.poll_once()
    assert at_barrier == [0], "barrier ran after (or without) the flip"
    assert w.current_iteration == 1 and _served_value(w) == 7.0


def test_generate_pinned_to_start_version_across_swap(tmp_path):
    """An in-flight generate completes against the base it started on
    even when the pointer flips mid-request."""
    repo = _repo(tmp_path)
    w = ServingWorker(None, str(tmp_path), repo=repo, engine_factory=_fake)
    w.poll_once()
    gate = {"started": threading.Event(), "release": threading.Event()}
    w._engine.gate = gate
    out = {}

    def request():
        out["res"] = w.generate(PROMPTS, max_new_tokens=3)

    t = threading.Thread(target=request)
    t.start()
    assert gate["started"].wait(10.0)
    w._engine.gate = None           # only the in-flight request blocks
    _publish(repo, 9.0)
    assert w.poll_once() and w.current_iteration == 1  # flip mid-request
    gate["release"].set()
    t.join(timeout=10.0)
    res = out["res"]
    assert res.iteration == 0, "request re-labelled across the swap"
    assert float(res.tokens[0, -1]) == 0.0, "request decoded the new base"
    assert w.requests_pinned_across_swaps == 1
    assert _served_value(w) == 9.0  # the next request serves the new base


def test_rollback_moves_pointer_backwards(tmp_path):
    """A gate rollback publishes a SMALLER iteration; the worker must
    swap backwards (target test is !=, not >) and serve the restored
    base."""
    repo = _repo(tmp_path)
    w = ServingWorker(None, str(tmp_path), repo=repo, engine_factory=_fake)
    w.poll_once()
    _publish(repo, 3.0)
    _publish(repo, 5.0)
    assert w.poll_once() and w.current_iteration == 2
    assert _served_value(w) == 5.0
    repo.rollback(1)
    assert w.poll_once(), "rollback publish was not observed"
    assert w.current_iteration == 1
    assert _served_value(w) == 3.0
    assert w.last_swap == {"from_iteration": 2, "to_iteration": 1,
                           "swap_latency_s": w.last_swap["swap_latency_s"]}
    # the worker polled AFTER both publishes, so it jumped 0 -> 2 in one
    # swap (a poll adopts the latest publish) and then rolled back to 1
    assert w.live_swaps == 2 and w.versions_served == {0, 1, 2}


def test_generate_before_first_swap_raises(tmp_path):
    w = ServingWorker(None, str(tmp_path), engine_factory=_fake)
    with pytest.raises(RuntimeError, match="no base resident"):
        w.generate(PROMPTS)


def test_cross_process_worker_and_status_embedding(tmp_path):
    """A worker with only the root polls repository.json (atomic write;
    base npz durable before the json names it) — and the daemon's status
    embeds the worker's serving_state.json as the 'serving' block."""
    repo = _repo(tmp_path)
    _publish(repo, 4.0)
    w = ServingWorker(None, str(tmp_path), engine_factory=_fake)  # no repo=
    assert w.poll_once() and w.current_iteration == 1
    assert _served_value(w) == 4.0
    assert not w.poll_once(), "no new publish, no swap"
    _publish(repo, 6.0)
    assert w.poll_once() and w.current_iteration == 2
    assert _served_value(w) == 6.0

    state = ckpt.load_json(os.path.join(str(tmp_path), SERVING_STATE_FILE))
    assert state["iteration"] == 2
    assert state["versions_served"] == [1, 2]
    assert state["swaps_total"] == 2 and state["live_swaps"] == 1
    assert state["last_swap"]["swap_latency_s"] > 0.0

    svc = ColdService(repo, policy=AdmissionPolicy())
    st = svc.status()
    assert st["serving"]["iteration"] == 2
    assert st["serving"]["versions_served"] == [1, 2]
    svc.close()

    records = ckpt.read_jsonl(os.path.join(str(tmp_path), METRICS_FILE))
    swaps = [r for r in records if r.get("event") == "swap"]
    assert [s["to_iteration"] for s in swaps] == [1, 2]
    assert all(s["swap_latency_s"] > 0 and "requests_pinned_across_swaps" in s
               for s in swaps)


def test_watch_thread_swaps_under_concurrent_traffic(tmp_path):
    """Mini in-process load: client threads generate continuously while
    publishes land; every response must carry exactly the value that was
    published as its iteration — no torn or mixed versions."""
    repo = _repo(tmp_path)
    w = ServingWorker(None, str(tmp_path), repo=repo, engine_factory=_fake)
    w.poll_once()
    w.start(interval=0.001)
    expected = {0: 0.0}
    stop = threading.Event()
    seen, errors = [], []

    def client():
        while not stop.is_set():
            try:
                r = w.generate(PROMPTS, max_new_tokens=2)
                seen.append((r.iteration, float(r.tokens[0, -1])))
            except Exception as err:  # noqa: BLE001
                errors.append(err)

    threads = [threading.Thread(target=client) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for k in range(1, 5):
            expected[_publish(repo, 10.0 * k)] = 10.0 * k
            wait_until(lambda k=k: w.current_iteration == k,
                       desc=f"adoption of iteration {k}")
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10.0)
        w.stop()
    assert not errors
    assert w.live_swaps >= 3
    assert seen, "no traffic flowed"
    torn = [(it, v) for it, v in seen if expected[it] != v]
    assert not torn, f"version-torn responses: {torn[:5]}"
    assert w.watch_error is None


# ---------------------------------------------------------------------------
# interleaving property suite
# ---------------------------------------------------------------------------


def _is_subsequence(sub, seq):
    it = iter(seq)
    return all(x in it for x in sub)  # `in` consumes the iterator


@settings(max_examples=12)
@given(st.data())
def test_interleaving_serves_only_published_versions(data):
    """Any interleaving of publish/swap/generate/rollback: every request
    is served by exactly one published base version (the weights the
    repository published AS that iteration when the worker adopted it),
    and the served-version sequence is a subsequence of the
    published-iteration sequence."""
    ops = data.draw(st.lists(
        st.sampled_from(["publish", "poll", "generate", "rollback"]),
        min_size=4, max_size=14))
    root = tempfile.mkdtemp(prefix="hot_swap_prop_")
    try:
        repo = _repo(root)
        w = ServingWorker(None, root, repo=repo, engine_factory=_fake)
        w.poll_once()
        published_seq = [0]          # iteration stamps in publish order
        live = {0: 0.0}              # iteration -> w published AS it (now)
        served_seq = [0]             # worker flip order
        swap_value = live[0]         # value captured at the last adoption
        next_v = 1.0
        for op in ops:
            if op == "publish":
                it = _publish(repo, next_v)
                live[it] = next_v
                published_seq.append(it)
                next_v += 1.0
            elif op == "rollback":
                if repo.iteration == 0:
                    continue
                target = data.draw(st.integers(0, repo.iteration - 1))
                repo.rollback(target)
                live = {k: v for k, v in live.items() if k <= target}
                published_seq.append(target)
            elif op == "poll":
                if w.poll_once():
                    served_seq.append(w.current_iteration)
                    swap_value = live[w.current_iteration]
            else:
                r = w.generate(PROMPTS, max_new_tokens=2)
                assert r.iteration == w.current_iteration
                assert float(r.tokens[0, -1]) == swap_value, (
                    f"request served weights that were never published as "
                    f"iteration {r.iteration}")
        assert _is_subsequence(served_seq, published_seq), (
            f"served {served_seq} is not a subsequence of published "
            f"{published_seq}")
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------
# real task evals in the regression gate (ProbeSuite suite=)
# ---------------------------------------------------------------------------


def _eval_datasets(n_tasks=2, n_examples=12, seq_len=8):
    from repro.data.synthetic import SyntheticSuite
    suite = SyntheticSuite(num_tasks=n_tasks, seed=0)
    out = []
    for t in range(n_tasks):
        ds = suite.dataset(t, 1, n_examples, seq_len, split_seed=0)
        out.append((t, ds["x_test"], ds["y_test"],
                    suite.tasks[t].num_classes))
    return out


def test_probe_suite_accepts_multitask_evals(tiny_cfg):
    from repro.models import encoder as E
    from repro.utils.flat import FlatSpec

    body = E.init_encoder_body(tiny_cfg, jax.random.PRNGKey(0))
    spec = FlatSpec.from_tree(body)
    flat = np.asarray(spec.flatten(body), np.float32)
    evals = MultitaskEvals(tiny_cfg, body, _eval_datasets(), seed=0)
    probes = ProbeSuite(spec.size, suite=evals)
    assert probes.n_tasks == 2

    scores = probes.score(flat)
    assert set(scores) == {"task00", "task01"}
    assert scores == probes.score(flat), "real-eval probes must be pure"
    accs = probes.accuracies(flat)
    assert all(0.0 <= a <= 1.0 for a in accs.values())
    # the pytree spelling scores identically to the flat row
    assert probes.score(body) == scores

    # a trashed base moves REAL task losses; the gate trips on it while
    # the identical base stays clean
    gate = RegressionGate(probes, tolerance=0.05)
    assert gate.check(scores, flat).ok
    harmful = flat + np.float32(50.0) * np.sign(flat)
    report = gate.check(scores, harmful)
    assert not report.ok and report.worst > 0.05

    with pytest.raises(ValueError, match="size"):
        ProbeSuite(spec.size + 1, suite=evals)


def test_probe_suite_synthetic_path_unchanged():
    """Regression: without suite=MultitaskEvals the synthetic linear-
    readout probes behave exactly as before (same names, same scores)."""
    flat = np.linspace(-1.0, 1.0, 501, dtype=np.float32)
    a = ProbeSuite(flat.size, n_tasks=3, seed=0)
    b = ProbeSuite(flat.size, n_tasks=3, seed=0)
    assert a._evals is None
    assert [t[0] for t in a._tasks] == [t[0] for t in b._tasks]
    assert a.score(flat) == b.score(flat)
    assert set(a.score(flat)) == {t[0] for t in a._tasks}
    report = a.compare(a.score(flat), a.score(flat + 0.5), tolerance=1e-6)
    assert isinstance(report.ok, bool)


# ---------------------------------------------------------------------------
# swap-seam kill -9 crash matrix
# ---------------------------------------------------------------------------

_SCENARIO = r'''
import os, sys
sys.path.insert(0, "src")
import numpy as np, jax
from repro.checkpoint import io as ckpt
from repro.configs import get_config, reduce_config
from repro.core.repository import Repository
from repro.models.transformer import init_lm
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient
from repro.serve.engine import Engine
from repro.serve.hot_swap import ServingWorker

root, phase = sys.argv[1], sys.argv[2]
CFG = reduce_config(get_config("gemma3-1b"))
PROMPT = np.arange(2, 6, dtype=np.int32)[None, :]

if phase == "prep":
    # iteration 0 exists, a worker has served it (serving_state at 0),
    # and ONE finetune sits durably in the queue
    params = init_lm(CFG, jax.random.PRNGKey(0))
    repo = Repository(params, root=root, spill=True, screen=False)
    w = ServingWorker(CFG, root, max_len=16)
    w.poll_once()
    r = w.generate(PROMPT, max_new_tokens=4)
    assert r.iteration == 0, r.iteration
    ft = jax.tree.map(lambda x: x + 0.01, params)
    ContributorClient(root, name="c0").submit(ft, base_iteration=0)
    print("PREP ok")

elif phase == "fuse":
    # the daemon fuses the queued contribution -> iteration 1 published
    repo = Repository.open(root, spill=True)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1))
    for _ in range(200):
        stt = svc.run_once()
        if (stt["iteration"] >= 1 and stt["queue_depth"] == 0
                and stt["staged"] == 0 and not stt["inflight"]):
            break
    svc.close()
    assert repo.iteration == 1, repo.iteration
    print("FUSED it=1")

elif phase == "swap":
    # armed via REPRO_CRASH_POINT: dies at one of the 3 swap seams
    w = ServingWorker(CFG, root, max_len=16)
    w.poll_once()
    print("SWAP survived")

elif phase == "verify":
    # a fresh worker must serve the PUBLISHED base bit-for-bit — never a
    # half-swapped one — and the repository must show exactly-once fusion
    w = ServingWorker(CFG, root, max_len=16)
    w.poll_once()
    r = w.generate(PROMPT, max_new_tokens=4)
    meta = ckpt.load_json(os.path.join(root, "repository.json"))
    it = int(meta["iteration"])
    base = ckpt.load(os.path.join(root, "base_iter%04d.npz" % it))
    oracle = Engine(CFG, base, max_len=16).generate(PROMPT, max_new_tokens=4)
    assert r.iteration == it, (r.iteration, it)
    assert np.array_equal(r.tokens, oracle.tokens), "half-swapped base served"
    stt = ckpt.load_json(os.path.join(root, "serving_state.json"))
    assert stt["iteration"] == it, stt
    repo = Repository.open(root, spill=True)
    assert repo.iteration == it, repo.iteration
    assert len(repo.history) == it, "fusion replayed or lost"
    qdir = os.path.join(root, "queue")
    qfiles = [f for f in os.listdir(qdir)
              if f.endswith(".npz")] if os.path.isdir(qdir) else []
    print("DONE it=%d fused=%d qfiles=%d" % (it, len(repo.history), len(qfiles)))
'''


@pytest.fixture(scope="module")
def _prepped_root(tmp_path_factory):
    """iteration 1 published, worker state at iteration 0, queue GC'd —
    the swap-crash phases never mutate the root, so one prep serves every
    seam (each test clones it)."""
    root = str(tmp_path_factory.mktemp("swap_crash") / "repo")
    run_child(_SCENARIO, [root, "prep"])
    run_child(_SCENARIO, [root, "fuse"])
    return root


def _clone(src, tmp_path):
    dst = str(tmp_path / "repo")
    shutil.copytree(src, dst)
    return dst


@pytest.mark.slow
@pytest.mark.parametrize("point", SWAP_SEAMS)
def test_swap_crash_matrix(tmp_path, _prepped_root, point):
    """kill -9 at every swap seam: the restarted worker always serves a
    published, uncorrupted base (token-identical to the on-disk npz the
    atomic repository.json names) and fusion stays exactly-once."""
    root = _clone(_prepped_root, tmp_path)
    before = ckpt.load_json(os.path.join(root, SERVING_STATE_FILE))
    assert before["iteration"] == 0
    run_child(_SCENARIO, [root, "swap"], crash_at=point)
    # whatever the kill window, serving_state is parseable (atomic write)
    # and names only an iteration the worker FULLY adopted
    after = ckpt.load_json(os.path.join(root, SERVING_STATE_FILE))
    assert after["iteration"] == 0, (
        "crashed worker persisted state for a swap it never completed")
    out = run_child(_SCENARIO, [root, "verify"])
    assert "DONE it=1 fused=1 qfiles=0" in out.stdout


@pytest.mark.slow
def test_swap_uninterrupted_reference(tmp_path, _prepped_root):
    """The same scenario with no kill converges to the same state the
    crash matrix demands — the matrix compares against a live bar."""
    root = _clone(_prepped_root, tmp_path)
    out = run_child(_SCENARIO, [root, "swap"])
    assert "SWAP survived" in out.stdout
    after = ckpt.load_json(os.path.join(root, SERVING_STATE_FILE))
    assert after["iteration"] == 1
    out = run_child(_SCENARIO, [root, "verify"])
    assert "DONE it=1 fused=1 qfiles=0" in out.stdout
