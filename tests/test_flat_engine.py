"""Streaming flat-buffer fusion engine: FlatParams round-trips, kernel vs
jnp-oracle parity, single-pass screen+fuse semantics, spill, persistence."""
import contextlib
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.core import fusion
from repro.core.repository import Repository
from repro.core.validation import screen_norms
from repro.kernels import ops
from repro.utils.flat import FlatSpec, flatten_tree

KEY = jax.random.PRNGKey(3)


@contextlib.contextmanager
def kernels(enabled: bool):
    prev = ops.kernels_enabled()
    ops.use_kernels(enabled)
    try:
        yield
    finally:
        ops.use_kernels(prev)


def _odd_tree(key, dtype=jnp.float32, scale=1.0):
    """Non-block-aligned leaf shapes (nothing is a multiple of 8*128)."""
    ks = jax.random.split(key, 4)
    return {
        "emb": {"w": jax.random.normal(ks[0], (7, 13), jnp.float32).astype(dtype) * scale},
        "blocks": [
            {"w": jax.random.normal(ks[1], (5,), jnp.float32).astype(dtype) * scale},
            {"w": jax.random.normal(ks[2], (3, 11, 2), jnp.float32).astype(dtype) * scale},
        ],
        "head": jax.random.normal(ks[3], (17,), jnp.float32).astype(dtype) * scale,
    }


# ---------------------------------------------------------------------------
# FlatParams round trips
# ---------------------------------------------------------------------------


def test_flat_roundtrip_mixed_dtypes():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.asarray(7, jnp.int32)},
    }
    buf, spec = flatten_tree(tree)
    assert spec.dtype == "float32"  # mixed tree widens to f32 storage
    assert buf.shape == (11,)
    back = spec.unflatten(buf)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_flat_roundtrip_bf16_storage():
    tree = {"w": jnp.ones((3, 5), jnp.bfloat16), "v": jnp.zeros((9,), jnp.bfloat16)}
    buf, spec = flatten_tree(tree)
    assert spec.dtype == "bfloat16"  # all-bf16 tree stays bf16 (half the HBM traffic)
    back = spec.unflatten(buf)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32), 1.0)


def test_flat_spec_json_roundtrip():
    tree = _odd_tree(KEY)
    buf, spec = flatten_tree(tree)
    spec2 = FlatSpec.from_json(spec.to_json())
    assert spec2.size == spec.size and spec2.dtype == spec.dtype
    back = spec2.unflatten(buf)  # reconstructed treedef is path-keyed dicts
    np.testing.assert_allclose(
        np.asarray(back["emb"]["w"]), np.asarray(tree["emb"]["w"]))


def test_flat_spec_json_roundtrip_nonsorted_paths():
    """List indices '0'..'10' do NOT sort lexicographically ('10' < '2'):
    the reconstructed dict tree flattens in a different order than the
    original list, and every value must still land at its own path."""
    tree = {"l": [jnp.full((3,), float(i)) for i in range(11)]}
    buf, spec = flatten_tree(tree)
    back = FlatSpec.from_json(spec.to_json()).unflatten(buf)
    for i in range(11):
        np.testing.assert_array_equal(np.asarray(back["l"][str(i)]), float(i))


def test_flat_shape_mismatch_raises():
    tree = {"w": jnp.ones((4,))}
    spec = FlatSpec.from_tree(tree)
    with pytest.raises(ValueError):
        spec.flatten({"w": jnp.ones((5,))})
    with pytest.raises(ValueError):
        # same leaf count and shape, different key: must not silently fuse
        spec.flatten({"v": jnp.ones((4,))})
    with pytest.raises(ValueError):
        spec.unflatten(jnp.ones((3,)))


def test_save_flat_roundtrip(tmp_path):
    for dtype in (jnp.float32, jnp.bfloat16):
        tree = _odd_tree(KEY, dtype=dtype)
        buf, spec = flatten_tree(tree)
        path = os.path.join(tmp_path, f"flat_{jnp.dtype(dtype).name}.npz")
        ckpt.save_flat(path, buf, spec)
        assert ckpt.is_flat(path)
        buf2, spec2 = ckpt.load_flat(path)
        assert buf2.dtype == buf.dtype
        np.testing.assert_array_equal(
            np.asarray(buf2, np.float32), np.asarray(buf, np.float32))
        assert spec2.size == spec.size


# ---------------------------------------------------------------------------
# kernel path vs jnp oracle parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fuse_average_kernel_vs_oracle(dtype):
    models = [_odd_tree(jax.random.PRNGKey(i), dtype=dtype) for i in range(4)]
    with kernels(False):
        want = fusion.average(models)
    with kernels(True):
        got = fusion.average(models)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=rtol, atol=rtol)


@pytest.mark.parametrize("alpha", [0.3, 1.0])
def test_fuse_damped_kernel_vs_oracle(alpha):
    base = _odd_tree(jax.random.PRNGKey(9))
    models = [_odd_tree(jax.random.PRNGKey(i)) for i in range(3)]
    weights = [1.0, 2.5, 0.5]
    with kernels(False):
        want = fusion.damped(base, models, alpha=alpha, weights=weights)
    with kernels(True):
        got = fusion.damped(base, models, alpha=alpha, weights=weights)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_fuse_task_arithmetic_kernel_vs_oracle():
    base = _odd_tree(jax.random.PRNGKey(9))
    models = [_odd_tree(jax.random.PRNGKey(i), scale=0.1) for i in range(3)]
    with kernels(False):
        want = fusion.task_arithmetic(base, models, lam=0.4)
    with kernels(True):
        got = fusion.task_arithmetic(base, models, lam=0.4)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_zero_weight_masks_nonfinite_row():
    """A weight-0 contributor full of NaN must not poison the fuse — the
    contract behind the engine's second (re-weighted) pass."""
    N = 1000  # non-block-aligned
    base = jax.random.normal(KEY, (N,))
    good = jnp.stack([base + 1.0, base - 1.0])
    bad = jnp.full((1, N), jnp.nan)
    contribs = jnp.concatenate([good, bad])
    w = jnp.asarray([1.0, 1.0, 0.0])
    for enabled in (True, False):
        with kernels(enabled):
            fused, sq = ops.fuse_flat(base, contribs, w, 1.0)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(base), atol=1e-5)
        assert not np.isfinite(np.asarray(sq)[2])  # statistic still honest


# ---------------------------------------------------------------------------
# screening edge cases
# ---------------------------------------------------------------------------


def test_screen_norms_all_rejected():
    rep = screen_norms([float("nan"), float("inf"), 0.0])
    assert rep.accepted == [] and len(rep.rejected) == 3


def test_screen_norms_cohort_below_three_no_mad():
    # with only 2 finite norms the MAD outlier rule must NOT fire
    rep = screen_norms([1.0, 1e6])
    assert rep.accepted == [0, 1]
    rep3 = screen_norms([1.0, 1.1, 0.9, 1e6])
    assert 3 in rep3.rejected


def test_screen_norms_zero_diff_allow_zero():
    rep = screen_norms([0.0, 1.0], allow_zero=True)
    assert rep.accepted == [0, 1]
    rep = screen_norms([0.0, 1.0], allow_zero=False)
    assert 0 in rep.rejected and "no-op" in rep.reasons[0]


def test_screen_norms_max_norm_ceiling():
    rep = screen_norms([1.0, 3.0], max_norm=2.0)
    assert rep.accepted == [0] and 1 in rep.rejected


# ---------------------------------------------------------------------------
# Repository streaming engine
# ---------------------------------------------------------------------------


def _contribs(base, n, seed=0, scale=0.1):
    out = []
    for i in range(n):
        noise = jax.tree.map(
            lambda x, k=jax.random.fold_in(jax.random.PRNGKey(seed), i):
                jax.random.normal(k, x.shape, jnp.float32) * scale,
            base)
        out.append(jax.tree.map(jnp.add, base, noise))
    return out


def test_repository_flat_vs_pytree_engine_parity():
    base = _odd_tree(KEY)
    uploads = _contribs(base, 4)
    uploads.append(jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), base))  # screened out
    with kernels(True):
        repo_flat = Repository(base)
        assert repo_flat.use_flat
        for u in uploads:
            repo_flat.upload(u)
        rec_flat = repo_flat.fuse_pending()
    with kernels(False):
        repo_leaf = Repository(base)
        assert not repo_leaf.use_flat
        for u in uploads:
            repo_leaf.upload(u)
        rec_leaf = repo_leaf.fuse_pending()
    assert rec_flat.n_accepted == rec_leaf.n_accepted == 4
    np.testing.assert_allclose(rec_flat.diff_norms[:4], rec_leaf.diff_norms[:4], rtol=1e-4)
    for a, b in zip(jax.tree.leaves(repo_flat.download()),
                    jax.tree.leaves(repo_leaf.download())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_repository_single_pass_when_all_accepted(monkeypatch):
    """Screen-enabled fuse must be exactly ONE streaming pass over the staged
    buffer when nothing is rejected, and exactly two when something is."""
    calls = []
    real = ops.fuse_flat

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(ops, "fuse_flat", counting)
    base = _odd_tree(KEY)
    with kernels(True):
        repo = Repository(base)
        for u in _contribs(base, 4):
            repo.upload(u)
        rec = repo.fuse_pending()
    assert rec.n_accepted == 4
    assert len(calls) == 1  # screen + fuse in one pass

    calls.clear()
    with kernels(True):
        repo = Repository(base)
        for u in _contribs(base, 4):
            repo.upload(u)
        repo.upload(jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), base))
        rec = repo.fuse_pending()
    assert rec.n_accepted == 4
    assert len(calls) == 2  # one screen+fuse pass + one re-weighted pass


def test_repository_flat_drops_pytrees_on_upload():
    base = _odd_tree(KEY)
    with kernels(True):
        repo = Repository(base)
        repo.upload(_contribs(base, 1)[0])
        staged = repo._pending[0]
        assert isinstance(staged, jax.Array) and staged.ndim == 1  # flat row, not a pytree


def test_repository_flat_task_arithmetic():
    base = _odd_tree(KEY)
    uploads = _contribs(base, 3)
    with kernels(True):
        repo = Repository(base, fusion_op="task_arithmetic",
                          fusion_kwargs={"lam": 0.4}, screen=False)
        assert repo.use_flat
        for u in uploads:
            repo.upload(u)
        repo.fuse_pending()
    with kernels(False):
        want = fusion.task_arithmetic(base, uploads, lam=0.4)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(repo.download())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_repository_flat_all_rejected_raises():
    base = _odd_tree(KEY)
    with kernels(True):
        repo = Repository(base)
        repo.upload(jax.tree.map(lambda x: jnp.full_like(x, jnp.inf), base))
        with pytest.raises(RuntimeError, match="all contributions rejected"):
            repo.fuse_pending()
        # the failed fuse must not have advanced or corrupted the base
        assert repo.iteration == 0
        for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(repo.download())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_repository_spill_to_disk(tmp_path):
    root = str(tmp_path / "repo")
    base = _odd_tree(KEY)
    uploads = _contribs(base, 3)
    with kernels(True):
        repo = Repository(base, root=root, spill=True)
        for u in uploads:
            repo.upload(u)
        # staged rows live on disk, not in memory
        assert all(isinstance(p, str) and os.path.exists(p) for p in repo._pending)
        rec = repo.fuse_pending()
        assert rec.n_accepted == 3
        repo_mem = Repository(base)
        for u in uploads:
            repo_mem.upload(u)
        repo_mem.fuse_pending()
    for a, b in zip(jax.tree.leaves(repo.download()),
                    jax.tree.leaves(repo_mem.download())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_repository_spill_requires_root():
    with pytest.raises(ValueError):
        Repository(_odd_tree(KEY), spill=True)


def test_repository_open_with_spill(tmp_path):
    """A spill repository must be reopenable with spill=True (open()
    constructs with root=None internally and restores root/spill after)."""
    root = str(tmp_path / "repo")
    base = _odd_tree(KEY)
    with kernels(True):
        repo = Repository(base, root=root, spill=True)
        for u in _contribs(base, 3):
            repo.upload(u)
        repo.fuse_pending()
        again = Repository.open(root, spill=True)
        assert again.spill and again.root == root
        again.upload(_contribs(again.download(), 1)[0])
        rec = again.fuse_pending()
    assert rec.n_accepted == 1 and again.iteration == 2


def test_make_fuse_step_mesh_without_contrib_axis():
    """flat=True must fall back to the per-leaf reduction on meshes that
    have no contributor axis instead of crashing."""
    from jax.sharding import Mesh

    from repro.core.distributed import ColdSchedule, make_fuse_step

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    params = {"w": jnp.stack([jnp.zeros((4,)), jnp.full((4,), 2.0)])}
    fuse = make_fuse_step(None, mesh, ColdSchedule())
    out = jax.jit(fuse)(params)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)


def test_repository_open_restores_settings(tmp_path):
    root = str(tmp_path / "repo")
    base = _odd_tree(KEY)
    with kernels(True):
        repo = Repository(
            base, root=root, fusion_op="damped",
            fusion_kwargs={"alpha": 0.5}, screen=False, mad_threshold=3.0)
        for u in _contribs(base, 3):
            repo.upload(u)
        rec = repo.fuse_pending()
        again = Repository.open(root)
    assert again.iteration == 1
    assert again.fusion_op == "damped"
    assert again.fusion_kwargs == {"alpha": 0.5}
    assert again.screen is False
    assert again.mad_threshold == 3.0
    assert len(again.history) == 1
    assert again.history[0].n_contributions == rec.n_contributions
    assert again.history[0].op == "damped"
    np.testing.assert_allclose(again.history[0].diff_norms, rec.diff_norms)
    for a, b in zip(jax.tree.leaves(repo.download()), jax.tree.leaves(again.download())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_repository_async_flat_single_pass(monkeypatch):
    calls = []
    real = ops.fuse_flat

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(ops, "fuse_flat", counting)
    base = _odd_tree(KEY)
    contrib = _contribs(base, 1)[0]
    with kernels(True):
        repo = Repository(base)
        repo.contribute_async(contrib, alpha=0.5)
    assert len(calls) == 1
    with kernels(False):
        repo2 = Repository(base)
        repo2.contribute_async(contrib, alpha=0.5)
    for a, b in zip(jax.tree.leaves(repo.download()), jax.tree.leaves(repo2.download())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_repository_async_flat_rejects_nan():
    base = _odd_tree(KEY)
    with kernels(True):
        repo = Repository(base)
        with pytest.raises(RuntimeError, match="rejected"):
            repo.contribute_async(jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), base))
        assert repo.iteration == 0


# ---------------------------------------------------------------------------
# atomic checkpoint writes
# ---------------------------------------------------------------------------


def test_checkpoint_write_is_atomic(tmp_path, monkeypatch):
    """A crash mid-write must leave the previous file intact and no temp
    droppings behind."""
    path = os.path.join(tmp_path, "m.npz")
    ckpt.save(path, {"w": jnp.zeros((4,))})

    real_savez = np.savez

    def exploding(fname, **arrays):
        real_savez(fname, **arrays)  # file fully written...
        raise OSError("simulated crash before publish")

    monkeypatch.setattr(np, "savez", exploding)
    with pytest.raises(OSError):
        ckpt.save(path, {"w": jnp.ones((4,))})
    monkeypatch.undo()

    back = ckpt.load(path)  # previous checkpoint survives untouched
    np.testing.assert_array_equal(np.asarray(back["w"]), 0.0)
    leftovers = [f for f in os.listdir(tmp_path) if "tmp" in f]
    assert leftovers == []


def test_buffer_pair_swap_and_restore():
    from repro.utils.flat import BufferPair

    bp = BufferPair()
    bp.front.rows.extend(["a", "b"])
    back = bp.swap()
    assert back.rows == ["a", "b"] and bp.front.rows == []
    with pytest.raises(RuntimeError, match="in flight"):
        bp.swap()
    bp.retire_back()
    assert bp.back is None
    assert bp.swap().rows == []


def test_staged_buffer_handle():
    from repro.utils.flat import StagedBuffer

    buf = StagedBuffer.from_rows([jnp.zeros((5,)), jnp.ones((5,))])
    assert buf.k == 2 and not buf.sharded
    sharded = StagedBuffer(jnp.zeros((3, 4, 8)))
    assert sharded.k == 3 and sharded.sharded
    with pytest.raises(ValueError, match="empty cohort"):
        StagedBuffer.from_rows([])
    # ops entry points unwrap the handle transparently
    base = jnp.zeros((5,))
    fused, sq = ops.fuse_flat(base, buf, jnp.ones((2,), jnp.float32), 1.0)
    np.testing.assert_allclose(np.asarray(fused), 0.5)


def test_shard_slices_roundtrip():
    from repro.utils.flat import ShardedFlatSpec

    rng = np.random.default_rng(0)
    for n, s in [(5, 2), (561, 4), (9000, 8)]:
        row = rng.standard_normal(n).astype(np.float32)
        sp = ShardedFlatSpec.for_size(n, s)
        slices = sp.shard_slices(row)
        assert len(slices) == s and all(x.shape == (sp.shard_len,) for x in slices)
        # slice s equals shard(row)[s] and the slices reassemble exactly
        grid = np.asarray(sp.shard(row))
        for i, sl in enumerate(slices):
            np.testing.assert_array_equal(sl, grid[i])
        np.testing.assert_array_equal(sp.unshard_slices(slices), row)


def test_save_flat_shards_roundtrip(tmp_path):
    from repro.utils.flat import FlatSpec, ShardedFlatSpec

    tree = _odd_tree(KEY)
    buf, spec = flatten_tree(tree)
    sp = ShardedFlatSpec.for_size(spec.size, 4)
    path = str(tmp_path / "row.npz")
    ckpt.save_flat_shards(path, sp.shard_slices(np.asarray(buf)), spec, sp)
    assert ckpt.is_flat_sharded(path) and not ckpt.is_flat(path)
    meta = ckpt.flat_row_meta(path)
    assert meta["sharded"] and meta["size"] == spec.size
    with ckpt.FlatShardReader(path) as r:
        assert r.sspec == sp and r.spec.size == spec.size
        np.testing.assert_array_equal(r.shard(1), sp.shard_slices(np.asarray(buf))[1])
        np.testing.assert_allclose(r.full_row(), np.asarray(buf))


def test_save_json_atomic_crash_keeps_previous(tmp_path, monkeypatch):
    path = os.path.join(tmp_path, "m.json")
    ckpt.save_json_atomic(path, {"v": 1})
    real_replace = os.replace

    def exploding(src, dst):
        raise OSError("simulated crash before publish")

    monkeypatch.setattr(os, "replace", exploding)
    with pytest.raises(OSError):
        ckpt.save_json_atomic(path, {"v": 2})
    monkeypatch.setattr(os, "replace", real_replace)
    assert ckpt.load_json(path) == {"v": 1}
    assert [f for f in os.listdir(tmp_path) if "tmp" in f] == []


def test_checkpoint_save_appends_npz_suffix(tmp_path):
    """np.savez semantics: a suffix-less target still produces <name>.npz."""
    ckpt.save(os.path.join(tmp_path, "model"), {"w": jnp.ones((2,))})
    assert os.path.exists(os.path.join(tmp_path, "model.npz"))
    back = ckpt.load(os.path.join(tmp_path, "model.npz"))
    np.testing.assert_array_equal(np.asarray(back["w"]), 1.0)
