"""Contributor service loop: queue submit/admission/fuse behaviour, spill
compaction, property tests over submit/poll/fuse interleavings, and the
kill-at-checkpoint fault-injection suite (exactly-once fusion across every
parametrized crash window — see docs/service_loop.md's crash matrix)."""
import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np
import pytest

from _faults import run_child, wait_until
from _hypothesis_compat import given, settings, st
from repro.checkpoint import io as ckpt
from repro.core.repository import Repository
from repro.serve.cold_service import (ERROR_RING, QUEUE_DIR, QUEUE_MANIFEST,
                                      STATUS_FILE, AdmissionPolicy,
                                      ColdService, ContributorClient)
from repro.serve.probes import ProbeSuite, RegressionGate
from repro.utils.flat import (FlatSpec, ShardedFlatSpec, delta_encode,
                              row_checksum)


def _m(v, n=64):
    return {"w": jnp.full((n,), float(v)), "b": jnp.full((5,), float(v))}


def _make(root, **kw):
    kw.setdefault("screen", False)
    repo = Repository(_m(0), root=root, spill=True, **kw)
    return repo


def _drain(svc, max_cycles=100):
    """Run service cycles until quiescent (bounded — never an open loop)."""
    for _ in range(max_cycles):
        st = svc.run_once()
        if (st["queue_depth"] == 0 and st["staged"] == 0
                and not st["inflight"]):
            return st
    raise AssertionError(f"service did not drain in {max_cycles} cycles: {st}")


# ---------------------------------------------------------------------------
# queue submit -> admit -> fuse -> GC
# ---------------------------------------------------------------------------


def test_submit_admit_fuse_roundtrip(tmp_path):
    """Queue-driven ingest publishes the same base as direct upload, and a
    consumed submission leaves neither a queue file nor a manifest entry."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=3))
    client = ContributorClient(root, name="c0")
    for v, w in ((1.0, 2.0), (3.0, 1.0), (5.0, 1.0)):
        client.submit(_m(v), weight=w)
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 3
    # weighted mean (2·1 + 3 + 5) / 4
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 2.5)
    direct = Repository(_m(0), screen=False)
    for v, w in ((1.0, 2.0), (3.0, 1.0), (5.0, 1.0)):
        direct.upload(_m(v), weight=w)
    direct.fuse_pending()
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]),
                               np.asarray(direct.download()["w"]))
    qdir = os.path.join(root, QUEUE_DIR)
    assert [f for f in os.listdir(qdir) if f.endswith(".npz")] == []
    assert ckpt.load_json(os.path.join(qdir, QUEUE_MANIFEST))["entries"] == []


def test_min_cohort_batches_arrivals(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=3))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0))
    client.submit(_m(2.0))
    st = svc.run_once()
    assert st["iteration"] == 0 and st["staged"] == 2  # undersized: held
    client.submit(_m(3.0))
    st = _drain(svc)
    assert st["iteration"] == 1
    assert svc.repo.history[0].n_contributions == 3  # one cohort, not three


def test_max_wait_fuses_undersized_cohort(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root),
                      policy=AdmissionPolicy(min_cohort=5, max_wait_s=0.05))
    ContributorClient(root, name="c0").submit(_m(4.0))
    svc.run_once()
    assert svc.repo.iteration == 0  # not yet: below min_cohort, too young
    wait_until(lambda: svc.run_once()["iteration"] >= 1,
               timeout=10.0, desc="timeout-triggered fuse")
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 4.0)


def test_dispatch_overlaps_queue_drain(tmp_path):
    """wait=False dispatch: while a cohort fuses on device, the next
    arrivals are admitted into the fresh front buffer."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0))
    client.submit(_m(3.0))
    st = svc.run_once()
    assert st["inflight"]  # dispatched, not yet published
    client.submit(_m(10.0))
    client.submit(_m(20.0))
    st = svc.run_once()  # finalizes cohort 1, dispatches cohort 2
    assert st["iteration"] >= 1
    st = _drain(svc)
    assert st["iteration"] == 2
    assert [r.n_contributions for r in svc.repo.history] == [2, 2]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 15.0)


def test_idempotent_retry_same_seq(tmp_path):
    """A contributor retrying a submission (same name+seq) atomically
    replaces the same queue file — it can never fuse twice."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2))
    client = ContributorClient(root, name="c0")
    a = client.submit(_m(2.0), seq=0)
    b = client.submit(_m(2.0), seq=0)  # retry
    assert a == b
    client.submit(_m(6.0))
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 2
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 4.0)


def test_garbage_and_inflight_tmp_files_ignored(tmp_path):
    """A torn enqueue can only exist as a .tmp-* file (invisible) or as
    garbage bytes under the final name (quarantined at admission) —
    neither reaches the fuse, and the daemon survives both."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=1))
    qdir = os.path.join(root, QUEUE_DIR)
    with open(os.path.join(qdir, "torn-000000.npz"), "wb") as f:
        f.write(b"PK\x03\x04 truncated garbage")
    with open(os.path.join(qdir, "c9-000001.npz.tmp-123"), "wb") as f:
        f.write(b"half an npz")
    ContributorClient(root, name="c0").submit(_m(7.0))
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 1
    assert st["rejected_total"] == 1
    assert "unreadable" in st["recent_rejects"][0]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 7.0)


def test_remark_of_staged_row_is_not_budget_starved(tmp_path):
    """Regression (review): a row ingested pre-crash but never marked in
    the queue manifest must be re-marked even when max_cohort leaves no
    admission budget — a starved re-mark would let it fuse unmarked and
    later be re-ingested (double-fused)."""
    root = str(tmp_path / "repo")
    repo = _make(root)
    client = ContributorClient(root, name="c0")
    client.submit(_m(9.0))  # z: will be staged but never queue-marked
    z_path = os.path.join(root, QUEUE_DIR, "c0-000000.npz")
    repo.ingest_spilled(z_path)  # simulates crash at service.post_ingest
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1, max_cohort=1))
    client.submit(_m(1.0))
    client.submit(_m(2.0))
    st = _drain(svc)
    fused = sum(r.n_contributions for r in svc.repo.history)
    assert fused == 3, f"z double-fused or dropped: {svc.repo.history}"
    assert st["iteration"] == 3  # max_cohort=1: three single-row cohorts
    qdir = os.path.join(root, QUEUE_DIR)
    assert [f for f in os.listdir(qdir) if f.endswith(".npz")] == []


def test_max_wait_covers_recovered_rows(tmp_path):
    """Regression (review): rows recovered from the staging manifest at
    service start must start the cohort clock — an undersized recovered
    cohort fuses by max_wait_s without needing a fresh arrival."""
    root = str(tmp_path / "repo")
    _make(root).upload(_m(3.0))  # staged + spilled, then "crash"
    reopened = Repository.open(root, spill=True, screen=False)
    assert reopened.n_staged == 1
    svc = ColdService(reopened,
                      policy=AdmissionPolicy(min_cohort=5, max_wait_s=0.05))
    wait_until(lambda: svc.run_once()["iteration"] >= 1,
               timeout=10.0, desc="recovered-cohort timeout fuse")
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 3.0)


def test_serve_forever_exits_on_stalled_undersized_cohort(tmp_path):
    """Regression (review): idle_timeout means 'no progress', so a daemon
    holding an undersized cohort below min_cohort exits (rows stay durable
    in the manifest) instead of busy-spinning forever."""
    import threading
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=4))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0))
    client.submit(_m(2.0))
    out = {}
    t = threading.Thread(target=lambda: out.update(
        svc.serve_forever(poll_interval=0.01, idle_timeout=0.3)))
    t.start()
    t.join(timeout=30.0)
    assert not t.is_alive(), "serve_forever hung on a stalled cohort"
    assert out["iteration"] == 0 and out["staged"] == 2
    # the stalled rows survive for the next service instance
    again = Repository.open(root, spill=True)
    assert again.n_staged == 2


def test_admission_rejects_stale_submission(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root),
                      policy=AdmissionPolicy(min_cohort=1, max_staleness=1))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0), base_iteration=0)
    _drain(svc)
    client.submit(_m(2.0), base_iteration=1)
    _drain(svc)
    assert svc.repo.iteration == 2
    client.submit(_m(9.0), base_iteration=0)  # finetuned from a stale base
    st = _drain(svc)
    assert st["iteration"] == 2  # never fused
    assert st["rejected_total"] == 1
    assert "stale" in st["recent_rejects"][0]["reason"]


def test_admission_rejects_mismatched_spec(tmp_path):
    """A row from a different architecture is refused at the queue
    boundary; the daemon keeps serving."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=1))
    wrong = {"other": jnp.zeros((13,))}
    ContributorClient(root, name="bad").submit(wrong)
    ContributorClient(root, name="good").submit(_m(3.0))
    st = _drain(svc)
    assert st["iteration"] == 1 and st["rejected_total"] == 1
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 3.0)


def test_checksum_verification(tmp_path):
    """verify_checksums re-reads the row at admission and rejects a file
    whose content no longer matches the contributor's CRC."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, verify_checksums=True))
    client = ContributorClient(root, name="c0")
    client.submit(_m(2.0), checksum=True)
    st = _drain(svc)
    assert st["iteration"] == 1 and st["rejected_total"] == 0
    # now corrupt a submission in place: right spec, wrong bytes vs CRC
    spec = FlatSpec.from_tree(_m(0))
    row = np.asarray(spec.flatten(_m(5.0)))
    path = os.path.join(root, QUEUE_DIR, "c0-000001.npz")
    ckpt.save_flat(path, row, spec,
                   extra={"id": "c0-000001", "checksum": row_checksum(row + 1)})
    st = _drain(svc)
    assert st["iteration"] == 1 and st["rejected_total"] == 1
    assert "checksum" in st["recent_rejects"][-1]["reason"]


def test_sharded_slice_submission(tmp_path):
    """Per-shard submissions (ShardedFlatSpec.shard_slices) fuse to the
    same base as whole-row submissions, even on a meshless repository
    (portable fallback)."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2))
    client = ContributorClient(root, name="c0")
    spec = FlatSpec.from_tree(_m(0))
    sspec = ShardedFlatSpec.from_spec(spec, 4)
    client.submit(_m(2.0))
    client.submit(row=spec.flatten(_m(6.0)), spec=spec, sspec=sspec)
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 2
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 4.0)
    np.testing.assert_allclose(np.asarray(svc.repo.download()["b"]), 4.0)


def test_screen_outlier_diluted_not_fatal(tmp_path):
    """§9 at service level: a lone outlier cohort all-rejects (publish
    abandoned, daemon survives), later arrivals dilute it, and the re-pass
    fuses with the outlier weight-zeroed."""
    root = str(tmp_path / "repo")
    repo = Repository(_m(0), root=root, spill=True, screen=True)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1))
    client = ContributorClient(root, name="c0")
    client.submit({"w": jnp.full((64,), jnp.inf), "b": jnp.full((5,), 1.0)})
    st = svc.run_once()  # dispatch
    st = svc.run_once()  # finalize -> all rejected -> cohort restored
    assert st["iteration"] == 0 and st["last_error"] is not None
    assert "rejected" in st["last_error"]
    for v in (1.0, 1.2, 0.8, 1.1):
        client.submit(_m(v))
    st = _drain(svc)
    assert st["iteration"] == 1
    rec = svc.repo.history[0]
    assert rec.n_contributions == 5 and rec.n_accepted == 4
    assert np.isfinite(np.asarray(svc.repo.download()["w"])).all()


def test_status_endpoint_fields(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0))
    st = svc.run_once()
    for key in ("iteration", "queue_depth", "staged", "inflight", "fuses",
                "fused_contributions", "rejected_total", "fuse_latency_s",
                "last_fuse", "pid", "running", "updated_at"):
        assert key in st, key
    assert st["staged"] == 1 and st["running"] and st["last_fuse"] is None
    # the client reads the same thing, atomically published
    assert client.status()["staged"] == 1
    assert os.path.exists(os.path.join(root, STATUS_FILE))
    client.submit(_m(3.0))
    st = _drain(svc)
    assert st["last_fuse"]["n_accepted"] == 2
    assert st["fuse_latency_s"] > 0
    final = svc.close()
    assert final["running"] is False
    assert client.iteration() == 1


def test_wait_for_iteration_bounded(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root))
    svc.run_once()
    client = ContributorClient(root, name="c0")
    with pytest.raises(TimeoutError):
        client.wait_for_iteration(1, timeout=0.1, interval=0.01)
    client.submit(_m(2.0))
    _drain(svc)
    st = client.wait_for_iteration(1, timeout=5.0)
    assert st["iteration"] == 1
    np.testing.assert_allclose(np.asarray(client.download_base()["w"]), 2.0)


def test_service_requires_spill(tmp_path):
    root = str(tmp_path / "repo")
    repo = Repository(_m(0), root=root, screen=False)  # spill=False
    with pytest.raises(ValueError, match="spill=True"):
        ColdService(repo)
    with pytest.raises(ValueError, match="on-disk"):
        ColdService(Repository(_m(0), screen=False))


def test_ingest_spilled_direct_api(tmp_path):
    """The queue-ingest entry point registers an on-disk row by reference:
    no copy, manifest-tracked, recovered like any spilled upload."""
    root = str(tmp_path / "repo")
    repo = _make(root)
    spec = FlatSpec.from_tree(_m(0))
    path = os.path.join(root, QUEUE_DIR, "x-000000.npz")
    ckpt.save_flat(path, spec.flatten(_m(8.0)), spec)
    repo.ingest_spilled(path, weight=2.0)
    assert repo.n_staged == 1
    assert "queue/x-000000.npz" in repo.staged_spill_files()
    # crash here would recover it: reopen instead of fusing
    again = Repository.open(root, spill=True)
    assert again.n_staged == 1 and again._pending_weights == [2.0]
    again.fuse_pending()
    np.testing.assert_allclose(np.asarray(again.download()["w"]), 8.0)
    with pytest.raises(ValueError, match="outside"):
        repo.ingest_spilled(os.path.join(str(tmp_path), "elsewhere.npz"))


# ---------------------------------------------------------------------------
# novelty admission screen (docs/service_loop.md)
# ---------------------------------------------------------------------------


def test_novelty_screen_rejects_replay_and_near_duplicate(tmp_path):
    """Exact replays (same content, different id) and near-duplicates are
    rejected at the queue boundary; distinct contributions are admitted."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=2, novelty_threshold=0.05, sketch_window=8))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0))
    client.submit(_m(1.0))             # exact replay, new submission id
    client.submit(_m(1.0 + 1e-6))      # near-duplicate
    client.submit(_m(4.0))             # distinct
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 2
    assert st["rejected_total"] == 2 and st["novelty_rejected_total"] == 2
    assert all("near-duplicate" in r["reason"] for r in st["recent_rejects"])
    assert st["novelty_screen"] is True and st["sketch_entries"] == 2
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 2.5)


def test_novelty_screen_survives_restart(tmp_path):
    """The sketch window is durable: a replay of a row fused BEFORE a
    daemon restart is still rejected by the restarted daemon."""
    root = str(tmp_path / "repo")
    pol = AdmissionPolicy(min_cohort=1, novelty_threshold=0.05,
                          sketch_window=8)
    svc = ColdService(_make(root), policy=pol)
    client = ContributorClient(root, name="c0")
    client.submit(_m(2.0))
    _drain(svc)
    svc.close()
    svc2 = ColdService(Repository.open(root, spill=True), policy=pol)
    ContributorClient(root, name="c1").submit(_m(2.0))  # replay, new name
    st = _drain(svc2)
    assert st["iteration"] == 1 and st["novelty_rejected_total"] == 1


def test_novelty_screen_off_by_default(tmp_path):
    """Without novelty_threshold the replay fuses (PR 4 behaviour) and no
    sketch state is created."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0))
    client.submit(_m(1.0))
    st = _drain(svc)
    assert st["fused_contributions"] == 2 and st["novelty_rejected_total"] == 0
    assert st["sketch_entries"] is None
    assert not os.path.exists(os.path.join(root, "cohort_sketch.json"))


def test_novelty_screen_without_rider_sketch(tmp_path):
    """Rows enqueued without a rider sketch (foreign writers) are sketched
    from the file at admission — the screen still catches the replay."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, novelty_threshold=0.05, sketch_window=8))
    spec = FlatSpec.from_tree(_m(0))
    row = np.asarray(spec.flatten(_m(6.0)))
    qdir = os.path.join(root, QUEUE_DIR)
    ckpt.save_flat(os.path.join(qdir, "f-000000.npz"), row, spec,
                   extra={"id": "f-000000"})
    _drain(svc)
    ckpt.save_flat(os.path.join(qdir, "f-000001.npz"), row, spec,
                   extra={"id": "f-000001"})
    st = _drain(svc)
    assert st["iteration"] == 1 and st["novelty_rejected_total"] == 1


def test_novelty_screen_not_bypassed_by_forged_rider_id(tmp_path):
    """Regression (review): the self-match skip is keyed by id AND queue
    file — a replay that forges a previously admitted submission's rider
    id under a new file cannot talk its way past the screen."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, novelty_threshold=0.05, sketch_window=8))
    client = ContributorClient(root, name="c0")
    client.submit(_m(2.0))
    _drain(svc)
    spec = FlatSpec.from_tree(_m(0))
    ckpt.save_flat(os.path.join(root, QUEUE_DIR, "forger-000000.npz"),
                   np.asarray(spec.flatten(_m(2.0))), spec,
                   extra={"id": "c0-000000"})  # the fused row's id, replayed
    st = _drain(svc)
    assert st["iteration"] == 1 and st["novelty_rejected_total"] == 1, st


def test_novelty_screen_distrusts_rider_under_verify(tmp_path):
    """With verify_checksums the service recomputes the sketch from the
    file: a rider sketch that lies about duplicate content cannot evade
    the screen."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, novelty_threshold=0.05, sketch_window=8,
        verify_checksums=True))
    client = ContributorClient(root, name="c0")
    client.submit(_m(3.0))
    _drain(svc)
    spec = FlatSpec.from_tree(_m(0))
    row = np.asarray(spec.flatten(_m(3.0)))  # duplicate content...
    fake = np.asarray(spec.flatten(_m(99.0)))  # ...novel-looking rider sketch
    from repro.utils.flat import row_sketch_host
    ckpt.save_flat(os.path.join(root, QUEUE_DIR, "liar-000000.npz"), row, spec,
                   extra={"id": "liar-000000",
                          "sketch": row_sketch_host(fake).tolist()})
    st = _drain(svc)
    assert st["iteration"] == 1 and st["novelty_rejected_total"] == 1


# ---------------------------------------------------------------------------
# admit-path hardening (malformed riders, torn reads, re-mark dedupe)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad_extra", [
    {"base_iteration": "garbage"},
    {"base_iteration": [1, 2]},
    {"weight": "heavy"},
    {"weight": {"x": 1}},
    {"weight": "nan"},   # finite-ness: NaN·w/Σw would publish a NaN base
    {"weight": "inf"},
    {"id": {"not": "a string"}},
])
def test_malformed_rider_is_per_file_rejection(tmp_path, bad_extra):
    """Regression: a garbage rider must be a per-file rejection with a
    reason — not a daemon last_error that stalls the whole admit pass."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, max_staleness=2))
    spec = FlatSpec.from_tree(_m(0))
    ckpt.save_flat(os.path.join(root, QUEUE_DIR, "bad-000000.npz"),
                   np.asarray(spec.flatten(_m(9.0))), spec,
                   extra={"id": "bad-000000", **bad_extra})
    ContributorClient(root, name="good").submit(_m(5.0), base_iteration=0)
    st = _drain(svc)
    assert st["iteration"] == 1 and st["last_error"] is None
    assert st["rejected_total"] == 1
    assert "malformed rider" in st["recent_rejects"][0]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 5.0)


def _corrupt_buffer_entry(path):
    """Rewrite a flat npz so its metadata entries stay readable but the
    buffer entry's bytes are garbage (CRC fails on access) — a torn file
    that passes the admission meta peek and dies on the full-row read."""
    import zipfile
    tmp = path + ".rewrite"
    with zipfile.ZipFile(path) as zin, \
            zipfile.ZipFile(tmp, "w", zipfile.ZIP_STORED) as zout:
        for info in zin.infolist():
            zout.writestr(info.filename, zin.read(info.filename))
            if info.filename.startswith("__flat_buffer__"):
                # poison the central directory's recorded CRC: zipfile
                # raises BadZipFile ("Bad CRC-32") when the entry is read
                zout.infolist()[-1].CRC = 0xDEADBEEF
    os.replace(tmp, path)


def test_torn_row_between_meta_and_checksum_read_quarantined(tmp_path):
    """Regression: _checksum_ok raising (file torn between the meta peek
    and the full-row read) must reject that one file, not abort the
    whole admit pass."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, verify_checksums=True))
    client = ContributorClient(root, name="c0")
    client.submit(_m(2.0), checksum=True)
    _corrupt_buffer_entry(os.path.join(root, QUEUE_DIR, "c0-000000.npz"))
    client.submit(_m(7.0), checksum=True)  # healthy row behind the torn one
    st = _drain(svc)
    assert st["iteration"] == 1 and st["last_error"] is None
    assert st["rejected_total"] == 1
    assert "unreadable" in st["recent_rejects"][0]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 7.0)


def test_remark_dedupes_by_file_when_rider_id_differs(tmp_path):
    """Regression: a submission whose rider id differs from its filename
    stem, ingested pre-crash but never queue-marked, must end up under ONE
    queue-manifest entry after the re-mark — and fuse exactly once."""
    root = str(tmp_path / "repo")
    repo = _make(root)
    spec = FlatSpec.from_tree(_m(0))
    path = os.path.join(root, QUEUE_DIR, "stem-000000.npz")
    ckpt.save_flat(path, np.asarray(spec.flatten(_m(4.0))), spec,
                   extra={"id": "rider-id-x", "weight": 2.0})
    repo.ingest_spilled(path, weight=2.0)  # crash at service.post_ingest
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1))
    st = svc.run_once()
    files = [e["file"] for e in svc._entries.values()]
    assert files.count("stem-000000.npz") <= 1, svc._entries
    st = _drain(svc)
    assert st["iteration"] == 1
    assert sum(r.n_contributions for r in svc.repo.history) == 1
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 4.0)
    qdir = os.path.join(root, QUEUE_DIR)
    assert [f for f in os.listdir(qdir) if f.endswith(".npz")] == []
    assert ckpt.load_json(os.path.join(qdir, QUEUE_MANIFEST))["entries"] == []


def test_rejection_counters_survive_restart(tmp_path):
    """Counters persist in the queue manifest even on reject-only passes,
    so a restarted daemon's totals match what the status reported."""
    root = str(tmp_path / "repo")
    pol = AdmissionPolicy(min_cohort=1, novelty_threshold=0.05)
    svc = ColdService(_make(root), policy=pol)
    client = ContributorClient(root, name="c0")
    client.submit(_m(2.0))
    _drain(svc)
    client.submit(_m(2.0))  # replay: a reject-only admit pass
    st = _drain(svc)
    assert st["rejected_total"] == 1 and st["novelty_rejected_total"] == 1
    svc.close()
    svc2 = ColdService(Repository.open(root, spill=True), policy=pol)
    st2 = svc2.status()
    assert st2["rejected_total"] == 1 and st2["novelty_rejected_total"] == 1


# ---------------------------------------------------------------------------
# property tests: queue/cohort invariants under arbitrary interleavings
# ---------------------------------------------------------------------------


# NOTE: @settings below @given so the shim's given() sees the settings
# (decorators apply bottom-up; real hypothesis accepts either order)
@given(st.lists(st.sampled_from(["submit", "cycle", "burst"]),
                min_size=1, max_size=8))
@settings(max_examples=8, deadline=None)
def test_interleavings_preserve_monotonicity_and_drop_nothing(ops):
    """Any interleaving of submit / poll-cycle / burst keeps the published
    iteration monotone and fuses every submission exactly once."""
    root = tempfile.mkdtemp(prefix="cold_prop_")
    try:
        svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2))
        client = ContributorClient(root, name="p")
        submitted, last_it = 0, 0
        for op in ops:
            if op == "submit":
                client.submit(_m(float(submitted)))
                submitted += 1
            elif op == "burst":
                client.submit(_m(float(submitted)))
                client.submit(_m(float(submitted + 1)))
                submitted += 2
            st = svc.run_once()
            assert st["iteration"] >= last_it, "iteration went backwards"
            last_it = st["iteration"]
        svc.policy.min_cohort = 1  # drain stragglers below the cohort bar
        st = _drain(svc)
        assert st["iteration"] >= last_it
        fused = sum(r.n_contributions for r in svc.repo.history)
        assert fused == submitted, f"dropped/duplicated: {fused} != {submitted}"
        assert st["iteration"] == len(svc.repo.history)
        qdir = os.path.join(root, QUEUE_DIR)
        assert [f for f in os.listdir(qdir) if f.endswith(".npz")] == []
    finally:
        shutil.rmtree(root, ignore_errors=True)


# NOTE: @settings below @given so the shim's given() sees the settings
@given(st.lists(st.sampled_from(["submit", "dup", "near", "cycle", "burst"]),
                min_size=1, max_size=8))
@settings(max_examples=8, deadline=None)
def test_interleavings_with_duplicates_screen_consistently(ops):
    """Any interleaving of distinct submits, exact replays, and
    near-duplicates: every distinct contribution fuses exactly once, every
    planted duplicate is rejected exactly once, and the counters stay
    consistent with the history."""
    root = tempfile.mkdtemp(prefix="cold_prop_nov_")
    try:
        svc = ColdService(_make(root), policy=AdmissionPolicy(
            min_cohort=2, novelty_threshold=0.02, sketch_window=64))
        client = ContributorClient(root, name="p")
        distinct = dups = 0
        last_val = None
        for op in ops:
            if op in ("submit", "burst"):
                for _ in range(2 if op == "burst" else 1):
                    distinct += 1
                    last_val = float(distinct)
                    client.submit(_m(last_val))
            elif op == "dup" and last_val is not None:
                client.submit(_m(last_val))            # exact replay
                dups += 1
            elif op == "near" and last_val is not None:
                client.submit(_m(last_val + 1e-7))     # near-duplicate
                dups += 1
            st = svc.run_once()
        svc.policy.min_cohort = 1  # drain stragglers below the cohort bar
        st = _drain(svc)
        fused = sum(r.n_contributions for r in svc.repo.history)
        assert fused == distinct, f"{fused} fused != {distinct} distinct"
        assert st["novelty_rejected_total"] == dups, st
        assert st["rejected_total"] == dups, st
        assert st["iteration"] == len(svc.repo.history)
        qdir = os.path.join(root, QUEUE_DIR)
        assert [f for f in os.listdir(qdir) if f.endswith(".npz")] == []
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------
# spill compaction / GC
# ---------------------------------------------------------------------------


def _fuse_rounds(repo, n):
    for it in range(n):
        repo.upload(_m(float(it + 1)))
        repo.fuse_pending()


def test_compact_keeps_current_base_and_staged_rows(tmp_path):
    root = str(tmp_path / "repo")
    repo = _make(root)
    _fuse_rounds(repo, 4)  # bases 0..4 on disk, 4 archived rows
    repo.upload(_m(9.0))   # staged, manifest-referenced
    out = repo.compact(keep_bases=2)
    assert out == {"bases_removed": 3, "rows_removed": 4}
    bases = sorted(f for f in os.listdir(root) if f.startswith("base_iter"))
    assert bases == ["base_iter0003.npz", "base_iter0004.npz"]
    again = Repository.open(root, spill=True)
    assert again.iteration == 4 and again.n_staged == 1
    again.fuse_pending()
    np.testing.assert_allclose(np.asarray(again.download()["w"]), 9.0)


@pytest.mark.parametrize("survive_removes", [0, 1, 3])
def test_compact_crash_midway_never_breaks_recovery(tmp_path, monkeypatch,
                                                    survive_removes):
    """Kill compact after N deletions, for several N: recovery must never
    reference a deleted file — open() + fuse still work."""
    root = str(tmp_path / "repo")
    repo = _make(root)
    _fuse_rounds(repo, 3)
    repo.upload(_m(7.0))
    real_remove, calls = os.remove, []

    def flaky_remove(path):
        if len(calls) >= survive_removes:
            raise RuntimeError("injected crash mid-compact")
        calls.append(path)
        real_remove(path)

    monkeypatch.setattr(os, "remove", flaky_remove)
    with pytest.raises(RuntimeError, match="mid-compact"):
        repo.compact(keep_bases=1)
    monkeypatch.setattr(os, "remove", real_remove)
    again = Repository.open(root, spill=True)
    assert again.iteration == 3 and again.n_staged == 1
    again.fuse_pending()
    np.testing.assert_allclose(np.asarray(again.download()["w"]), 7.0)
    # a clean re-run finishes the job
    again.compact(keep_bases=1)
    assert sorted(f for f in os.listdir(root) if f.startswith("base_iter")) \
        == ["base_iter0004.npz"]


def test_compact_validations(tmp_path):
    with pytest.raises(ValueError, match="on-disk"):
        Repository(_m(0)).compact()
    repo = _make(str(tmp_path / "repo"))
    with pytest.raises(ValueError, match="keep_bases"):
        repo.compact(keep_bases=0)


def test_service_compacts_after_publish(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, compact_keep_bases=1))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0))
    _drain(svc)
    client.submit(_m(2.0))
    _drain(svc)
    assert svc.repo.iteration == 2
    bases = [f for f in os.listdir(root) if f.startswith("base_iter")]
    assert bases == ["base_iter0002.npz"]


# ---------------------------------------------------------------------------
# fault injection: exactly-once fusion across kill-at-checkpoint crashes
# ---------------------------------------------------------------------------

_SCENARIO = '''
import os, sys
sys.path.insert(0, "src")
import numpy as np
import jax.numpy as jnp
from repro.core.repository import Repository
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient

root, phase = sys.argv[1], sys.argv[2]

def m(v):
    return {"w": jnp.full((96,), float(v)), "b": jnp.full((7,), float(v))}

if phase == "prep":
    Repository(m(0.0), root=root, spill=True, screen=False)
    client = ContributorClient(root, name="c")
    for v, w in ((1.0, 2.0), (3.0, 1.0), (5.0, 1.0)):
        client.submit(m(v), weight=w, base_iteration=0)
    print("PREP_OK", flush=True)
    sys.exit(0)

if phase == "client_crash":
    # killed mid-submit: nothing durable may appear under the final name
    client = ContributorClient(root, name="late")
    client.submit(m(9.0), weight=1.0, seq=0)
    raise AssertionError("unreachable: client.mid_submit must fire")

if phase == "client_retry":
    client = ContributorClient(root, name="late")
    print("RETRY", client.submit(m(9.0), weight=1.0, seq=0), flush=True)
    sys.exit(0)

# phase == "serve": poll to quiescence (or die at the armed crash point)
repo = Repository.open(root, spill=True)
svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=3))
for _ in range(200):
    st = svc.run_once()
    if (st["iteration"] >= 1 and not st["inflight"] and st["staged"] == 0
            and st["queue_depth"] == 0):
        break
else:
    print("NO_CONVERGENCE", st, flush=True)
    sys.exit(3)
st = svc.close()
w = np.asarray(repo.download()["w"])
n_q = len([f for f in os.listdir(svc.queue_dir) if f.endswith(".npz")])
print(f"DONE it={st['iteration']} fused={st['fused_contributions']} "
      f"w={w[0]:.6f} qfiles={n_q}", flush=True)
'''

# the crash windows of docs/service_loop.md's matrix, in lifecycle order:
# after a row enters the staging manifest but before the queue manifest
# marks it; after the fuse dispatch but before any publish; after the base
# publish but before the staging-manifest rewrite; after the full publish
# but before queue GC; and mid-GC between file delete and entry drop.
CRASH_POINTS = [
    "service.post_ingest",
    "service.post_dispatch",
    "repo.post_publish_pre_manifest",
    "service.post_publish",
    "service.mid_gc",
]


def _done_line(res):
    line = [l for l in res.stdout.splitlines() if l.startswith("DONE")][0]
    return dict(kv.split("=") for kv in line.split()[1:])


@pytest.mark.slow
@pytest.mark.parametrize("point", CRASH_POINTS)
def test_exactly_once_fusion_across_crash_points(tmp_path, point):
    """kill -9 the daemon at any crash window, restart it: every submitted
    contribution fuses exactly once and the published base equals the
    uninterrupted run's (weighted mean 2.5)."""
    root = str(tmp_path / "repo")
    run_child(_SCENARIO, [root, "prep"])
    run_child(_SCENARIO, [root, "serve"], crash_at=point)
    res = run_child(_SCENARIO, [root, "serve"])  # restart, run to completion
    done = _done_line(res)
    assert done["it"] == "1", done       # ONE publish total — never two
    assert done["fused"] == "3", done    # every submission, exactly once
    assert abs(float(done["w"]) - 2.5) < 1e-5, done
    assert done["qfiles"] == "0", done   # queue fully GC'd


@pytest.mark.slow
def test_uninterrupted_reference_run(tmp_path):
    """The oracle the crash tests compare against: prep + serve with no
    crash lands on the same DONE line."""
    root = str(tmp_path / "repo")
    run_child(_SCENARIO, [root, "prep"])
    done = _done_line(run_child(_SCENARIO, [root, "serve"]))
    assert done == {"it": "1", "fused": "3", "w": "2.500000", "qfiles": "0"}


# the novelty-screen variant of the crash matrix: three distinct prepped
# submissions plus a planted exact replay of one of them, served with the
# screen armed.  Every window of the original matrix plus the new
# sketch-persist window (service.post_sketch) must converge to the same
# duplicate-free base with consistent rejection counters.
_NOVELTY_SCENARIO = '''
import os, sys
sys.path.insert(0, "src")
import numpy as np
import jax.numpy as jnp
from repro.core.repository import Repository
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient

root, phase = sys.argv[1], sys.argv[2]

def m(v):
    return {"w": jnp.full((96,), float(v)), "b": jnp.full((7,), float(v))}

if phase == "prep":
    Repository(m(0.0), root=root, spill=True, screen=False)
    client = ContributorClient(root, name="c")
    for v, w in ((1.0, 2.0), (3.0, 1.0), (5.0, 1.0)):
        client.submit(m(v), weight=w, base_iteration=0)
    # the planted replay: same content as c-000001, different contributor
    ContributorClient(root, name="d").submit(m(3.0), weight=1.0,
                                             base_iteration=0)
    print("PREP_OK", flush=True)
    sys.exit(0)

repo = Repository.open(root, spill=True)
svc = ColdService(repo, policy=AdmissionPolicy(
    min_cohort=3, novelty_threshold=0.02, sketch_window=8))
for _ in range(200):
    st = svc.run_once()
    if (st["iteration"] >= 1 and not st["inflight"] and st["staged"] == 0
            and st["queue_depth"] == 0):
        break
else:
    print("NO_CONVERGENCE", st, flush=True)
    sys.exit(3)
st = svc.close()
w = np.asarray(repo.download()["w"])
n_q = len([f for f in os.listdir(svc.queue_dir) if f.endswith(".npz")])
print(f"DONE it={st['iteration']} fused={st['fused_contributions']} "
      f"w={w[0]:.6f} rej={st['rejected_total']} "
      f"nov={st['novelty_rejected_total']} qfiles={n_q}", flush=True)
'''

_NOVELTY_DONE = {"it": "1", "fused": "3", "w": "2.500000",
                 "rej": "1", "nov": "1", "qfiles": "0"}


@pytest.mark.slow
@pytest.mark.parametrize("point", ["service.post_sketch"] + CRASH_POINTS)
def test_novelty_screen_exactly_once_across_crash_points(tmp_path, point):
    """kill -9 the screened daemon at any window (including the new
    sketch-persist window), restart: every distinct submission fuses
    exactly once, the replay is rejected exactly once, and the counters
    agree with the uninterrupted run."""
    root = str(tmp_path / "repo")
    run_child(_NOVELTY_SCENARIO, [root, "prep"])
    run_child(_NOVELTY_SCENARIO, [root, "serve"], crash_at=point)
    done = _done_line(run_child(_NOVELTY_SCENARIO, [root, "serve"]))
    assert done == _NOVELTY_DONE, done


@pytest.mark.slow
def test_novelty_uninterrupted_reference_run(tmp_path):
    root = str(tmp_path / "repo")
    run_child(_NOVELTY_SCENARIO, [root, "prep"])
    done = _done_line(run_child(_NOVELTY_SCENARIO, [root, "serve"]))
    assert done == _NOVELTY_DONE, done


# fault-harness regression for the re-mark dedupe: a submission whose rider
# id differs from its filename stem, killed at service.post_ingest (staged
# but never queue-marked), must re-mark into ONE entry and fuse once.
_ODD_ID_SCENARIO = '''
import os, sys
sys.path.insert(0, "src")
import numpy as np
import jax.numpy as jnp
from repro.checkpoint import io as ckpt
from repro.core.repository import Repository
from repro.serve.cold_service import AdmissionPolicy, ColdService
from repro.utils.flat import FlatSpec

root, phase = sys.argv[1], sys.argv[2]

def m(v):
    return {"w": jnp.full((96,), float(v)), "b": jnp.full((7,), float(v))}

if phase == "prep":
    Repository(m(0.0), root=root, spill=True, screen=False)
    spec = FlatSpec.from_tree(m(0.0))
    ckpt.save_flat(os.path.join(root, "queue", "stem-000000.npz"),
                   np.asarray(spec.flatten(m(4.0))), spec,
                   extra={"id": "rider-id-x", "weight": 1.0})
    print("PREP_OK", flush=True)
    sys.exit(0)

repo = Repository.open(root, spill=True)
svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=1))
for _ in range(200):
    st = svc.run_once()
    if (st["iteration"] >= 1 and not st["inflight"] and st["staged"] == 0
            and st["queue_depth"] == 0):
        break
else:
    print("NO_CONVERGENCE", st, flush=True)
    sys.exit(3)
st = svc.close()
qman = ckpt.load_json(os.path.join(root, "queue", "queue_manifest.json"))
w = np.asarray(repo.download()["w"])
n_q = len([f for f in os.listdir(svc.queue_dir) if f.endswith(".npz")])
print(f"DONE it={st['iteration']} fused={st['fused_contributions']} "
      f"w={w[0]:.6f} entries={len(qman['entries'])} qfiles={n_q}", flush=True)
'''


@pytest.mark.slow
def test_odd_rider_id_remark_across_post_ingest_crash(tmp_path):
    root = str(tmp_path / "repo")
    run_child(_ODD_ID_SCENARIO, [root, "prep"])
    run_child(_ODD_ID_SCENARIO, [root, "serve"],
              crash_at="service.post_ingest")
    done = _done_line(run_child(_ODD_ID_SCENARIO, [root, "serve"]))
    assert done == {"it": "1", "fused": "1", "w": "4.000000",
                    "entries": "0", "qfiles": "0"}, done


@pytest.mark.slow
def test_client_killed_mid_submit_then_retry(tmp_path):
    """A contributor killed mid-enqueue leaves nothing under the final
    name; the retry (same name+seq) enqueues exactly one row."""
    root = str(tmp_path / "repo")
    run_child(_SCENARIO, [root, "prep"])
    run_child(_SCENARIO, [root, "client_crash"], crash_at="client.mid_submit")
    qdir = os.path.join(root, QUEUE_DIR)
    files = [f for f in os.listdir(qdir) if f.endswith(".npz")]
    assert not any(f.startswith("late-") for f in files), files
    run_child(_SCENARIO, [root, "client_retry"])
    files = [f for f in os.listdir(qdir) if f.startswith("late-")]
    assert files == ["late-000000.npz"]
    # 3 prepped + 1 retried row fuse in one cohort: (2·1+3+5+9)/5
    res = run_child(_SCENARIO, [root, "serve"])
    done = _done_line(res)
    assert done["fused"] == "4" and abs(float(done["w"]) - 3.8) < 1e-5, done


# ---------------------------------------------------------------------------
# forgetting regression gate: probes -> rollback -> quarantine -> metrics
# ---------------------------------------------------------------------------

def _gate(tolerance=0.5):
    # _m trees flatten to 64 + 5 = 69 elements
    return RegressionGate(ProbeSuite(69, seed=0), tolerance=tolerance)


def _harmful(client, base_iteration, n=2, scale=10.0, seed=7):
    """Submit n rows of large uniform-norm noise: invisible to the MAD
    screen (all norms agree), harmful to the probe readouts."""
    rng = np.random.default_rng(seed)
    for _ in range(n):
        client.submit(
            {"w": (0.2 + rng.normal(0, scale, 64)).astype(np.float32),
             "b": (0.2 + rng.normal(0, scale, 5)).astype(np.float32)},
            base_iteration=base_iteration)


def test_gate_clean_publish_rebaselines(tmp_path):
    """Benign cohorts pass the gate and move the baseline with them — the
    tolerance is on the per-fuse delta, so benign drift never accumulates
    into a false trip."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2),
                      gate=_gate())
    client = ContributorClient(root, name="c")
    for v in (0.1, 0.3):
        client.submit(_m(v), base_iteration=0)
    st = _drain(svc)
    assert st["iteration"] == 1 and st["rollbacks_total"] == 0
    assert st["gate"] and st["last_gate"]["ok"] is True
    assert ckpt.load_json(os.path.join(root, "gate_state.json"))["iteration"] == 1
    for v in (0.2, 0.4):
        client.submit(_m(v), base_iteration=1)
    st = _drain(svc)
    assert st["iteration"] == 2 and st["rollbacks_total"] == 0
    assert ckpt.load_json(os.path.join(root, "gate_state.json"))["iteration"] == 2


def test_gate_trips_rolls_back_and_quarantines(tmp_path):
    root = str(tmp_path / "repo")
    repo = _make(root)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=2), gate=_gate())
    client = ContributorClient(root, name="c")
    for v in (0.1, 0.3):
        client.submit(_m(v), base_iteration=0)
    _drain(svc)
    good = np.array(repo.flat_base_host(), copy=True)
    _harmful(ContributorClient(root, name="bad"), base_iteration=1)
    st = _drain(svc)
    assert st["iteration"] == 1, st
    assert st["rollbacks_total"] == 1 and st["quarantined_total"] == 2
    assert st["last_gate"]["ok"] is False and st["last_gate"]["regressed"]
    np.testing.assert_array_equal(repo.flat_base_host(), good)
    qdir = os.path.join(root, "quarantine")
    assert len([f for f in os.listdir(qdir) if f.endswith(".npz")]) == 2
    # quarantined rows never re-enter the queue: more cycles change nothing
    st = _drain(svc)
    assert st["quarantined_total"] == 2 and st["iteration"] == 1
    # the verdicts landed in the metrics time series
    events = [r["event"] for r in
              ckpt.read_jsonl(os.path.join(root, "metrics.jsonl"))]
    assert "quarantine" in events and "rollback" in events
    # ... and a benign cohort after the rollback still fuses cleanly
    for v in (0.2, 0.4):
        client.submit(_m(v), base_iteration=1)
    st = _drain(svc)
    assert st["iteration"] == 2 and st["rollbacks_total"] == 1
    svc.close()
    # counters and gate state survive restart
    svc2 = ColdService(Repository.open(root, spill=True), gate=_gate())
    st2 = svc2.status()
    assert st2["rollbacks_total"] == 1 and st2["quarantined_total"] == 2
    svc2.close()


def test_gate_requires_retained_baseline_bases(tmp_path):
    """Arming the gate with compaction keeping <2 bases would delete the
    rollback target; the service widens the floor instead."""
    root = str(tmp_path / "repo")
    with pytest.warns(UserWarning, match="keep_bases"):
        svc = ColdService(_make(root),
                          policy=AdmissionPolicy(compact_keep_bases=1),
                          gate=_gate())
    assert svc.policy.compact_keep_bases == 2


def test_recent_errors_ring_bounded_and_persisted(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root))
    for i in range(ERROR_RING + 9):
        svc._note_error(RuntimeError(f"boom {i}"))
    errs = svc.status()["recent_errors"]
    assert len(errs) == ERROR_RING
    assert f"boom {ERROR_RING + 8}" in errs[-1]["error"]
    assert all("t" in e for e in errs)
    svc.close()
    errs2 = ColdService(Repository.open(root, spill=True)).status()["recent_errors"]
    assert len(errs2) == ERROR_RING
    assert f"boom {ERROR_RING + 8}" in errs2[-1]["error"]


def test_wait_for_iteration_total_wait_bounded_by_timeout(tmp_path):
    """Regression test for the backoff: even with a poll interval far
    above the timeout, every sleep is clamped to the remaining budget."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root))
    svc.run_once()
    client = ContributorClient(root)
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        client.wait_for_iteration(5, timeout=0.2, interval=5.0,
                                  max_interval=60.0)
    assert time.monotonic() - t0 < 1.0


def test_serve_forever_idle_backoff_capped(tmp_path):
    """The no-progress sleep backs off but stays capped, so idle_timeout
    is honored promptly rather than overshot by a runaway interval."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root))
    t0 = time.monotonic()
    st = svc.serve_forever(poll_interval=0.01, idle_timeout=0.3,
                           max_poll_interval=0.05)
    elapsed = time.monotonic() - t0
    assert st["iteration"] == 0
    assert 0.3 <= elapsed < 2.0, elapsed


def test_metrics_emitted_on_state_change_only(tmp_path):
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=1))
    mpath = os.path.join(root, "metrics.jsonl")
    client = ContributorClient(root, name="c")
    client.submit(_m(1.0))
    _drain(svc)
    recs = ckpt.read_jsonl(mpath)
    n = len(recs)
    assert n >= 1
    assert all("t" in r and r["event"] == "cycle" for r in recs)
    for _ in range(10):
        svc.run_once()  # idle cycles may not grow the series
    assert len(ckpt.read_jsonl(mpath)) == n
    # a writer killed mid-append leaves a torn tail: readers skip it, and
    # the next service start repairs it so appends never weld mid-file
    with open(mpath, "a") as f:
        f.write('{"event": "cyc')
    assert len(ckpt.read_jsonl(mpath, warn=False)) == n
    svc.close()
    with pytest.warns(UserWarning, match="torn"):
        svc2 = ColdService(Repository.open(root, spill=True),
                           policy=AdmissionPolicy(min_cohort=1))
    client.submit(_m(2.0))
    _drain(svc2)
    recs = ckpt.read_jsonl(mpath)  # parses end to end: no welded line
    assert len(recs) > n
    assert all(r["event"] == "cycle" for r in recs)


# the gate variant of the crash matrix: a clean benign publish establishes
# the baseline, then a harmful cohort (large uniform-norm noise — admitted
# by every screen) is served with the gate armed.  kill -9 anywhere inside
# publish -> probe -> quarantine -> rollback, restart, and the run must
# converge to the benign fixed point with the harmful rows quarantined
# exactly once and the counters exact.
_GATE_SCENARIO = '''
import os, sys
sys.path.insert(0, "src")
import numpy as np
import jax.numpy as jnp
from repro.core.repository import Repository
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient
from repro.serve.probes import ProbeSuite, RegressionGate

root, phase = sys.argv[1], sys.argv[2]

def m(v):
    return {"w": jnp.full((96,), float(v)), "b": jnp.full((7,), float(v))}

def gate():
    return RegressionGate(ProbeSuite(103, seed=0), tolerance=0.5)

def serve(stop):
    repo = Repository.open(root, spill=True)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=3), gate=gate())
    for _ in range(200):
        st = svc.run_once()
        if stop(st):
            break
    else:
        print("NO_CONVERGENCE", st, flush=True)
        sys.exit(3)
    st = svc.close()
    w = np.asarray(repo.download()["w"])
    n_q = len([f for f in os.listdir(svc.queue_dir) if f.endswith(".npz")])
    n_quar = (len([f for f in os.listdir(svc.quarantine_dir)
                   if f.endswith(".npz")])
              if os.path.isdir(svc.quarantine_dir) else 0)
    print(f"DONE it={st['iteration']} fused={st['fused_contributions']} "
          f"w={w[0]:.6f} qfiles={n_q} quar={n_quar} "
          f"quarc={st['quarantined_total']} rb={st['rollbacks_total']}",
          flush=True)

if phase == "prep":
    Repository(m(0.0), root=root, spill=True, screen=False)
    client = ContributorClient(root, name="c")
    for v in (0.1, 0.3, 0.5):
        client.submit(m(v), weight=1.0, base_iteration=0)
    print("PREP_OK", flush=True)
    sys.exit(0)

if phase == "serve_clean":
    serve(lambda st: st["iteration"] >= 1 and not st["inflight"]
          and st["staged"] == 0 and st["queue_depth"] == 0)
    sys.exit(0)

if phase == "plant":
    client = ContributorClient(root, name="bad")
    rng = np.random.default_rng(99)
    for j in range(3):
        client.submit({"w": (0.3 + rng.normal(0, 10.0, 96)).astype(np.float32),
                       "b": (0.3 + rng.normal(0, 10.0, 7)).astype(np.float32)},
                      weight=1.0, base_iteration=1)
    print("PLANT_OK", flush=True)
    sys.exit(0)

# phase == "serve": drive the harmful cohort through
# publish -> probe -> quarantine -> rollback to quiescence
serve(lambda st: st["rollbacks_total"] >= 1 and st["iteration"] == 1
      and not st["inflight"] and st["staged"] == 0
      and st["queue_depth"] == 0)
'''

# every window of the harmful cohort's lifecycle, in order: staging, fuse
# dispatch, the two publish windows, then the three gate seams — verdict
# computed but unapplied (post_probe), cohort quarantined but base not yet
# rolled back (post_quarantine), base restored on disk but spill manifest
# not yet rewritten (mid_rollback).
GATE_CRASH_POINTS = [
    "service.post_ingest",
    "service.post_dispatch",
    "repo.post_publish_pre_manifest",
    "service.post_publish",
    "service.post_probe",
    "service.post_quarantine",
    "repo.mid_rollback",
]

_GATE_DONE = {"it": "1", "fused": "3", "w": "0.300000", "qfiles": "0",
              "quar": "3", "quarc": "3", "rb": "1"}


@pytest.mark.slow
@pytest.mark.parametrize("point", GATE_CRASH_POINTS)
def test_gate_exactly_once_across_crash_points(tmp_path, point):
    """kill -9 the daemon anywhere inside the gate's verdict path and
    restart: the harmful cohort is quarantined exactly once, the base
    converges to the benign fixed point, and no admitted row is lost or
    double-fused."""
    root = str(tmp_path / "repo")
    run_child(_GATE_SCENARIO, [root, "prep"])
    run_child(_GATE_SCENARIO, [root, "serve_clean"])
    run_child(_GATE_SCENARIO, [root, "plant"])
    run_child(_GATE_SCENARIO, [root, "serve"], crash_at=point)
    done = _done_line(run_child(_GATE_SCENARIO, [root, "serve"]))
    assert done == _GATE_DONE, (point, done)
    # the metrics series survived the kill -9 parseable end to end.  The
    # series is best-effort (the counters in the queue manifest are the
    # source of truth): a kill between the rollback's on-disk commit and
    # its append — exactly the repo.mid_rollback window — loses that one
    # record, and the restart correctly does NOT replay the (already
    # applied) verdict just to re-log it.
    recs = ckpt.read_jsonl(os.path.join(root, "metrics.jsonl"), warn=False)
    events = [r["event"] for r in recs]
    assert "quarantine" in events, events
    if point != "repo.mid_rollback":
        assert "rollback" in events, events
    assert recs[-1]["rollbacks_total"] == 1, recs[-1]


@pytest.mark.slow
def test_gate_uninterrupted_reference_run(tmp_path):
    """The oracle the gate crash tests compare against."""
    root = str(tmp_path / "repo")
    run_child(_GATE_SCENARIO, [root, "prep"])
    run_child(_GATE_SCENARIO, [root, "serve_clean"])
    run_child(_GATE_SCENARIO, [root, "plant"])
    done = _done_line(run_child(_GATE_SCENARIO, [root, "serve"]))
    assert done == _GATE_DONE, done


# ---------------------------------------------------------------------------
# delta-compressed submissions (docs/service_loop.md): admission, vintage
# pin, checksum-over-encoded-bytes, novelty from the decoded delta, and the
# mixed compressed+dense crash matrix
# ---------------------------------------------------------------------------

# uniform deltas with k_per_block covering every live entry reconstruct to
# float32 rounding (~1e-7 relative), so the dense closed forms carry over
_KB = 128  # > 69 live entries of _m: nothing is dropped by top-k


def _submit_compressed(client, v, *, weight=None, base_iteration=0,
                       base_v=0.0, **kw):
    return client.submit(_m(v), weight=weight, base_iteration=base_iteration,
                         compress=True, base=_m(base_v), k_per_block=_KB,
                         **kw)


def test_compressed_submit_fuse_roundtrip(tmp_path):
    """Compressed submissions fuse to the dense closed form, never leave a
    dense row in the queue, and GC like any other submission."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=2))
    client = ContributorClient(root, name="c0")
    _submit_compressed(client, 3.0, weight=1.0)
    _submit_compressed(client, 9.0, weight=3.0)
    qdir = os.path.join(root, QUEUE_DIR)
    for f in os.listdir(qdir):
        if f.endswith(".npz"):  # encoded payloads on the wire, never dense
            assert ckpt.is_flat_compressed(os.path.join(qdir, f)), f
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 2
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]),
                               (1 * 3.0 + 3 * 9.0) / 4.0, atol=1e-5)
    assert [f for f in os.listdir(qdir) if f.endswith(".npz")] == []
    # (the queue-bytes reduction itself is asserted at realistic N by
    # benchmarks/service_loop.py --compress; 69 params is all overhead)


def test_compressed_mixed_cohort_matches_dense(tmp_path):
    """A cohort mixing dense rows and compressed deltas publishes the same
    weighted mean as the all-dense equivalent."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=4))
    client = ContributorClient(root, name="c0")
    client.submit(_m(1.0), weight=2.0)
    client.submit(_m(3.0), weight=1.0)
    _submit_compressed(client, 5.0, weight=1.0)
    _submit_compressed(client, 7.0, weight=2.0)
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 4
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]),
                               (2 * 1 + 1 * 3 + 1 * 5 + 2 * 7) / 6.0,
                               atol=1e-5)


def test_compressed_vintage_pin_rejects_stale(tmp_path):
    """A delta declared against any iteration but the current one is a
    per-file rejection — it can only mis-decode against the wrong base."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=1))
    client = ContributorClient(root, name="c0")
    client.submit(_m(2.0))
    _drain(svc)
    assert svc.repo.iteration == 1
    _submit_compressed(client, 5.0, base_iteration=0)  # yesterday's base
    st = _drain(svc)
    assert st["iteration"] == 1 and st["rejected_total"] == 1
    assert "stale" in st["recent_rejects"][0]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 2.0)
    # ... and future vintages are equally undecodable
    _submit_compressed(client, 5.0, base_iteration=7)
    st = _drain(svc)
    assert st["rejected_total"] == 2
    assert "stale" in st["recent_rejects"][-1]["reason"]


def test_compressed_without_base_iteration_is_malformed(tmp_path):
    """A compressed file with no declared vintage is undecodable by
    construction: per-file malformed-rider rejection, daemon unharmed."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=1))
    spec = FlatSpec.from_tree(_m(0))
    base = np.asarray(spec.flatten(_m(0.0)), np.float32)
    pay = delta_encode(np.asarray(spec.flatten(_m(4.0)), np.float32), base,
                       k_per_block=_KB)
    ckpt.save_flat_delta(os.path.join(root, QUEUE_DIR, "f-000000.npz"), pay,
                         spec, extra={"id": "f-000000"})
    ContributorClient(root, name="good").submit(_m(5.0))
    st = _drain(svc)
    assert st["iteration"] == 1 and st["last_error"] is None
    assert st["rejected_total"] == 1
    assert "malformed rider" in st["recent_rejects"][0]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 5.0)


def test_compressed_nonfinite_scale_is_malformed(tmp_path):
    """Non-finite quantization scales would decode to a non-finite delta:
    rejected at the boundary, not dispatched into the fuse."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=1))
    client = ContributorClient(root, name="c0")
    sub = _submit_compressed(client, 4.0)
    path = os.path.join(root, QUEUE_DIR, sub + ".npz")
    with np.load(path) as data:
        arrays = {k: data[k] for k in data.files}
    arrays["__delta_scales__"] = np.full_like(arrays["__delta_scales__"],
                                              np.inf)
    np.savez(path, **arrays)
    ContributorClient(root, name="good").submit(_m(5.0))
    st = _drain(svc)
    assert st["iteration"] == 1 and st["last_error"] is None
    assert st["rejected_total"] == 1
    assert "non-finite quantization scale" in st["recent_rejects"][0]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 5.0)


def test_compressed_checksum_over_encoded_bytes(tmp_path):
    """Regression: verify_checksums recomputes over the ENCODED payload
    bytes.  A liar rider stamping the decoded row's CRC is a per-file
    rejection — matching on the decoded row would let a corrupted payload
    through whenever it still decoded cleanly."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, verify_checksums=True))
    client = ContributorClient(root, name="c0")
    _submit_compressed(client, 2.0, checksum=True)
    st = _drain(svc)
    assert st["iteration"] == 1 and st["rejected_total"] == 0
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 2.0,
                               atol=1e-5)
    # the liar: a hand-built file whose rider CRC is of the decoded row
    spec = FlatSpec.from_tree(_m(0))
    base = np.asarray(svc.repo.flat_base_host())
    row = np.asarray(spec.flatten(_m(6.0)), np.float32)
    pay = delta_encode(row, base, k_per_block=_KB)
    ckpt.save_flat_delta(
        os.path.join(root, QUEUE_DIR, "liar-000000.npz"), pay, spec,
        extra={"id": "liar-000000", "base_iteration": 1,
               "checksum": row_checksum(row)})
    st = _drain(svc)
    assert st["iteration"] == 1 and st["rejected_total"] == 1
    assert "checksum" in st["recent_rejects"][-1]["reason"]


def test_compressed_replay_caught_by_novelty_screen(tmp_path):
    """Two same-content compressed submissions from different contributors
    (no rider sketch — the screen must sketch from the decoded delta,
    without materializing a dense host row): one fuses, one rejects."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(
        min_cohort=1, novelty_threshold=0.05, sketch_window=8))
    spec = FlatSpec.from_tree(_m(0))
    base = np.asarray(spec.flatten(_m(0.0)), np.float32)
    pay = delta_encode(np.asarray(spec.flatten(_m(6.0)), np.float32), base,
                       k_per_block=_KB)
    for name in ("a-000000", "b-000000"):
        ckpt.save_flat_delta(os.path.join(root, QUEUE_DIR, f"{name}.npz"),
                             pay, spec,
                             extra={"id": name, "base_iteration": 0})
    st = _drain(svc)
    assert st["iteration"] == 1 and st["fused_contributions"] == 1
    assert st["novelty_rejected_total"] == 1
    assert "near-duplicate" in st["recent_rejects"][0]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 6.0,
                               atol=1e-5)


def test_compressed_deferred_while_inflight_then_vintage_checked(tmp_path):
    """While a fuse is in flight the base is already moving: a compressed
    arrival is DEFERRED (neither staged nor rejected), and once the
    publish lands its vintage is re-checked against the new iteration."""
    root = str(tmp_path / "repo")
    svc = ColdService(_make(root), policy=AdmissionPolicy(min_cohort=1))
    client = ContributorClient(root, name="c0")
    client.submit(_m(2.0))
    st = svc.run_once()
    assert st["inflight"]
    _submit_compressed(client, 5.0, base_iteration=0)
    st = svc.run_once()  # defers the delta, then finalizes the publish
    assert st["iteration"] == 1
    assert st["queue_depth"] == 1 and st["rejected_total"] == 0
    st = _drain(svc)  # now at vintage 1: the 0-vintage delta is stale
    assert st["rejected_total"] == 1
    assert "stale" in st["recent_rejects"][-1]["reason"]
    np.testing.assert_allclose(np.asarray(svc.repo.download()["w"]), 2.0)


def test_compressed_rejected_after_gate_rollback(tmp_path):
    """Regression: the PR 6 gate rolls the base back, so a delta declared
    against the rolled-back-away vintage must be rejected as stale — never
    decoded against the restored (different) base."""
    root = str(tmp_path / "repo")
    repo = _make(root)
    svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=2),
                      gate=_gate())
    client = ContributorClient(root, name="c")
    for v in (0.1, 0.3):
        client.submit(_m(v), base_iteration=0)
    _drain(svc)
    assert svc.repo.iteration == 1
    good = np.array(repo.flat_base_host(), copy=True)
    _harmful(ContributorClient(root, name="bad"), base_iteration=1)
    st = _drain(svc)
    assert st["rollbacks_total"] == 1 and st["iteration"] == 1
    # a rider finetuned from the transient (rolled-back) iteration-2 base
    _submit_compressed(client, 9.0, base_iteration=2)
    st = _drain(svc)
    assert st["iteration"] == 1 and "stale" in st["recent_rejects"][-1]["reason"]
    np.testing.assert_array_equal(repo.flat_base_host(), good)


# the mixed variant of the crash matrix: two dense + two compressed
# submissions, all declared against vintage 0, served through every kill
# window of the original matrix.  Exactly-once must hold for BOTH row
# kinds, and the published base must match the all-dense closed form.
_COMPRESSED_SCENARIO = '''
import os, sys
sys.path.insert(0, "src")
import numpy as np
import jax.numpy as jnp
from repro.core.repository import Repository
from repro.serve.cold_service import AdmissionPolicy, ColdService, ContributorClient

root, phase = sys.argv[1], sys.argv[2]

def m(v):
    return {"w": jnp.full((96,), float(v)), "b": jnp.full((7,), float(v))}

if phase == "prep":
    Repository(m(0.0), root=root, spill=True, screen=False)
    client = ContributorClient(root, name="c")
    client.submit(m(1.0), weight=2.0, base_iteration=0)
    client.submit(m(3.0), weight=1.0, base_iteration=0)
    for v, w in ((5.0, 1.0), (7.0, 2.0)):
        client.submit(m(v), weight=w, base_iteration=0, compress=True,
                      base=m(0.0), k_per_block=128)
    print("PREP_OK", flush=True)
    sys.exit(0)

# phase == "serve": poll to quiescence (or die at the armed crash point)
repo = Repository.open(root, spill=True)
svc = ColdService(repo, policy=AdmissionPolicy(min_cohort=4))
for _ in range(200):
    st = svc.run_once()
    if (st["iteration"] >= 1 and not st["inflight"] and st["staged"] == 0
            and st["queue_depth"] == 0):
        break
else:
    print("NO_CONVERGENCE", st, flush=True)
    sys.exit(3)
st = svc.close()
w = np.asarray(repo.download()["w"])
n_q = len([f for f in os.listdir(svc.queue_dir) if f.endswith(".npz")])
print(f"DONE it={st['iteration']} fused={st['fused_contributions']} "
      f"w={w[0]:.6f} qfiles={n_q}", flush=True)
'''


def _assert_compressed_done(done):
    assert done["it"] == "1", done       # ONE publish total — never two
    assert done["fused"] == "4", done    # both kinds, each exactly once
    # weighted mean (2·1 + 3 + 5 + 2·7) / 6, to int8-codec reconstruction
    assert abs(float(done["w"]) - 4.0) < 1e-5, done
    assert done["qfiles"] == "0", done   # queue fully GC'd


@pytest.mark.slow
@pytest.mark.parametrize("point", CRASH_POINTS)
def test_compressed_exactly_once_fusion_across_crash_points(tmp_path, point):
    """kill -9 the daemon at any crash window with a mixed compressed+dense
    cohort staged, restart it: every submission of either kind fuses
    exactly once and the base equals the uninterrupted run's."""
    root = str(tmp_path / "repo")
    run_child(_COMPRESSED_SCENARIO, [root, "prep"])
    run_child(_COMPRESSED_SCENARIO, [root, "serve"], crash_at=point)
    done = _done_line(run_child(_COMPRESSED_SCENARIO, [root, "serve"]))
    _assert_compressed_done(done)


@pytest.mark.slow
def test_compressed_uninterrupted_reference_run(tmp_path):
    """The oracle the mixed crash tests compare against."""
    root = str(tmp_path / "repo")
    run_child(_COMPRESSED_SCENARIO, [root, "prep"])
    done = _done_line(run_child(_COMPRESSED_SCENARIO, [root, "serve"]))
    _assert_compressed_done(done)
